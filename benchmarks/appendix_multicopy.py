"""Paper §VIII / Appendix-D extension: storage budget for kappa simultaneous
layout copies.  Queries are serviced by the cheapest held copy; movement
replaces one copy.  Measures the storage-for-query-cost tradeoff on the
TPC-H-like workload.
"""
from __future__ import annotations

from typing import List

from benchmarks import common
from repro.core import build_default_layout, layouts, make_generator
from repro.core.extensions import MultiCopyDUMTS


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    total = common.TOTAL_QUERIES // (4 if quick else 2)
    data, stream = common.build_bench("tpch", total_queries=total)
    gen = make_generator("qdtree")

    # Precompute a fixed state space (per-template layouts) so kappa is the
    # only variable.
    by_template = {}
    for q in stream.queries:
        by_template.setdefault(q.template_id, []).append(q)
    store = {}
    for tid, qs in sorted(by_template.items()):
        lay = gen(tid, data, qs[:150], common.PARTITIONS)
        lay.materialize(data)
        store[tid] = lay
    store[len(store)] = build_default_layout(len(store), data,
                                             common.PARTITIONS)

    for kappa in (1, 2, 3):
        d = MultiCopyDUMTS(alpha=common.ALPHA, initial_states=sorted(store),
                           kappa=kappa, seed=0)
        qcost = 0.0
        for q in stream.queries:
            costs = {sid: float(layouts.eval_cost(
                lay.serving_meta(), q.lo, q.hi))
                for sid, lay in store.items()}
            _, c = d.observe(costs)
            qcost += c
        total_cost = qcost + d.total_reorg_cost
        rows.append(common.csv_row(
            f"appendixD.kappa_{kappa}", 0.0,
            f"total={total_cost:.1f};query={qcost:.1f};"
            f"reorg={d.total_reorg_cost:.1f};moves={d.moves};"
            f"storage_copies={kappa}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
