"""Table I: measured relative cost of reorganization vs a full-scan query.

The paper measures Spark+Parquet on local disk: alpha in 60-100x across file
sizes 16MB..4GB.  We measure the same two operations on this host's partition
store (numpy-compressed partitions on local disk): full table scan vs full
reorganization (read + re-route + re-compress + write), across table sizes.
The measured ratio feeds the cost model's alpha (config default 80).
"""
from __future__ import annotations

import tempfile
from typing import List

import numpy as np

from benchmarks import common
from repro.core import build_default_layout, make_generator, make_templates
from repro.data.partition_store import PartitionStore

SIZES_MB = (4, 16, 64)      # synthetic table sizes (npz-compressed scale)


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    rng = np.random.default_rng(0)
    sizes = SIZES_MB[:2] if quick else SIZES_MB
    for mb in sizes:
        n_rows = int(mb * 1024 * 1024 / (12 * 8))      # 12 f64 cols
        data = rng.uniform(0, 100, (n_rows, 12))
        templates = make_templates(3, 12, rng)
        queries = [templates[0].sample(rng, data.min(0), data.max(0))
                   for _ in range(50)]
        with tempfile.TemporaryDirectory() as td:
            store = PartitionStore(td + "/table")
            init = build_default_layout(0, data, common.PARTITIONS)
            store.write(data, init)
            # Full-scan time (averaged).
            scans = [store.full_scan_seconds() for _ in range(3)]
            scan_s = float(np.median(scans))
            # Reorganization: read + BID update + shuffle + compress + write.
            gen = make_generator("qdtree")
            layout = gen(1, data, queries, common.PARTITIONS)
            reorg_s = store.reorganize(layout).seconds
            alpha = reorg_s / max(scan_s, 1e-9)
            rows.append(common.csv_row(
                f"table1.size_{mb}mb", scan_s * 1e6,
                f"query_s={scan_s:.3f};reorg_s={reorg_s:.2f};"
                f"alpha={alpha:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
