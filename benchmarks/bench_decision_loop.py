"""Decision-loop throughput: per-query re-padding vs. the StateMatrix plane.

Measures queries/sec through the online loop at a fixed state space of S
layouts with P partitions each, isolating the metadata plane (layout
*generation* is excluded — candidates are prebuilt — because it costs the
same on every path and would only dilute the comparison):

* ``step/reference``  — ``engine.step`` with the original per-query
  ``eval_cost_states`` re-padding estimate path (``compute="reference"``),
  the "before" number;
* ``step/statematrix`` — ``engine.step`` over the persistent packed
  StateMatrix plane (``compute="numpy"``), bit-identical decisions/costs;
* ``run/batched``     — ``engine.run``'s fast path on the same plane:
  pre-stacked query bounds, serve costs evaluated in blocks.

Writes ``BENCH_decision_loop.json``; the checked-in file tracks the perf
trajectory (acceptance: >= 5x step-loop throughput at S=8, P=256, C=8).
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import layouts, make_templates, generate_workload
from repro.core import workload as wl
from repro.engine import Decision, InMemoryBackend, LayoutEngine


def make_state_space(data: np.ndarray, num_states: int,
                     partitions: int, rng) -> List[layouts.Layout]:
    """S synthetic clustered layouts: each sorts the table along a random
    projection and cuts it into equal partitions (tight zone maps, like the
    generators produce, but cheap enough to sweep)."""
    n = len(data)
    out = []
    for s in range(num_states):
        proj = data @ rng.normal(size=data.shape[1])
        assignment = np.empty(n, dtype=np.int64)
        assignment[np.argsort(proj, kind="stable")] = (
            np.arange(n) * partitions // n)
        meta = layouts.metadata_from_assignment(data, assignment, partitions)
        out.append(layouts.Layout(layout_id=s, name=f"synthetic-{s}",
                                  technique="synthetic", meta=meta))
    return out


class ScoringPolicy:
    """Minimal fixed-state decision layer: score every state per query,
    follow the argmin, never reorganize.  Isolates metadata-plane
    throughput from switching/generation effects."""

    name = "Scoring"
    alpha = 0.0

    def __init__(self, state_space: List[layouts.Layout]):
        self.state_space = state_space
        self.ids = [lay.layout_id for lay in state_space]

    def bind(self, backend) -> int:
        for lay in self.state_space:
            backend.register(lay)
        return self.ids[0]

    def decide(self, index: int, query, backend) -> Decision:
        costs = backend.estimate_costs(self.ids, query)
        return Decision(state=min(costs, key=costs.get))

    def info(self) -> dict:
        return {}


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench_config(data: np.ndarray, queries: List[wl.Query], num_states: int,
                 partitions: int, reps: int, rng) -> List[Dict]:
    state_space = make_state_space(data, num_states, partitions, rng)
    rows = []

    def fresh_engine(compute: str) -> LayoutEngine:
        space = [layouts.Layout(layout_id=lay.layout_id, name=lay.name,
                                technique=lay.technique, meta=lay.meta)
                 for lay in state_space]
        return LayoutEngine(ScoringPolicy(space), InMemoryBackend(
            data, compute=compute))

    def measure(mode: str, make_fn) -> Dict:
        secs = min(_time_once(make_fn()) for _ in range(reps))
        return {
            "S": num_states, "P": partitions, "C": int(data.shape[1]),
            "queries": len(queries), "mode": mode,
            "qps": round(len(queries) / secs, 1),
            "us_per_query": round(secs / len(queries) * 1e6, 2),
        }

    def step_loop(compute):
        engine = fresh_engine(compute)
        engine.start()

        def go():
            for q in queries:
                engine.step(q)
        return go

    def batched_run():
        engine = fresh_engine("numpy")
        engine.start()
        return lambda: engine.run(queries)

    rows.append(measure("step/reference", lambda: step_loop("reference")))
    rows.append(measure("step/statematrix", lambda: step_loop("numpy")))
    rows.append(measure("run/batched", lambda: batched_run()))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the S=8, P=256 acceptance point")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, CI sanity only")
    ap.add_argument("--out", default="BENCH_decision_loop.json")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    if args.smoke:
        # Sized for the CI regression gate: big enough that the
        # StateMatrix-vs-reference speedup ratio is stable run to run
        # (see benchmarks/check_regression.py), small enough to finish in
        # a few seconds on any runner.
        n_rows, n_queries, reps = 8_000, 400, 5
        sweep = [(4, 64)]
    elif args.quick:
        n_rows, n_queries, reps = 40_000, 1_000, 3
        sweep = [(8, 256)]
    else:
        n_rows, n_queries, reps = 40_000, 1_500, 3
        sweep = [(2, 64), (2, 256), (8, 64), (8, 256), (8, 1024),
                 (32, 256), (32, 1024)]
    c = 8
    data = rng.uniform(0, 100, size=(n_rows, c))
    templates = make_templates(6, c, rng)
    stream = generate_workload(templates, data.min(0), data.max(0),
                               total_queries=n_queries, seed=1,
                               segment_length=(200, 400))
    queries = list(stream.queries)

    results: List[Dict] = []
    for num_states, partitions in sweep:
        results.extend(bench_config(data, queries, num_states, partitions,
                                    reps, rng))
        print(f"S={num_states} P={partitions}: " + "  ".join(
            f"{r['mode']}={r['qps']:.0f}q/s" for r in results[-3:]),
            flush=True)

    speedups = {}
    by_key = {(r["S"], r["P"], r["mode"]): r for r in results}
    for num_states, partitions in sweep:
        ref = by_key[(num_states, partitions, "step/reference")]
        sm = by_key[(num_states, partitions, "step/statematrix")]
        run = by_key[(num_states, partitions, "run/batched")]
        speedups[f"S{num_states}_P{partitions}"] = {
            "step": round(sm["qps"] / ref["qps"], 2),
            "batched_run": round(run["qps"] / ref["qps"], 2),
        }

    payload = {
        "benchmark": "decision_loop",
        "units": "queries/sec (best of reps)",
        "config": {"rows": n_rows, "columns": c, "queries": n_queries,
                   "reps": reps, "platform": platform.platform(),
                   "numpy": np.__version__},
        "results": results,
        "speedup_vs_reference": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    for key, s in speedups.items():
        print(f"  {key}: step x{s['step']}, batched run x{s['batched_run']}")


if __name__ == "__main__":
    main()
