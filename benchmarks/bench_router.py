"""Router benchmark: shard-count scaling of the fleet-of-fleets.

The benchmark axis the routing plane opens
(:mod:`repro.engine.router`): T tenants' worth of drift traffic served
by a :class:`FleetRouter` at 1 / 2 / 4 / 8 shards.  Shards share no
mutable state, so the deployment-relevant number on an N-core box is
the **critical path**: every shard drains its own queue in parallel
and the slowest shard gates the fleet.  This process may have a single
core (CI runners often do), so each shard's drain is *timed
individually, run sequentially*, and

    critical-path events/sec = total events / max(per-shard drain wall)

which is exact for perfectly-parallel shards and deterministic given
the placement (consistent hashing fixes each shard's tenant set).  A
``parallel`` lane runs the same placement over real OS processes
(:class:`repro.launch.shard_host.ProcessShardSet`) and reports measured
wall — informative only, since its speedup is capped by
``os.cpu_count()``.

Correctness is asserted inside the benchmark, not just measured:

* the 1-shard router's merged trace is **bit-identical** to a plain
  ``FleetEngine.run`` on the same stream (the router is invisible);
* a live-migration cell moves tenants between shards mid-stream and
  must reproduce the unsharded per-tenant traces bitwise.

The regression gate checks the normalized section ``router_scaling``
(floor-gated): critical-path throughput at N shards divided by the
1-shard router on the same machine.  Routing-plane overhead creep, a
placement bug collapsing tenants onto one shard, or accidental
cross-shard serialization all drag it down wherever it runs.

``--chaos serialize`` migrates every tenant onto shard ``s0`` before
the stream (the placement-collapse failure mode): the critical path
degenerates to the 1-shard wall and the ``router_scaling`` floor must
trip.  Never use it for a checked-in baseline.  See
``check_regression.py``.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import FleetEngine, FleetRouter, InMemoryBackend, \
    LayoutEngine, OreoPolicy
from repro.launch.shard_host import ProcessShardSet

SCENARIO = "sudden_shift"
SHARD_COUNTS = (1, 2, 4, 8)


def tenant_engine(seed: int, rows: int, cols: int, alpha: float,
                  delta: int, partitions: int) -> LayoutEngine:
    """Module-level (and built from a picklable partial) so the same
    factory drives both the inline router and spawned shard workers."""
    data = np.random.default_rng(100 + seed).uniform(
        0, 100, size=(rows, cols))
    cfg = OreoConfig(
        alpha=alpha, seed=0, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data,
                        build_default_layout(0, data, partitions,
                                             sort_col=0),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


def make_factories(num_tenants: int, rows: int, cols: int, alpha: float,
                   delta: int, partitions: int) -> Dict:
    return {f"t{t}": functools.partial(tenant_engine, t, rows, cols,
                                       alpha, delta, partitions)
            for t in range(num_tenants)}


def make_stream(factories, rows: int, cols: int, qpt: int, seed: int):
    lo, hi = np.zeros(cols), np.full(cols, 100.0)
    return make_drift_scenario(SCENARIO, lo, hi,
                               num_tenants=len(factories),
                               queries_per_tenant=qpt, seed=seed)


def assert_same_traces(ref, got, label: str) -> None:
    for tid in ref.per_tenant:
        a, b = ref.per_tenant[tid], got.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs), (label, tid)
        assert a.reorg_indices == b.reorg_indices, (label, tid)
        assert np.array_equal(a.state_seq, b.state_seq), (label, tid)


def sweep_cell(factories, fs, num_shards: int, chaos: str) -> Dict:
    """One shard count: submit everything, time each shard's drain
    individually (sequentially — see module docstring), merge."""
    router = FleetRouter({tid: f() for tid, f in factories.items()},
                         num_shards=num_shards)
    if chaos == "serialize" and num_shards > 1:
        # the placement-collapse failure mode the gate must catch
        for tid in router.tenant_ids:
            router.migrate_tenant(tid, "s0")
    t0 = time.perf_counter()
    for event in fs:
        router.submit(event)
    route_wall = time.perf_counter() - t0

    walls: Dict[str, float] = {}
    depths: Dict[str, int] = {}
    for sid in router.shard_ids:
        shard = router.shard(sid)
        depths[sid] = shard.queue_depth
        t0 = time.perf_counter()
        shard.drain()
        walls[sid] = time.perf_counter() - t0
    result = router.result()
    assert result.ticks == len(fs)

    critical = max(walls.values())
    total = sum(walls.values())
    return {
        "num_shards": num_shards,
        "events": len(fs),
        "events_per_shard": depths,
        "route_wall_s": round(route_wall, 4),
        "critical_path_wall_s": round(critical, 4),
        "serial_wall_s": round(total, 4),
        "critical_path_events_per_sec": round(len(fs) / critical, 1),
        "serial_events_per_sec": round(len(fs) / total, 1),
        "_result": result,
    }


def migration_cell(factories, fs) -> Dict:
    """Mid-stream live migration at 4 shards must keep every per-tenant
    trace bitwise equal to the unsharded fleet."""
    ref = FleetEngine({tid: f() for tid, f in factories.items()}).run(fs)
    router = FleetRouter({tid: f() for tid, f in factories.items()},
                         num_shards=4)
    events = list(fs)
    half = len(events) // 2
    for ev in events[:half]:
        router.submit(ev)
    router.drain()
    moved = 0
    for tid in list(router.tenant_ids)[::4]:
        src = router.shard_of(tid)
        dst = next(s for s in router.shard_ids if s != src)
        if router.migrate_tenant(tid, dst):
            moved += 1
    for ev in events[half:]:
        router.submit(ev)
    router.drain()
    assert_same_traces(ref, router.result(), "migration")
    return {
        "num_shards": 4,
        "tenants_migrated": moved,
        "directory_overrides": len(router.directory.overrides),
        "traces_bit_identical": True,
    }


def parallel_cell(factories, fs, num_shards: int) -> Dict:
    """The same placement over real worker processes — measured wall,
    informative only (speedup is capped by the core count)."""
    t0 = time.perf_counter()
    with ProcessShardSet(factories, num_shards=num_shards) as procs:
        spawn_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        for ev in fs:
            procs.submit(ev)
        procs.drain()
        wall = time.perf_counter() - t0
        result = procs.result()
    assert result.ticks == len(fs)
    return {
        "num_shards": num_shards,
        "cpu_count": os.cpu_count(),
        "spawn_wall_s": round(spawn_wall, 4),
        "wall_s": round(wall, 4),
        "events_per_sec": round(len(fs) / wall, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: small fleet, short streams")
    ap.add_argument("--out", default="BENCH_router.json")
    ap.add_argument("--chaos", choices=("none", "serialize"),
                    default="none",
                    help="serialize: migrate every tenant onto s0 before "
                         "the stream so the critical path collapses and "
                         "the router_scaling floor must trip; never use "
                         "for a checked-in baseline")
    ap.add_argument("--skip-parallel", action="store_true",
                    help="skip the process-parallel lane (informative "
                         "only; spawning workers is slow on tiny runners)")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 16, 1_500, 5, 100
        alpha, delta, partitions = 2.5, 5, 8
    else:
        tenants, rows, cols, qpt = 64, 4_000, 6, 64
        alpha, delta, partitions = 4.0, 8, 8

    factories = make_factories(tenants, rows, cols, alpha, delta,
                               partitions)
    fs = make_stream(factories, rows, cols, qpt, seed=7)

    # Smoke walls are tens of milliseconds; best-of-3 keeps scheduler
    # noise on small CI runners out of the gated ratios.
    repeats = 3 if args.smoke else 1
    results: List[Dict] = []
    by_shards: Dict[int, Dict] = {}
    for n in SHARD_COUNTS:
        row = sweep_cell(factories, fs, n, args.chaos)
        for _ in range(repeats - 1):
            again = sweep_cell(factories, fs, n, args.chaos)
            again.pop("_result")
            if again["critical_path_wall_s"] < row["critical_path_wall_s"]:
                again["_result"] = row.pop("_result")
                row = again
        by_shards[n] = row
        print(f"shards={n}  critical-path="
              f"{row['critical_path_events_per_sec']:9.1f}/s  "
              f"(slowest shard {row['critical_path_wall_s']:.3f}s of "
              f"{row['serial_wall_s']:.3f}s total)", flush=True)

    # the 1-shard router is bit-invisible over a plain fleet
    ref = FleetEngine({tid: f() for tid, f in factories.items()}).run(fs)
    assert_same_traces(ref, by_shards[1].pop("_result"), "one-shard")
    print("one-shard trace identity: ok", flush=True)
    for n in SHARD_COUNTS[1:]:
        by_shards[n].pop("_result")
    results = [by_shards[n] for n in SHARD_COUNTS]

    base = by_shards[1]["critical_path_events_per_sec"]
    scaling = {f"shards{n}_vs_1":
               round(by_shards[n]["critical_path_events_per_sec"] / base, 4)
               for n in SHARD_COUNTS[1:]}
    print("scaling vs 1 shard: " + ", ".join(
        f"{k}=x{v:.2f}" for k, v in scaling.items()), flush=True)
    if args.chaos == "none":
        assert scaling["shards4_vs_1"] >= 2.0, \
            f"4-shard critical path below 2x: {scaling['shards4_vs_1']}"

    migration = migration_cell(factories, fs)
    print(f"migration      moved={migration['tenants_migrated']} "
          f"overrides={migration['directory_overrides']} "
          f"bit_identical={migration['traces_bit_identical']}", flush=True)

    parallel = None
    if not args.skip_parallel:
        parallel = parallel_cell(factories, fs, num_shards=2)
        print(f"parallel(2p)   {parallel['events_per_sec']:9.1f}/s "
              f"measured on {parallel['cpu_count']} cpu(s)", flush=True)

    payload = {
        "benchmark": "router",
        "units": "events/sec; critical path = total events / slowest "
                 "shard's individually-timed drain (shards share no "
                 "state, so parallel deployment is gated by the slowest "
                 "shard); the gated section is a machine-normalized "
                 "ratio vs the 1-shard router",
        "config": {
            "scenario": SCENARIO, "tenants": tenants, "rows": rows,
            "columns": cols, "queries_per_tenant": qpt, "alpha": alpha,
            "delta": delta, "partitions": partitions,
            "smoke": bool(args.smoke), "chaos": args.chaos,
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "migration": migration,
        "parallel": parallel,
        "router_scaling": {SCENARIO: scaling},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
