"""Shared benchmark scaffolding: datasets, workloads, method runners."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (OreoConfig, build_default_layout, generate_workload,
                        make_generator, make_templates)
from repro.core.layout_manager import LayoutManagerConfig
from repro.core.oreo import RunResult
from repro.core.workload import WorkloadStream
from repro.data.datasets import DATASETS, telemetry_templates
from repro.engine import (GreedyPolicy, InMemoryBackend, LayoutEngine,
                          MTSOptimalPolicy, OfflineOptimalPolicy, OreoPolicy,
                          RegretPolicy, StaticPolicy)

# Benchmark scale: the paper runs 30k queries over ~20 segments on 58-column
# denormalized tables; we default to 12k queries over 12 segments (same
# ~1k-queries-per-segment drift rate, same alpha=80) on 32-column tables,
# with 16 templates of 1-2 columns each so no single 32-partition layout can
# serve the whole workload (the paper's conflict structure).
TOTAL_QUERIES = 12_000
NUM_SEGMENTS = 12
NUM_TEMPLATES = 16
NUM_COLUMNS = 32
N_ROWS = 150_000
ALPHA = 80.0
PARTITIONS = 32


def _widen(data: np.ndarray, target_cols: int, seed: int) -> np.ndarray:
    """Pad fact tables with extra measure/dimension columns (the paper's
    denormalized tables have 58 columns; ours start at 9-13)."""
    n, c = data.shape
    if c >= target_cols:
        return data
    rng = np.random.default_rng(seed + 99)
    extra = []
    for i in range(target_cols - c):
        kind = i % 3
        if kind == 0:
            extra.append(rng.uniform(0, 1000, n))
        elif kind == 1:
            extra.append(rng.zipf(1.6, n).clip(max=5000).astype(float))
        else:
            base = data[:, i % c]
            extra.append(base * rng.uniform(0.5, 2.0) + rng.normal(0, 10, n))
    return np.concatenate([data, np.stack(extra, axis=1)], axis=1)


def build_bench(dataset: str, total_queries: int = TOTAL_QUERIES,
                seed: int = 0) -> Tuple[np.ndarray, WorkloadStream]:
    data, names = DATASETS[dataset](N_ROWS, seed=seed)
    rng = np.random.default_rng(seed + 10)
    if dataset == "telemetry":
        templates = telemetry_templates(data.shape[1], seed=seed)
    else:
        data = _widen(data, NUM_COLUMNS, seed)
        templates = make_templates(NUM_TEMPLATES, data.shape[1], rng,
                                   cols_per_template=(1, 2),
                                   selectivity_range=(0.02, 0.10))
    stream = generate_workload(templates, data.min(0), data.max(0),
                               total_queries=total_queries, seed=seed + 20,
                               num_segments=NUM_SEGMENTS)
    return data, stream


def run_methods(data: np.ndarray, stream: WorkloadStream, technique: str,
                alpha: float = ALPHA,
                methods: Tuple[str, ...] = ("Static", "Greedy", "Regret",
                                            "OREO"),
                gamma: float = 1.0, epsilon: float = 0.08, delta: int = 0,
                candidate_source: str = "sw",
                seed: int = 0) -> Dict[str, RunResult]:
    gen = make_generator(technique)
    out: Dict[str, RunResult] = {}
    mgr = LayoutManagerConfig(target_partitions=PARTITIONS, epsilon=epsilon,
                              candidate_source=candidate_source)
    for method in methods:
        t0 = time.time()
        engine_delta = 0
        if method == "Static":
            policy = StaticPolicy(data, stream, gen, alpha,
                                  target_partitions=PARTITIONS)
        elif method == "Greedy":
            policy = GreedyPolicy(data,
                                  build_default_layout(0, data, PARTITIONS),
                                  gen, alpha, mgr_cfg=mgr)
        elif method == "Regret":
            policy = RegretPolicy(data,
                                  build_default_layout(0, data, PARTITIONS),
                                  gen, alpha, mgr_cfg=mgr)
        elif method == "OREO":
            cfg = OreoConfig(alpha=alpha, gamma=gamma, delta=delta, seed=seed,
                             manager=mgr)
            policy = OreoPolicy(data,
                                build_default_layout(0, data, PARTITIONS),
                                gen, cfg)
            engine_delta = delta
        elif method == "MTS Optimal":
            policy = MTSOptimalPolicy(data, stream, gen, alpha,
                                      target_partitions=PARTITIONS,
                                      gamma=gamma, seed=seed)
        elif method == "Offline Optimal":
            policy = OfflineOptimalPolicy(data, stream, gen, alpha,
                                          target_partitions=PARTITIONS)
        else:
            raise ValueError(method)
        res = LayoutEngine(policy, InMemoryBackend(data),
                           delta=engine_delta).run(stream, name=method)
        res.info["wall_seconds"] = time.time() - t0
        out[method] = res
    return out


def avg_over_seeds(data, stream_builder, technique, method_kwargs,
                   seeds=(0, 1, 2)) -> Dict[str, Dict[str, float]]:
    """Average MTS-randomized methods over seeds (paper: mean of 3 runs)."""
    agg: Dict[str, List[RunResult]] = {}
    for s in seeds:
        stream = stream_builder(s)
        res = run_methods(data, stream, technique, seed=s, **method_kwargs)
        for k, v in res.items():
            agg.setdefault(k, []).append(v)
    out = {}
    for k, rs in agg.items():
        out[k] = {
            "total": float(np.mean([r.total_cost for r in rs])),
            "query": float(np.mean([r.total_query_cost for r in rs])),
            "reorg": float(np.mean([r.total_reorg_cost for r in rs])),
            "moves": float(np.mean([r.num_reorgs for r in rs])),
        }
    return out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def result_csv(prefix: str, res: RunResult, n_queries: int) -> str:
    us = res.info.get("wall_seconds", 0.0) * 1e6 / max(n_queries, 1)
    derived = (f"total={res.total_cost:.1f};query={res.total_query_cost:.1f};"
               f"reorg={res.total_reorg_cost:.1f};moves={res.num_reorgs}")
    return csv_row(prefix, us, derived)
