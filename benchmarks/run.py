"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks query
counts ~4x for smoke runs; the full run reproduces the paper's Fig. 3/4/5/6
and Tables I/II at reduced (documented) scale plus kernel rooflines.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# Invoked as ``python benchmarks/run.py``, sys.path[0] is benchmarks/
# itself — put the repo root first so the ``benchmarks`` package resolves.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig3,table1")
    args, _ = ap.parse_known_args()

    from benchmarks import (appendix_multicopy, bench_kernels,
                            fig3_end_to_end, fig4_gap_to_optimal,
                            fig5_alpha_sweep, fig6_epsilon_sweep,
                            table1_alpha, table2_ablations)
    suites = {
        "fig3": fig3_end_to_end.run,
        "fig4": fig4_gap_to_optimal.run,
        "fig5": fig5_alpha_sweep.run,
        "fig6": fig6_epsilon_sweep.run,
        "table1": table1_alpha.run,
        "table2": table2_ablations.run,
        "appendixD": appendix_multicopy.run,
        "kernels": bench_kernels.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn(quick=args.quick):
                print(row, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # keep the suite going; record the failure
            import traceback
            traceback.print_exc()
            print(f"{name}.FAILED,0,error={type(e).__name__}")


if __name__ == "__main__":
    main()
