"""Serving benchmark: the admission-controlled front end under load.

The benchmark axis the serving tier opens (:mod:`repro.serve.frontend`):
for the two stress scenarios — ``flash_crowd`` (drift: one tenant's
query rate spikes) and ``ingest_burst`` (streaming: appends arrive in
bursts between reads) — a multi-tenant OREO fleet serves the same event
stream two ways:

* **direct**   — ``FleetEngine.run(stream)``: the raw engine loop, no
  serving tier; its events/sec is the machine-local baseline;
* **frontend** — :class:`repro.serve.ServeFrontend` in a closed serving
  loop (submit → pump), with the bounded ingress queue, per-tenant
  admission, the circuit breaker, and the plane-versioned serve-cost
  cache all active.  Per-event wall latency (admission → completion) is
  stamped for the p50/p99 cells.

Both arms see identical events and must produce bit-identical traces
(asserted).  Raw QPS and raw milliseconds are machine-local, so the
regression gate checks **normalized** sections, both sides measured in
the same process:

* ``serving_qps_ratio``   (floor-gated): frontend QPS / direct QPS —
  overhead creep in the serving tier drags it down on any machine;
* ``latency_tail``        (ceiling-gated): p99 / p50 latency — tail
  amplification (a stall on a fraction of events) inflates it while
  leaving the QPS ratio nearly untouched.

An **overload** cell (flash_crowd through an undersized queue on a
K=1 scheduler) exercises the breaker and asserts the serving-tier
contract: >= 1 reorganization deferred, zero queries dropped, and the
per-tenant charge ledgers bitwise identical to the unshedded run.

``--chaos uniform|tail`` injects ``time.sleep`` into the dispatch path
(every event / every 50th event) to verify the gates trip: ``uniform``
must fail the ``serving_qps_ratio`` floor, ``tail`` the
``latency_tail`` ceiling.  See ``check_regression.py``.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario, make_ingest_scenario
from repro.engine import (FleetEngine, InMemoryBackend, IngestConfig,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          UnlimitedScheduler)
from repro.serve import FrontendConfig, ServeFrontend

SCENARIOS = ("flash_crowd", "ingest_burst")
INGEST_SCENARIOS = ("ingest_burst",)


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int,
                  ingest: Optional[IngestConfig]) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=0, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data,
                        build_default_layout(0, data, partitions, sort_col=0),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        ingest=ingest)


def make_stream(scenario: str, col_lo, col_hi, num_tenants: int,
                queries_per_tenant: int, seed: int):
    if scenario in INGEST_SCENARIOS:
        return make_ingest_scenario(scenario, col_lo, col_hi,
                                    num_tenants=num_tenants,
                                    queries_per_tenant=queries_per_tenant,
                                    seed=seed)
    return make_drift_scenario(scenario, col_lo, col_hi,
                               num_tenants=num_tenants,
                               queries_per_tenant=queries_per_tenant,
                               seed=seed)


def build_fleet(fs, tenant_data, scenario, alpha, delta, partitions,
                scheduler_factory=UnlimitedScheduler) -> FleetEngine:
    ingest = IngestConfig() if scenario in INGEST_SCENARIOS else None
    return FleetEngine(
        {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions,
                            ingest)
         for tid in fs.tenant_ids}, scheduler_factory())


class _ChaosFrontend(ServeFrontend):
    """Gate-verification aid: sleeps inside the dispatch path."""

    def __init__(self, fleet, config, mode: str, seconds: float):
        super().__init__(fleet, config)
        self._chaos_mode = mode
        self._chaos_seconds = seconds
        self._chaos_n = 0

    def _dispatch_one(self, ev, t0):
        self._chaos_n += 1
        if self._chaos_mode == "uniform" or \
                (self._chaos_mode == "tail" and self._chaos_n % 50 == 0):
            time.sleep(self._chaos_seconds)
        super()._dispatch_one(ev, t0)


def serving_config() -> FrontendConfig:
    # A shallow queue kept drained by the closed loop: latency stamps
    # measure dispatch, not open-loop queueing; the breaker stays armed
    # but never trips at this depth, so the trace is the direct one.
    return FrontendConfig(queue_capacity=64, overflow_policy="block",
                          pump_chunk=8, record_latency=True)


def assert_same_trace(a, b, scenario: str) -> None:
    for tid in a.per_tenant:
        x, y = a.per_tenant[tid], b.per_tenant[tid]
        assert np.array_equal(x.query_costs, y.query_costs), scenario
        assert x.reorg_indices == y.reorg_indices, scenario
        assert np.array_equal(x.state_seq, y.state_seq), scenario


def bench_cell(scenario: str, tenant_data, col_lo, col_hi,
               queries_per_tenant: int, alpha: float, delta: int,
               partitions: int, seed: int, chaos: str,
               chaos_seconds: float) -> Dict:
    fs = make_stream(scenario, col_lo, col_hi, len(tenant_data),
                     queries_per_tenant, seed)

    direct_fleet = build_fleet(fs, tenant_data, scenario, alpha, delta,
                               partitions)
    t0 = time.perf_counter()
    direct = direct_fleet.run(fs)
    direct_wall = time.perf_counter() - t0
    direct_qps = direct.ticks / direct_wall

    serve_fleet = build_fleet(fs, tenant_data, scenario, alpha, delta,
                              partitions)
    if chaos == "none":
        fe = ServeFrontend(serve_fleet, serving_config())
    else:
        fe = _ChaosFrontend(serve_fleet, serving_config(), chaos,
                            chaos_seconds)
    t0 = time.perf_counter()
    for event in fs:
        fe.submit_blocking(event)
        fe.pump()
    fe.flush()
    serve_wall = time.perf_counter() - t0
    got = fe.result()
    assert_same_trace(direct, got, scenario)

    stats = fe.stats()
    assert stats["processed"] == len(fs)
    lat_ms = np.asarray(fe.latencies) * 1e3
    p50, p99 = (float(np.percentile(lat_ms, p)) for p in (50, 99))
    qps = stats["processed"] / serve_wall
    return {
        "scenario": scenario,
        "tenants": len(fs.tenant_ids),
        "events": len(fs),
        "queries_per_tenant": queries_per_tenant,
        "direct": {"events_per_sec": round(direct_qps, 1)},
        "frontend": {
            "events_per_sec": round(qps, 1),
            "p50_ms": round(p50, 4),
            "p99_ms": round(p99, 4),
            "cache": stats["cache"],
            "breaker_opens": stats["breaker"]["opens"],
        },
        "qps_ratio": round(qps / direct_qps, 4),
        "p99_over_p50": round(p99 / max(p50, 1e-9), 4),
    }


OVERLOAD = dict(queue_capacity=48, overflow_policy="block",
                breaker_open_frac=0.5, breaker_close_frac=0.1,
                breaker_min_open_events=16, pump_chunk=4,
                record_latency=False)


def overload_cell(tenant_data, col_lo, col_hi, queries_per_tenant: int,
                  alpha: float, delta: int, partitions: int,
                  seed: int) -> Dict:
    """Flash crowd through an undersized queue on K=1: the breaker must
    shed reorg work while the serve path and the α-ledger stay exact."""
    fs = make_stream("flash_crowd", col_lo, col_hi, len(tenant_data),
                     queries_per_tenant, seed)
    ref = build_fleet(fs, tenant_data, "flash_crowd", alpha, delta,
                      partitions, lambda: KConcurrentScheduler(1)).run(fs)
    fleet = build_fleet(fs, tenant_data, "flash_crowd", alpha, delta,
                        partitions, lambda: KConcurrentScheduler(1))
    fe = ServeFrontend(fleet, FrontendConfig(**OVERLOAD))
    got = fe.run(fs)
    stats = fe.stats()

    dropped = sum(queries_per_tenant - len(got.per_tenant[t].query_costs)
                  for t in fs.tenant_ids)
    ledger_identical = all(
        got.per_tenant[t].reorg_indices == ref.per_tenant[t].reorg_indices
        and np.array_equal(got.per_tenant[t].state_seq,
                           ref.per_tenant[t].state_seq)
        for t in fs.tenant_ids)
    assert stats["breaker"]["opens"] >= 1, "overload never tripped breaker"
    assert stats["shed_count"] >= 1, "breaker deferred no reorg work"
    assert dropped == 0, f"{dropped} queries dropped under overload"
    assert ledger_identical, "shedding perturbed the charge ledger"
    return {
        "scenario": "flash_crowd",
        "queue_capacity": OVERLOAD["queue_capacity"],
        "scheduler": "k-concurrent(1)",
        "breaker_opens": stats["breaker"]["opens"],
        "breaker_closes": stats["breaker"]["closes"],
        "shed_count": stats["shed_count"],
        "shed_attempts": stats["shed_attempts"],
        "queries_dropped": dropped,
        "charge_ledger_identical": ledger_identical,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: small fleet, short streams")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--chaos", choices=("none", "uniform", "tail"),
                    default="none",
                    help="inject time.sleep into dispatch to verify the "
                         "gates trip (uniform -> QPS floor, tail -> p99 "
                         "ceiling); never use for a checked-in baseline")
    ap.add_argument("--chaos-seconds", type=float, default=0.002)
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 150
        alpha, delta, partitions = 2.5, 5, 8
        overload_qpt = 120
    else:
        tenants, rows, cols, qpt = 4, 8_000, 8, 800
        alpha, delta, partitions = 4.0, 10, 16
        overload_qpt = 400

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    qps_ratios: Dict[str, Dict[str, float]] = {}
    tails: Dict[str, Dict[str, float]] = {}
    for scenario in SCENARIOS:
        row = bench_cell(scenario, tenant_data, col_lo, col_hi, qpt,
                         alpha, delta, partitions, seed=7,
                         chaos=args.chaos,
                         chaos_seconds=args.chaos_seconds)
        results.append(row)
        qps_ratios[scenario] = {"frontend_vs_direct": row["qps_ratio"]}
        tails[scenario] = {"p99_over_p50": row["p99_over_p50"]}
        print(f"{scenario:14s} direct={row['direct']['events_per_sec']:9.1f}/s "
              f"frontend={row['frontend']['events_per_sec']:9.1f}/s "
              f"(x{row['qps_ratio']:.3f}) "
              f"p50={row['frontend']['p50_ms']:.3f}ms "
              f"p99={row['frontend']['p99_ms']:.3f}ms "
              f"(tail x{row['p99_over_p50']:.2f})", flush=True)

    over = overload_cell(tenant_data, col_lo, col_hi, overload_qpt,
                         alpha, delta, partitions, seed=7)
    print(f"overload       breaker opens={over['breaker_opens']} "
          f"shed={over['shed_count']} dropped={over['queries_dropped']} "
          f"ledger_identical={over['charge_ledger_identical']}")

    payload = {
        "benchmark": "serving",
        "units": "events/sec (QPS) and wall-clock ms per event; the gated "
                 "sections are machine-normalized ratios",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "smoke": bool(args.smoke),
            "chaos": args.chaos,
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "overload": over,
        "serving_qps_ratio": qps_ratios,
        "latency_tail": tails,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
