"""Reorg benchmark: atomic vs incremental migration under shared budgets.

The new benchmark axis the incremental reorganization plane opens
(:mod:`repro.engine.reorg`): for every registered drift scenario, a
multi-tenant fleet of OREO tenants runs twice under the *same* shared
maintenance budget —

* **atomic-deferred** — today's wholesale semantics: a reorganization
  banks one whole budget grant (a token buys a full table rewrite) and
  the fleet serves the stale layout until the swap lands;
* **incremental** — ``incremental=True`` engines under the same budget
  denominated in *rows* (``TokenBucketScheduler(rows_per_token=...)``):
  micro-moves trickle at the equivalent row bandwidth, and hybrid-layout
  serving realizes skipping benefit move by move while the migration is
  still in flight.

Both arms make bit-identical decisions (decisions are metadata-only and
never read the serving layout), charge bit-identical reorganization cost
(α at decision time; each completed migration's charge ledger telescopes
to exactly α — asserted here), and get the same rows/tick of maintenance
bandwidth — so the combined query+reorg cost difference isolates the
value of serving hybrid layouts early.  Costs are deterministic given the
seeds, which is what lets ``check_regression.py`` gate on the
``cost_ratio_atomic_over_incremental`` grid (ratio > 1: incremental
wins).

An ``unlimited``-budget cell rides along as a self-check: with no budget
pressure the two arms must land bitwise-identical totals.

``--smoke`` is the CI configuration; the checked-in ``reorg_smoke``
section of ``BENCH_reorg.json`` holds the baseline ratios the regression
gate compares against.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import (FleetEngine, InMemoryBackend, LayoutEngine,
                          OreoPolicy, TokenBucketScheduler,
                          UnlimitedScheduler)

SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
             "flash_crowd", "template_churn"]


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int, incremental: bool) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=0, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data, build_default_layout(0, data, partitions),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        incremental=incremental)


def budget_factories(label: str, rate: float, rows: int):
    """(atomic scheduler, incremental scheduler) under one shared budget.

    ``bucket``: the atomic arm banks one token per wholesale swap at
    ``rate`` tokens/tick; the incremental arm gets the row-denominated
    equivalent — ``rate * rows`` rows/tick, up to one banked migration —
    so both arms have the same maintenance bandwidth and the comparison
    isolates hybrid serving.
    """
    if label == "unlimited":
        return UnlimitedScheduler, UnlimitedScheduler
    if label == "bucket":
        return (lambda: TokenBucketScheduler(rate=rate, capacity=1.0,
                                             initial=0.0),
                lambda: TokenBucketScheduler(rate=rate * rows,
                                             capacity=float(rows),
                                             initial=0.0,
                                             rows_per_token=1.0))
    raise ValueError(label)


def ledger_stats(fleet: FleetEngine) -> Dict:
    migrations = completed = moves = rows_moved = 0
    charged = 0.0
    exact = True
    for tid in fleet.tenant_ids:
        ex = fleet.tenant(tid).reorg_executor
        if ex is None:
            continue
        for m in ex.migrations:
            migrations += 1
            rows_moved += m.moved_rows
            moves += m.moves_done
            charged += m.charged
            if m.completed_at >= 0:
                completed += 1
                exact = exact and (m.charged == m.alpha)
    return {"migrations": migrations, "completed": completed,
            "moves_done": moves, "rows_moved": rows_moved,
            "charged": round(charged, 6), "charge_exact": exact}


def bench_cell(scenario: str, budget: str, rate: float, tenant_data,
               col_lo, col_hi, queries_per_tenant: int, alpha: float,
               delta: int, partitions: int, rows: int, seed: int) -> Dict:
    fs = make_drift_scenario(scenario, col_lo, col_hi,
                             num_tenants=len(tenant_data),
                             queries_per_tenant=queries_per_tenant,
                             seed=seed)
    atomic_sched, incr_sched = budget_factories(budget, rate, rows)

    def fleet(incremental: bool) -> FleetEngine:
        factory = incr_sched if incremental else atomic_sched
        return FleetEngine(
            {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions,
                                incremental)
             for tid in fs.tenant_ids}, factory())

    t0 = time.perf_counter()
    ra = fleet(False).run(fs)
    atomic_wall = time.perf_counter() - t0
    incr_fleet = fleet(True)
    t0 = time.perf_counter()
    ri = incr_fleet.run(fs)
    incr_wall = time.perf_counter() - t0
    ledger = ledger_stats(incr_fleet)
    assert ledger["charge_exact"], \
        f"{scenario}/{budget}: a completed migration's ledger != alpha"
    if budget == "unlimited":
        assert ra.total_cost == ri.total_cost, \
            f"{scenario}: unbudgeted atomic/incremental diverged"
    # Reorg charges are count * alpha in both arms (decisions identical);
    # any combined-cost difference is query cost realized earlier.
    assert ra.total_reorg_cost == ri.total_reorg_cost, \
        f"{scenario}/{budget}: reorg accounting diverged"
    return {
        "scenario": scenario,
        "budget": budget,
        "atomic_scheduler": ra.scheduler,
        "incremental_scheduler": ri.scheduler,
        "tenants": len(fs.tenant_ids),
        "events": ra.ticks,
        "atomic_total_cost": round(ra.total_cost, 3),
        "incremental_total_cost": round(ri.total_cost, 3),
        "atomic_query_cost": round(ra.total_query_cost, 3),
        "incremental_query_cost": round(ri.total_query_cost, 3),
        "reorg_cost": round(ra.total_reorg_cost, 3),
        "reorgs": ra.num_reorgs,
        "atomic_swaps_deferred": ra.swaps_deferred,
        "cost_ratio_atomic_over_incremental": round(
            ra.total_cost / max(ri.total_cost, 1e-12), 4),
        "incremental_ledger": ledger,
        "atomic_events_per_sec": round(ra.ticks / atomic_wall, 1),
        "incremental_events_per_sec": round(ri.ticks / incr_wall, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: all scenarios x {unlimited, bucket}")
    ap.add_argument("--out", default="BENCH_reorg.json")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 150
        alpha, delta, partitions = 4.0, 10, 8
        rate = 0.005
    else:
        tenants, rows, cols, qpt = 4, 8_000, 8, 1_000
        alpha, delta, partitions = 10.0, 10, 16
        rate = 0.002

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    ratios: Dict[str, Dict[str, float]] = {}
    wins = 0
    for scenario in SCENARIOS:
        ratios[scenario] = {}
        for budget in ("unlimited", "bucket"):
            row = bench_cell(scenario, budget, rate, tenant_data, col_lo,
                             col_hi, qpt, alpha, delta, partitions, rows,
                             seed=7)
            results.append(row)
            ratio = row["cost_ratio_atomic_over_incremental"]
            ratios[scenario][budget] = ratio
            if budget == "bucket" and ratio > 1.0:
                wins += 1
            print(f"{scenario:16s} x {budget:10s} "
                  f"atomic={row['atomic_total_cost']:9.1f} "
                  f"incremental={row['incremental_total_cost']:9.1f} "
                  f"ratio={ratio:.3f} "
                  f"(moves={row['incremental_ledger']['moves_done']}, "
                  f"rows={row['incremental_ledger']['rows_moved']})",
                  flush=True)
    print(f"incremental beats atomic-deferred in {wins}/{len(SCENARIOS)} "
          f"scenarios under the tight bucket budget")

    payload = {
        "benchmark": "reorg",
        "units": "combined query+reorg cost (fraction-of-table + alpha per "
                 "reorg); ratio > 1 means incremental wins",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "bucket_rate": rate,
            "row_bandwidth_per_tick": rate * rows,
            "smoke": bool(args.smoke),
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "bucket_wins": {"incremental": wins, "scenarios": len(SCENARIOS)},
        "cost_ratio_atomic_over_incremental": ratios,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
