"""Fig. 5: effect of the relative reorganization cost alpha.

Paper claims: total gains shrink as alpha grows; the number of layout changes
drops (35 @ alpha=10 -> 18 @ alpha=300 in the paper); the decrease in total
cost is non-monotonic because the algorithm adapts its switching strategy.
"""
from __future__ import annotations

from typing import List

from benchmarks import common


ALPHAS = (10.0, 40.0, 80.0, 170.0, 300.0)


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    total = common.TOTAL_QUERIES // (4 if quick else 1)
    data, stream = common.build_bench("tpch", total_queries=total)
    for alpha in ALPHAS:
        res = common.run_methods(data, stream, "qdtree", alpha=alpha,
                                 methods=("OREO", "Static"))
        r = res["OREO"]
        static = res["Static"]
        gain = 100.0 * (static.total_cost - r.total_cost) / static.total_cost
        rows.append(common.csv_row(
            f"fig5.alpha_{int(alpha)}",
            r.info.get("wall_seconds", 0) * 1e6 / len(stream),
            f"total={r.total_cost:.1f};moves={r.num_reorgs};"
            f"gain_vs_static_pct={gain:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
