"""Kernel-perf lane: per-kernel µs/call + the fused-vs-separate ratio.

Successor of the old ``kernel_perf`` CSV module (its pruning / zorder /
flash-attention roofline rows still come out of :func:`run` for
``benchmarks/run.py``), promoted to a first-class BENCH family writing
``BENCH_kernels.json`` with three lanes:

* **fused_vs_separate** — the gated ratio.  The decision megakernel's
  dataflow (one pass over the packed ``(T, S, P, C)`` bounds plane
  emitting frame scan matrix + per-state costs + window scan
  frequencies) timed against the pre-megakernel dataflow it replaced
  (B separate per-frame ``fleet_scan`` launches + a reduction pass +
  T per-tenant ``move_score`` launches).  Both sides run the compiled
  XLA oracles so the lane is meaningful on CPU-only runners — the ratio
  isolates the *dataflow* win (one launch, one operand read) from
  Mosaic codegen, and a regression in either fused plumbing or the
  launch structure drags it below the gate.
* **interpret** — the Pallas megakernel in interpret mode on tiny
  shapes: not a speed measurement (interpret mode is a correctness
  vehicle) but proof on every runner that the kernel executes and
  matches its oracle bitwise.
* **compiled_pallas** — the megakernel compiled via Mosaic vs the three
  compiled separate kernels.  Skipped with an explicit reason on
  CPU-only runners (no Mosaic target); runs on TPU/GPU CI.

``--smoke`` is the CI configuration; the checked-in ``kernels_smoke``
section of ``BENCH_kernels.json`` holds the baseline ratio the
regression gate (benchmarks/check_regression.py) compares against.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

# Invoked as ``python benchmarks/bench_kernels.py``, sys.path[0] is
# benchmarks/ itself — put the repo root first so the package resolves.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from repro.kernels.decision_fused import decision_fused as df_kernel
from repro.kernels.decision_fused import ops as df_ops
from repro.kernels.fleet_scan import ref as fs_ref
from repro.kernels.move_score import ref as ms_ref
from repro.kernels.pruning import ref as prune_ref
from repro.kernels.zorder import ref as z_ref

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s


def _time(f, *args, iters: int = 5, **kw):
    """Best-of-iters wall seconds; compiles/warns on the warmup call."""
    out = f(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = f(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _fused_operands(B: int, T: int, S: int, P: int, C: int, W: int,
                    seed: int = 0):
    """float32 fleet plane + frame queries + recent-query window."""
    rng = np.random.default_rng(seed)
    f32 = jnp.float32
    q_lo = jnp.asarray(rng.uniform(0, 1, (B, T, C)), f32)
    q_hi = q_lo + 0.15
    p_min = jnp.asarray(rng.uniform(0, 1, (T, S, P, C)), f32)
    p_max = p_min + 0.2
    rows = jnp.asarray(rng.integers(100, 1000, (T, S, P)), f32)
    inv = 1.0 / rows.sum(axis=-1)
    w_lo = jnp.asarray(rng.uniform(0, 1, (W, C)), f32)
    w_hi = w_lo + 0.15
    return q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi


# ---------------------------------------------------------------------------
# Lane 1 (gated): fused dataflow vs the separate-pass dataflow it replaced
# ---------------------------------------------------------------------------

@jax.jit
def _reduce_cost(scan, rows, inv):
    return (scan * rows[None]).sum(axis=-1) * inv[None]


def _separate_passes(q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi):
    """The pre-megakernel per-tick dataflow: one ``fleet_scan`` launch per
    frame over the flattened plane, a reduction pass for costs, and one
    ``move_score`` launch per tenant for window frequencies — three reads
    of the bounds tensors and B + T + 1 launches."""
    B = q_lo.shape[0]
    T, S, P, C = p_min.shape
    pm2 = p_min.reshape(T, S * P, C)
    px2 = p_max.reshape(T, S * P, C)
    scans = [fs_ref.scan_fleet(q_lo[b], q_hi[b], pm2, px2) for b in range(B)]
    scan = jnp.stack(scans).reshape(B, T, S, P)
    cost = _reduce_cost(scan, rows, inv)
    freq = jnp.stack([ms_ref.move_scores(w_lo, w_hi, p_min[t], p_max[t])
                      for t in range(T)])
    return scan, cost, freq


def bench_fused_vs_separate(B: int, T: int, S: int, P: int, C: int, W: int,
                            reps: int, seed: int = 0) -> Dict:
    ops = _fused_operands(B, T, S, P, C, W, seed)

    def fused(*a):
        return df_ops.fused_decision(*a, use_kernel=False)

    fused_s = _time(fused, *ops, iters=reps)
    sep_s = _time(_separate_passes, *ops, iters=reps)

    # Same operands, same outputs: parity guards the measurement.
    f_scan, f_cost, f_freq = fused(*ops)
    s_scan, s_cost, s_freq = _separate_passes(*ops)
    assert np.array_equal(np.asarray(f_scan), np.asarray(s_scan))
    assert np.allclose(np.asarray(f_cost), np.asarray(s_cost), atol=1e-6)
    assert np.array_equal(np.asarray(f_freq), np.asarray(s_freq))

    return {
        "B": B, "T": T, "S": S, "P": P, "C": C, "W": W,
        "fused_us": round(fused_s * 1e6, 1),
        "separate_us": round(sep_s * 1e6, 1),
        "ratio": round(sep_s / fused_s, 2),
    }


# ---------------------------------------------------------------------------
# Lane 2 (always runs): megakernel in interpret mode, tiny shapes
# ---------------------------------------------------------------------------

def bench_interpret_lane(seed: int = 0) -> Dict:
    B, T, S, P, C, W = 2, 3, 2, 8, 4, 4
    ops = _fused_operands(B, T, S, P, C, W, seed)

    def kernel(*a):
        return df_kernel.fused_decision_pallas(*a, bt=2, bp=4, interpret=True)

    s = _time(kernel, *ops, iters=2)
    k_scan, k_cost, k_freq = kernel(*ops)
    o_scan, o_cost, o_freq = df_ops.fused_decision(*ops, use_kernel=False)
    assert np.array_equal(np.asarray(k_scan), np.asarray(o_scan))
    assert np.allclose(np.asarray(k_cost), np.asarray(o_cost), atol=1e-6)
    assert np.array_equal(np.asarray(k_freq), np.asarray(o_freq))
    return {
        "B": B, "T": T, "S": S, "P": P, "C": C, "W": W,
        "us_per_call": round(s * 1e6, 1),
        "parity_vs_oracle": "exact",
    }


# ---------------------------------------------------------------------------
# Lane 3 (accelerator only): megakernel compiled via Mosaic
# ---------------------------------------------------------------------------

def bench_compiled_pallas_lane(B: int, T: int, S: int, P: int, C: int, W: int,
                               reps: int, seed: int = 0) -> Dict:
    backend = jax.default_backend()
    if backend == "cpu":
        return {
            "skipped": True,
            "reason": "compiled Pallas lane needs an accelerator backend "
                      "(jax.default_backend() == 'cpu': Mosaic codegen "
                      "unavailable, interpret lane covers correctness)",
        }
    ops = _fused_operands(B, T, S, P, C, W, seed)

    def kernel(*a):
        return df_kernel.fused_decision_pallas(*a, interpret=False)

    fused_s = _time(kernel, *ops, iters=reps)
    sep_s = _time(_separate_passes, *ops, iters=reps)
    return {
        "backend": backend,
        "B": B, "T": T, "S": S, "P": P, "C": C, "W": W,
        "fused_kernel_us": round(fused_s * 1e6, 1),
        "separate_us": round(sep_s * 1e6, 1),
        "ratio": round(sep_s / fused_s, 2),
    }


# ---------------------------------------------------------------------------
# CSV entry point for benchmarks/run.py (legacy kernel_perf lanes + fused)
# ---------------------------------------------------------------------------

def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    # Pruning matrix: Q x P x C interval-overlap (paper's eval_skipped).
    Q, P, C = (2048, 512, 32) if not quick else (512, 128, 16)
    rng = np.random.default_rng(0)
    q_lo = jnp.asarray(rng.uniform(0, 1, (Q, C)), jnp.float32)
    q_hi = q_lo + 0.2
    p_min = jnp.asarray(rng.uniform(0, 1, (P, C)), jnp.float32)
    p_max = p_min + 0.2
    f = jax.jit(prune_ref.scan_matrix)
    s = _time(f, q_lo, q_hi, p_min, p_max)
    flops = 4.0 * Q * P * C                   # 2 cmp + 1 and + reduce
    bytes_ = 4.0 * (Q * C * 2 + P * C * 2 + Q * P)
    ai = flops / bytes_
    tpu_bound_us = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6
    rows.append(common.csv_row(
        f"kernel.pruning.{Q}x{P}x{C}", s * 1e6,
        f"flops={flops:.2e};bytes={bytes_:.2e};arith_intensity={ai:.2f};"
        f"tpu_roofline_us={tpu_bound_us:.1f};bound=memory"))

    # Z-order keys.
    N, m, bits = (1_000_000, 3, 10) if not quick else (100_000, 3, 10)
    vals = jnp.asarray(rng.uniform(0, 1, (N, m)), jnp.float32)
    lo = vals.min(0)
    hi = vals.max(0)
    f = jax.jit(lambda v: z_ref.zorder_keys(v, lo, hi, bits))
    s = _time(f, vals)
    bytes_ = 4.0 * N * m + 4.0 * N
    ops = float(N * m * bits * 3)
    rows.append(common.csv_row(
        f"kernel.zorder.{N}x{m}", s * 1e6,
        f"int_ops={ops:.2e};bytes={bytes_:.2e};"
        f"tpu_roofline_us={bytes_ / HBM_BW * 1e6:.1f};bound=memory"))

    # Flash attention jnp path (CPU) + analytic TPU roofline.
    B, H, T, dh = (1, 8, 1024, 64) if quick else (2, 8, 2048, 64)
    from repro.models import layers as L
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, dh), jnp.float32)
    f = jax.jit(lambda a, b, c: L.flash_attention(a, b, c, causal=True))
    s = _time(f, q, k, v, iters=3)
    flops = 4.0 * B * H * T * T * dh / 2      # causal halves the work
    bytes_ = 2.0 * (3 * B * T * H * dh + B * T * H * dh)
    rows.append(common.csv_row(
        f"kernel.flash_attention.{B}x{H}x{T}x{dh}", s * 1e6,
        f"flops={flops:.2e};bytes={bytes_:.2e};"
        f"tpu_roofline_us={max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6:.1f};"
        f"bound=compute"))

    # Fused decision megakernel dataflow vs the three separate passes.
    shape = (8, 8, 8, 64, 8, 32) if quick else (16, 16, 8, 128, 12, 64)
    cell = bench_fused_vs_separate(*shape, reps=3)
    rows.append(common.csv_row(
        "kernel.decision_fused."
        f"B{shape[0]}xT{shape[1]}xS{shape[2]}xP{shape[3]}", cell["fused_us"],
        f"separate_us={cell['separate_us']};"
        f"fused_vs_separate=x{cell['ratio']:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# JSON entry point: the BENCH_kernels.json family
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: one fused-vs-separate cell + interpret "
                         "lane, small")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()

    if args.smoke:
        cells = [dict(B=16, T=8, S=8, P=64, C=8, W=32)]
        reps = 5
    else:
        cells = [dict(B=16, T=8, S=8, P=64, C=8, W=32),
                 dict(B=32, T=16, S=8, P=128, C=12, W=64),
                 dict(B=32, T=32, S=8, P=128, C=12, W=64)]
        reps = 7

    grid: List[Dict] = []
    ratios: Dict[str, Dict[str, float]] = {}
    for cfg in cells:
        cell = bench_fused_vs_separate(reps=reps, **cfg)
        grid.append(cell)
        key = f"B{cfg['B']}_T{cfg['T']}_S{cfg['S']}_P{cfg['P']}"
        ratios[key] = {"fused_vs_separate": cell["ratio"]}
        print(f"{key:24s} fused={cell['fused_us']:9.1f}us "
              f"separate={cell['separate_us']:9.1f}us "
              f"x{cell['ratio']:.2f}", flush=True)

    interp = bench_interpret_lane()
    print(f"interpret lane: {interp['us_per_call']:.1f}us/call "
          f"({interp['parity_vs_oracle']} vs oracle)", flush=True)
    big = cells[-1]
    compiled = bench_compiled_pallas_lane(reps=reps, **big)
    if compiled.get("skipped"):
        print(f"compiled pallas lane: SKIPPED ({compiled['reason']})",
              flush=True)
    else:
        print(f"compiled pallas lane ({compiled['backend']}): "
              f"fused={compiled['fused_kernel_us']:.1f}us "
              f"x{compiled['ratio']:.2f}", flush=True)

    payload = {
        "benchmark": "kernels",
        "units": "us per call (best of reps, block_until_ready); "
                 "fused_vs_separate = separate-passes wall / fused wall on "
                 "identical operands, compiled XLA",
        "config": {
            "cells": cells, "reps": reps, "smoke": bool(args.smoke),
            "platform": platform.platform(), "numpy": np.__version__,
            "jax": jax.__version__, "jax_backend": jax.default_backend(),
        },
        "results": grid,
        "fused_vs_separate": ratios,
        "interpret_lane": interp,
        "compiled_pallas_lane": compiled,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
