"""Fleet benchmark: drift scenarios x reorg schedulers, loop vs batched.

Two sections, both written to ``BENCH_fleet.json``:

* **Scenario grid** — a multi-tenant :class:`repro.engine.FleetEngine` of
  OREO-policy tenants through each registered drift scenario
  (``repro.core.workload.DRIFT_SCENARIOS``) under each reorganization
  scheduler, once through the stepwise loop (``fleet.run``) and once
  through the packed-plane batched path (``fleet.run_batched``).  Reports
  combined query+reorg cost, deferrals, both throughputs, and asserts the
  two paths land identical total costs (the golden trace tests in
  ``tests/test_fleet_matrix.py`` check bit-identity query by query).

* **Tenant sweep** (T=4..64) — the fleet-plane microbenchmark behind the
  CI speedup gate: per tenant a fixed state space of synthetic clustered
  layouts and a stateless argmin policy over ``backend.estimate_vector``
  (isolating the decision plane, exactly like ``bench_decision_loop``'s
  ScoringPolicy isolates the single-table plane), selective range queries
  on every column.  The batched side runs ``compute="pallas_fused"``
  (f64 operands, so the float32 guard routes scoring through the exact
  numpy fused pass) and, because the policy implements
  ``decide_frames``, resolves whole no-reorg frame regions through the
  bulk decide path instead of per-event Python.  Loop and batched runs
  are interleaved rep by rep and each side takes its best, so the
  reported ``speedup_batched_vs_loop`` ratio is machine-portable where
  raw events/sec are not.

``--smoke`` is the CI configuration; the checked-in ``fleet_smoke``
section of ``BENCH_fleet.json`` holds the baseline ratios the regression
gate (benchmarks/check_regression.py) compares against.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import (OreoConfig, build_default_layout, layouts,
                        make_generator)
from repro.core import layout_manager as lm
from repro.core import workload as wl
from repro.core.workload import make_drift_scenario
from repro.engine import (Decision, FleetEngine, InMemoryBackend,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          TokenBucketScheduler, UnlimitedScheduler)

SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
             "flash_crowd", "template_churn"]


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int, seed: int = 0) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=seed, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data, build_default_layout(0, data, partitions),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


# ---------------------------------------------------------------------------
# Tenant sweep: fleet-plane throughput microbenchmark
# ---------------------------------------------------------------------------

def make_state_space(data: np.ndarray, num_states: int, partitions: int,
                     rng) -> List[layouts.Layout]:
    """S synthetic clustered layouts (same construction as
    bench_decision_loop): each sorts the table along a random projection and
    cuts it into equal partitions."""
    n = len(data)
    out = []
    for s in range(num_states):
        proj = data @ rng.normal(size=data.shape[1])
        assignment = np.empty(n, dtype=np.int64)
        assignment[np.argsort(proj, kind="stable")] = (
            np.arange(n) * partitions // n)
        meta = layouts.metadata_from_assignment(data, assignment, partitions)
        out.append(layouts.Layout(layout_id=s, name=f"synthetic-{s}",
                                  technique="synthetic", meta=meta))
    return out


class VectorScoringPolicy:
    """Minimal fixed-state decision layer: argmin over the per-slot cost
    vector, never reorganize.  Isolates fleet decision-plane throughput
    from switching/generation effects; identical decisions on the loop and
    batched paths because ``estimate_vector`` is bit-identical between
    them."""

    name = "VecScoring"
    alpha = 0.0

    def __init__(self, state_space: List[layouts.Layout]):
        self.state_space = state_space
        self.num = len(state_space)
        self.ids = [lay.layout_id for lay in state_space]
        self._ids_arr = np.asarray(self.ids, dtype=np.int64)
        # The engine consumes a Decision synchronously within the same
        # step, so a never-reorganizing policy can reuse one object.
        self._decision = Decision(state=self.ids[0])

    def bind(self, backend) -> int:
        for lay in self.state_space:
            backend.register(lay)
        return self.ids[0]

    def decide(self, index: int, query, backend) -> Decision:
        costs = backend.estimate_vector(query)
        dec = self._decision
        dec.state = self.ids[int(costs[:self.num].argmin())]
        return dec

    def decide_frames(self, costs: np.ndarray, backend):
        """Bulk form of :meth:`decide` (the BatchablePolicy contract):
        row-wise argmin over the candidate slots, never a reorg."""
        return self._ids_arr[costs[:, :self.num].argmin(axis=1)], None

    def info(self) -> dict:
        return {}


def selective_queries(col_lo: np.ndarray, col_hi: np.ndarray, n: int,
                      seed: int, selectivity: float = 0.1) -> List[wl.Query]:
    """Selective conjunctive range queries bounding *every* column — the
    regime where per-event column loops cost the loop path the most and
    the fused pass computes nothing it can skip."""
    rng = np.random.default_rng(seed)
    c = col_lo.shape[0]
    span = col_hi - col_lo
    width = span * selectivity
    out = []
    for _ in range(n):
        start = col_lo + rng.uniform(0, 1, c) * (span - width)
        out.append(wl.Query(lo=start, hi=start + width))
    return out


def bench_sweep_cell(num_tenants: int, rows: int, cols: int, num_states: int,
                     partitions: int, queries_per_tenant: int, reps: int,
                     seed: int) -> Dict:
    tenant_data = make_tenant_data(num_tenants, rows, cols, seed)
    tids = sorted(tenant_data)
    queries = {tid: selective_queries(tenant_data[tid].min(0),
                                      tenant_data[tid].max(0),
                                      queries_per_tenant, seed=seed + i)
               for i, tid in enumerate(tids)}
    events = []
    for k in range(queries_per_tenant):
        for tid in tids:
            events.append(wl.QueryEvent(tid, queries[tid][k]))

    def fresh_fleet() -> FleetEngine:
        return FleetEngine(
            {tid: LayoutEngine(
                VectorScoringPolicy(make_state_space(
                    tenant_data[tid], num_states, partitions,
                    np.random.default_rng(seed + 7 * i))),
                InMemoryBackend(tenant_data[tid]))
             for i, tid in enumerate(tids)},
            UnlimitedScheduler())

    # Interleave loop/batched reps so drift in machine load hits both
    # sides alike; each side keeps its best rep.
    best = {"loop": float("inf"), "batched": float("inf")}
    check = {}
    for _ in range(reps):
        for mode in ("loop", "batched"):
            fleet = fresh_fleet()
            t0 = time.perf_counter()
            res = (fleet.run(events) if mode == "loop"
                   else fleet.run_batched(events, compute="pallas_fused"))
            best[mode] = min(best[mode], time.perf_counter() - t0)
            check[mode] = res.total_cost
    assert check["loop"] == check["batched"], \
        f"loop/batched cost mismatch: {check}"
    loop_eps = len(events) / best["loop"]
    batched_eps = len(events) / best["batched"]
    return {
        "tenants": num_tenants, "S": num_states, "P": partitions,
        "C": cols, "events": len(events),
        "loop_events_per_sec": round(loop_eps, 1),
        "batched_events_per_sec": round(batched_eps, 1),
        "speedup": round(batched_eps / loop_eps, 2),
    }


# ---------------------------------------------------------------------------
# Scenario grid: OREO tenants under drift x schedulers
# ---------------------------------------------------------------------------

def bench_cell(scenario: str, scheduler_factory, tenant_data, col_lo, col_hi,
               queries_per_tenant: int, alpha: float, delta: int,
               partitions: int, seed: int) -> Dict:
    fs = make_drift_scenario(scenario, col_lo, col_hi,
                             num_tenants=len(tenant_data),
                             queries_per_tenant=queries_per_tenant, seed=seed)

    def fresh_fleet() -> FleetEngine:
        return FleetEngine(
            {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions)
             for tid in fs.tenant_ids},
            scheduler_factory())

    fleet = fresh_fleet()
    t0 = time.perf_counter()
    res = fleet.run(fs)
    loop_wall = time.perf_counter() - t0
    batched = fresh_fleet()
    t0 = time.perf_counter()
    bres = batched.run_batched(fs)
    batched_wall = time.perf_counter() - t0
    assert res.total_cost == bres.total_cost, \
        f"{scenario}: loop/batched cost mismatch"
    return {
        "scenario": scenario,
        "scheduler": res.scheduler,
        "tenants": len(fs.tenant_ids),
        "events": res.ticks,
        "total_cost": round(res.total_cost, 3),
        "query_cost": round(res.total_query_cost, 3),
        "reorg_cost": round(res.total_reorg_cost, 3),
        "reorgs": res.num_reorgs,
        "swaps_deferred": res.swaps_deferred,
        "deferred_ticks": res.deferred_ticks,
        "scheduler_stats": res.scheduler_stats,
        "events_per_sec": round(res.ticks / loop_wall, 1),
        "batched_events_per_sec": round(bres.ticks / batched_wall, 1),
        "batched_speedup": round(loop_wall / batched_wall, 2),
        "wall_seconds": round(loop_wall, 3),
        # engine-aggregated breakdown, straight off the per-tenant traces
        "decide_seconds": round(res.decide_seconds, 3),
        "reorg_seconds": round(res.reorg_seconds, 3),
        "serve_seconds": round(res.serve_seconds, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: all scenarios x 3 schedulers + sweep "
                         "to T=32, tiny")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 150
        alpha, delta, partitions = 4.0, 10, 8
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.005, capacity=1.0,
                                                    initial=0.0)),
        ]
        sweep_tenants = [4, 8, 16, 32]
        sweep_cfg = dict(rows=2_000, cols=10, num_states=8, partitions=8,
                         queries_per_tenant=150, reps=5, seed=100)
    else:
        tenants, rows, cols, qpt = 4, 20_000, 8, 1_500
        alpha, delta, partitions = 20.0, 10, 16
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.002,
                                                    capacity=2.0)),
        ]
        sweep_tenants = [4, 8, 16, 32, 64]
        sweep_cfg = dict(rows=4_000, cols=10, num_states=8, partitions=8,
                         queries_per_tenant=300, reps=5, seed=100)

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    for scenario in SCENARIOS:
        for label, factory in schedulers:
            row = bench_cell(scenario, factory, tenant_data, col_lo, col_hi,
                             qpt, alpha, delta, partitions, seed=7)
            results.append(row)
            print(f"{scenario:16s} x {label:10s} "
                  f"total={row['total_cost']:9.1f} "
                  f"(reorgs={row['reorgs']:3d}, "
                  f"deferred={row['swaps_deferred']:3d} swaps/"
                  f"{row['deferred_ticks']:4d} ticks) "
                  f"{row['events_per_sec']:7.0f} ev/s loop / "
                  f"{row['batched_events_per_sec']:7.0f} batched "
                  f"(x{row['batched_speedup']:.2f})", flush=True)

    sweep: List[Dict] = []
    speedups: Dict[str, Dict[str, float]] = {}
    for t in sweep_tenants:
        row = bench_sweep_cell(num_tenants=t, **sweep_cfg)
        sweep.append(row)
        speedups[f"T{t}"] = {"batched_vs_loop": row["speedup"]}
        print(f"sweep T={t:3d}: loop={row['loop_events_per_sec']:8.0f} ev/s "
              f"batched={row['batched_events_per_sec']:8.0f} ev/s "
              f"speedup x{row['speedup']:.2f}", flush=True)

    payload = {
        "benchmark": "fleet",
        "units": "combined query+reorg cost (fraction-of-table + alpha "
                 "per reorg); events/sec wall-clock",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "smoke": bool(args.smoke),
            "sweep": dict(sweep_cfg, tenants=sweep_tenants),
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "tenant_sweep": sweep,
        "speedup_batched_vs_loop": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
