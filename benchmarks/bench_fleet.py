"""Fleet benchmark: drift scenarios x reorg schedulers.

Runs a multi-tenant :class:`repro.engine.FleetEngine` — every tenant an
independent OREO-policy :class:`LayoutEngine` over its own table — through
each registered workload-drift scenario (``repro.core.workload.
DRIFT_SCENARIOS``: sudden shift, gradual drift, cyclic/diurnal, flash crowd,
template churn) under each reorganization scheduler, and reports the
combined query + reorg cost, swap deferrals, and the engine-aggregated
wall-clock breakdown (decide / reorg / serve seconds — no re-instrumentation
needed, the per-tenant ``RunResult`` carries them).

Writes ``BENCH_fleet.json``.  ``--smoke`` is the CI configuration: all five
scenarios x two schedulers at tiny sizes.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import (FleetEngine, InMemoryBackend, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, TokenBucketScheduler,
                          UnlimitedScheduler)

SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
             "flash_crowd", "template_churn"]


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int, seed: int = 0) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=seed, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data, build_default_layout(0, data, partitions),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


def bench_cell(scenario: str, scheduler_factory, tenant_data, col_lo, col_hi,
               queries_per_tenant: int, alpha: float, delta: int,
               partitions: int, seed: int) -> Dict:
    fs = make_drift_scenario(scenario, col_lo, col_hi,
                             num_tenants=len(tenant_data),
                             queries_per_tenant=queries_per_tenant, seed=seed)
    fleet = FleetEngine(
        {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions)
         for tid in fs.tenant_ids},
        scheduler_factory())
    t0 = time.perf_counter()
    res = fleet.run(fs)
    wall = time.perf_counter() - t0
    return {
        "scenario": scenario,
        "scheduler": res.scheduler,
        "tenants": len(fs.tenant_ids),
        "events": res.ticks,
        "total_cost": round(res.total_cost, 3),
        "query_cost": round(res.total_query_cost, 3),
        "reorg_cost": round(res.total_reorg_cost, 3),
        "reorgs": res.num_reorgs,
        "swaps_deferred": res.swaps_deferred,
        "deferred_ticks": res.deferred_ticks,
        "scheduler_stats": res.scheduler_stats,
        "events_per_sec": round(res.ticks / wall, 1),
        "wall_seconds": round(wall, 3),
        # engine-aggregated breakdown, straight off the per-tenant traces
        "decide_seconds": round(res.decide_seconds, 3),
        "reorg_seconds": round(res.reorg_seconds, 3),
        "serve_seconds": round(res.serve_seconds, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: all scenarios x 2 schedulers, tiny")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 150
        alpha, delta, partitions = 4.0, 10, 8
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.005, capacity=1.0,
                                                    initial=0.0)),
        ]
    else:
        tenants, rows, cols, qpt = 4, 20_000, 8, 1_500
        alpha, delta, partitions = 20.0, 10, 16
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.002,
                                                    capacity=2.0)),
        ]

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    for scenario in SCENARIOS:
        for label, factory in schedulers:
            row = bench_cell(scenario, factory, tenant_data, col_lo, col_hi,
                             qpt, alpha, delta, partitions, seed=7)
            results.append(row)
            print(f"{scenario:16s} x {label:10s} "
                  f"total={row['total_cost']:9.1f} "
                  f"(reorgs={row['reorgs']:3d}, "
                  f"deferred={row['swaps_deferred']:3d} swaps/"
                  f"{row['deferred_ticks']:4d} ticks) "
                  f"{row['events_per_sec']:8.0f} ev/s", flush=True)

    payload = {
        "benchmark": "fleet",
        "units": "combined query+reorg cost (fraction-of-table + alpha "
                 "per reorg); events/sec wall-clock",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "smoke": bool(args.smoke),
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
