"""Table II: transition-distribution gamma, sliding-window vs reservoir
candidate generation, and background-reorganization delay Delta.

Paper claims reproduced: gamma>0 cuts reorganization cost ~17-28% with flat
query cost; reservoir sampling raises query cost up to ~22% vs the sliding
window; Delta=alpha adds ~7-12% query cost with unchanged reorg cost.
"""
from __future__ import annotations

from typing import List

from benchmarks import common


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    total = common.TOTAL_QUERIES // (4 if quick else 2)
    datasets = ("tpch",) if quick else ("tpch", "tpcds", "telemetry")
    for ds in datasets:
        data, stream = common.build_bench(ds, total_queries=total)

        # gamma sweep (transition distribution; gamma=1 is the default row).
        for gamma in (0.0, 1.0, 2.0, 3.0):
            r = common.run_methods(data, stream, "qdtree", methods=("OREO",),
                                   gamma=gamma)["OREO"]
            rows.append(common.result_csv(f"table2.{ds}.gamma_{gamma}", r,
                                          len(stream)))

        # candidate-source ablation: SW vs RS vs SW+RS.
        for src in ("sw", "rs", "sw+rs"):
            r = common.run_methods(data, stream, "qdtree", methods=("OREO",),
                                   candidate_source=src)["OREO"]
            rows.append(common.result_csv(
                f"table2.{ds}.source_{src.replace('+', '_')}", r,
                len(stream)))

        # reorganization delay Delta (in queries; alpha=80 -> Delta=80 row).
        for delta in (0, 40, 80):
            r = common.run_methods(data, stream, "qdtree", methods=("OREO",),
                                   delta=delta)["OREO"]
            rows.append(common.result_csv(f"table2.{ds}.delta_{delta}", r,
                                          len(stream)))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
