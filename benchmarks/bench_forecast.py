"""Forecast benchmark: predictive vs reactive fleets over every scenario.

Runs the full scenario registry — the five drift scenarios
(``repro.core.workload.DRIFT_SCENARIOS``) and the five mixed read/write
ingest scenarios (``INGEST_SCENARIOS``, debt-aware compaction on in both
arms) — under each reorganization scheduler, twice per cell:

* **reactive** — the plain OREO fleet (identical construction to
  ``bench_fleet.py``): D-UMTS + LayoutManager, moving only once realized
  costs fill a counter;
* **forecast** — the same fleet with every tenant policy wrapped in
  :class:`repro.forecast.ForecastPolicy` at its default
  :class:`repro.forecast.ForecastConfig`: workload forecasting
  (period/trend), α-charged pre-positioning and online qd-tree growth.

The headline grid is ``forecast_vs_reactive``: combined query+reorg cost
of the reactive arm divided by the forecast arm (> 1 means forecasting
pays).  The registry's :data:`repro.core.workload.SCENARIO_INFO` marks
which scenarios carry a predictable signal (``forecastable``):
cyclic_diurnal and gradual_drift must *win* on aggregate, everything
else must stay within 5% of reactive — on the unpredictable scenarios
the forecaster goes silent and the trace is bitwise reactive, so those
ratios land at exactly 1.0.  A full (non ``--smoke``) run asserts this
acceptance envelope and refuses to write a payload that violates it.

``--smoke`` is the CI configuration; the checked-in ``forecast_smoke``
section of ``BENCH_forecast.json`` holds the baseline ratios the
regression gate (benchmarks/check_regression.py) compares against.  The
cost ratios are deterministic given the benchmark seeds, so any gate
trip is a behavioral regression, not machine noise.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import (SCENARIO_INFO, forecastable_scenarios,
                                 make_drift_scenario, make_ingest_scenario)
from repro.engine import (FleetEngine, InMemoryBackend, IngestConfig,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          TokenBucketScheduler, UnlimitedScheduler)
from repro.forecast import ForecastConfig, ForecastPolicy

DRIFT = ["sudden_shift", "gradual_drift", "cyclic_diurnal", "flash_crowd",
         "template_churn"]
INGEST = ["trickle", "append_heavy", "mixed_rw", "ingest_burst", "bulk_load"]


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int, forecast: bool, ingest: bool,
                  seed: int = 0) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=seed, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data, build_default_layout(0, data, partitions),
                        make_generator("qdtree"), cfg)
    if forecast:
        policy = ForecastPolicy(policy, config=ForecastConfig())
    return LayoutEngine(
        policy, InMemoryBackend(data), delta=cfg.delta,
        ingest=IngestConfig(debt_threshold=1.0) if ingest else None)


def bench_cell(scenario: str, scheduler_factory, tenant_data, col_lo,
               col_hi, queries_per_tenant: int, alpha: float, delta: int,
               partitions: int, seed: int) -> Dict:
    family = SCENARIO_INFO[scenario].family
    maker = make_drift_scenario if family == "drift" else make_ingest_scenario
    fs = maker(scenario, col_lo, col_hi, num_tenants=len(tenant_data),
               queries_per_tenant=queries_per_tenant, seed=seed)

    def run(forecast: bool):
        fleet = FleetEngine(
            {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions,
                                forecast=forecast, ingest=family == "ingest")
             for tid in fs.tenant_ids},
            scheduler_factory())
        t0 = time.perf_counter()
        res = fleet.run(fs)
        return res, time.perf_counter() - t0

    reactive, r_wall = run(forecast=False)
    forecasted, f_wall = run(forecast=True)
    infos = [forecasted.per_tenant[tid].info for tid in fs.tenant_ids]
    checks = sum(i["forecast_checks"] for i in infos)
    hits = sum(i["forecast_hits"] for i in infos)
    return {
        "scenario": scenario,
        "family": family,
        "forecastable": SCENARIO_INFO[scenario].forecastable,
        "scheduler": reactive.scheduler,
        "tenants": len(fs.tenant_ids),
        "reactive_total": round(reactive.total_cost, 3),
        "forecast_total": round(forecasted.total_cost, 3),
        "cost_ratio": round(reactive.total_cost / forecasted.total_cost, 6),
        "reactive_reorgs": reactive.num_reorgs,
        "forecast_reorgs": forecasted.num_reorgs,
        "prepositions": sum(i["prepositions"] for i in infos),
        "grown_admitted": sum(i["grown_admitted"] for i in infos),
        "forecasts": sum(i["forecasts"] for i in infos),
        "forecast_accuracy": round(hits / checks, 3) if checks else None,
        "wall_seconds": round(r_wall + f_wall, 3),
    }


def check_acceptance(ratios: Dict[str, Dict[str, float]],
                     aggregate: Dict[str, float]) -> List[str]:
    """The PR's acceptance envelope, evaluated on a full-size run."""
    failures = []
    for scenario in forecastable_scenarios():
        if aggregate[scenario] <= 1.0:
            failures.append(
                f"{scenario}: aggregate forecast-vs-reactive ratio "
                f"{aggregate[scenario]:.4f} <= 1.0 (forecasting must pay "
                f"on forecastable scenarios)")
    for scenario, row in ratios.items():
        for sched, ratio in row.items():
            if ratio < 0.95:
                failures.append(
                    f"{scenario} x {sched}: ratio {ratio:.4f} < 0.95 "
                    f"(the α-safety clamp must bound the damage)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: all 10 scenarios x 3 schedulers, tiny")
    ap.add_argument("--out", default="BENCH_forecast.json")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 150
        alpha, delta, partitions = 4.0, 10, 8
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.005, capacity=1.0,
                                                    initial=0.0)),
        ]
    else:
        tenants, rows, cols, qpt = 4, 20_000, 8, 1_500
        alpha, delta, partitions = 20.0, 10, 16
        schedulers = [
            ("unlimited", UnlimitedScheduler),
            ("k1", lambda: KConcurrentScheduler(1)),
            ("bucket", lambda: TokenBucketScheduler(rate=0.002,
                                                    capacity=2.0)),
        ]

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    ratios: Dict[str, Dict[str, float]] = {}
    totals: Dict[str, List[float]] = {}
    for scenario in DRIFT + INGEST:
        for label, factory in schedulers:
            row = bench_cell(scenario, factory, tenant_data, col_lo, col_hi,
                             qpt, alpha, delta, partitions, seed=7)
            results.append(row)
            ratios.setdefault(scenario, {})[label] = row["cost_ratio"]
            agg = totals.setdefault(scenario, [0.0, 0.0])
            agg[0] += row["reactive_total"]
            agg[1] += row["forecast_total"]
            acc = row["forecast_accuracy"]
            print(f"{scenario:16s} x {label:10s} "
                  f"ratio={row['cost_ratio']:.4f} "
                  f"(pre={row['prepositions']:3d}, "
                  f"grown={row['grown_admitted']:2d}, "
                  f"acc={'-' if acc is None else f'{acc:.2f}'}) "
                  f"{row['wall_seconds']:7.1f}s", flush=True)

    aggregate = {s: round(r / f, 6) for s, (r, f) in totals.items()}
    for scenario in DRIFT + INGEST:
        tag = "forecastable" if SCENARIO_INFO[scenario].forecastable else " "
        print(f"aggregate {scenario:16s} {aggregate[scenario]:.4f} {tag}")

    failures = check_acceptance(ratios, aggregate)
    if args.smoke:
        # smoke sizes undershoot the period detector's history needs
        # (α=4 also makes every mistake cheap), so the envelope is only
        # asserted at full size; smoke ratios are regression-gate
        # baselines, compared against themselves.
        failures = []
    if failures:
        for msg in failures:
            print(f"ACCEPTANCE FAILURE: {msg}")
        raise SystemExit(1)

    payload = {
        "benchmark": "forecast",
        "units": "combined query+reorg cost (fraction-of-table + alpha per "
                 "reorg); ratio = reactive/forecast, > 1 means the "
                 "predictive plane wins",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "smoke": bool(args.smoke),
            "forecast": dataclass_dict(ForecastConfig()),
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "forecast_vs_reactive": ratios,
        "scenario_aggregate_ratio": aggregate,
        "forecastable_scenarios": forecastable_scenarios(),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


def dataclass_dict(cfg: ForecastConfig) -> Dict:
    import dataclasses
    return dataclasses.asdict(cfg)


if __name__ == "__main__":
    main()
