"""Fig. 4: OREO vs MTS-Optimal (fixed precomputed state space) and
Offline-Optimal (full workload knowledge, switches at template boundaries).

Paper claims: OREO's query cost within ~14-17% of MTS-Optimal; 44-74% above
Offline-Optimal; comparable number of layout changes.
"""
from __future__ import annotations

from typing import List

from benchmarks import common


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    total = common.TOTAL_QUERIES // (4 if quick else 1)
    for ds in ("tpch", "tpcds"):
        data, stream = common.build_bench(ds, total_queries=total)
        res = common.run_methods(
            data, stream, "qdtree",
            methods=("OREO", "MTS Optimal", "Offline Optimal"))
        for method, r in res.items():
            rows.append(common.result_csv(
                f"fig4.{ds}.{method.replace(' ', '_')}", r, len(stream)))
        gap_mts = 100.0 * (res["OREO"].total_query_cost
                           / res["MTS Optimal"].total_query_cost - 1.0)
        gap_off = 100.0 * (res["OREO"].total_query_cost
                           / res["Offline Optimal"].total_query_cost - 1.0)
        rows.append(common.csv_row(f"fig4.{ds}.query_gap_vs_mts_opt_pct",
                                   0.0, f"value={gap_mts:.1f}"))
        rows.append(common.csv_row(f"fig4.{ds}.query_gap_vs_offline_pct",
                                   0.0, f"value={gap_off:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
