"""Fig. 3: total query + reorganization cost, OREO vs Static/Greedy/Regret,
on three datasets x two layout techniques (Qd-tree, Z-order).

Paper claims reproduced here: OREO beats the static optimized layout by up to
~32% (Qd-tree), sits between Greedy (min query cost, huge reorg cost) and
Regret (conservative), and stays dynamic under Z-order where Greedy/Regret
stop moving.
"""
from __future__ import annotations

from typing import List

from benchmarks import common


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    datasets = ("tpch", "tpcds", "telemetry")
    techniques = ("qdtree", "zorder")
    total = common.TOTAL_QUERIES // (4 if quick else 1)
    summary = {}
    for ds in datasets:
        data, stream = common.build_bench(ds, total_queries=total)
        for tech in techniques:
            res = common.run_methods(data, stream, tech)
            for method, r in res.items():
                rows.append(common.result_csv(
                    f"fig3.{ds}.{tech}.{method.replace(' ', '_')}", r,
                    len(stream)))
            static = res["Static"].total_cost
            oreo = res["OREO"].total_cost
            summary[(ds, tech)] = 100.0 * (static - oreo) / static
    for (ds, tech), imp in summary.items():
        rows.append(common.csv_row(
            f"fig3.{ds}.{tech}.improvement_vs_static_pct", 0.0,
            f"value={imp:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
