"""Fig. 6: effect of the layout-admission distance threshold epsilon.

Paper claims: larger epsilon shrinks the dynamic state space and slightly
raises query cost; overall performance is not very sensitive to epsilon.
"""
from __future__ import annotations

from typing import List

from benchmarks import common
from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core.layout_manager import LayoutManagerConfig
from repro.engine import InMemoryBackend, LayoutEngine, OreoPolicy

EPSILONS = (0.02, 0.05, 0.08, 0.15, 0.30)


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    total = common.TOTAL_QUERIES // (4 if quick else 1)
    data, stream = common.build_bench("tpch", total_queries=total)
    gen = make_generator("qdtree")
    for eps in EPSILONS:
        cfg = OreoConfig(alpha=common.ALPHA, gamma=1.0,
                         manager=LayoutManagerConfig(
                             target_partitions=common.PARTITIONS,
                             epsilon=eps))
        policy = OreoPolicy(data, build_default_layout(
            0, data, common.PARTITIONS), gen, cfg)
        res = LayoutEngine(policy, InMemoryBackend(data),
                           delta=cfg.delta).run(stream)
        rows.append(common.csv_row(
            f"fig6.epsilon_{eps}", 0.0,
            f"total={res.total_cost:.1f};query={res.total_query_cost:.1f};"
            f"reorg={res.total_reorg_cost:.1f};"
            f"admitted={res.info['candidates_admitted']};"
            f"max_states={res.info['max_state_space']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
