"""Kernel micro-benchmarks + analytic rooflines (CPU timings are for the
jnp paths; the Pallas kernels' TPU roofline terms are derived analytically
from block shapes -- see EXPERIMENTS.md §Roofline for the hardware model).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.pruning import ref as prune_ref
from repro.kernels.zorder import ref as z_ref

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s


def _time(f, *args, iters=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    # Pruning matrix: Q x P x C interval-overlap (paper's eval_skipped).
    Q, P, C = (2048, 512, 32) if not quick else (512, 128, 16)
    rng = np.random.default_rng(0)
    q_lo = jnp.asarray(rng.uniform(0, 1, (Q, C)), jnp.float32)
    q_hi = q_lo + 0.2
    p_min = jnp.asarray(rng.uniform(0, 1, (P, C)), jnp.float32)
    p_max = p_min + 0.2
    f = jax.jit(prune_ref.scan_matrix)
    s = _time(f, q_lo, q_hi, p_min, p_max)
    flops = 4.0 * Q * P * C                   # 2 cmp + 1 and + reduce
    bytes_ = 4.0 * (Q * C * 2 + P * C * 2 + Q * P)
    ai = flops / bytes_
    tpu_bound_us = max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6
    rows.append(common.csv_row(
        f"kernel.pruning.{Q}x{P}x{C}", s * 1e6,
        f"flops={flops:.2e};bytes={bytes_:.2e};arith_intensity={ai:.2f};"
        f"tpu_roofline_us={tpu_bound_us:.1f};bound=memory"))

    # Z-order keys.
    N, m, bits = (1_000_000, 3, 10) if not quick else (100_000, 3, 10)
    vals = jnp.asarray(rng.uniform(0, 1, (N, m)), jnp.float32)
    lo = vals.min(0)
    hi = vals.max(0)
    f = jax.jit(lambda v: z_ref.zorder_keys(v, lo, hi, bits))
    s = _time(f, vals)
    bytes_ = 4.0 * N * m + 4.0 * N
    ops = float(N * m * bits * 3)
    rows.append(common.csv_row(
        f"kernel.zorder.{N}x{m}", s * 1e6,
        f"int_ops={ops:.2e};bytes={bytes_:.2e};"
        f"tpu_roofline_us={bytes_ / HBM_BW * 1e6:.1f};bound=memory"))

    # Flash attention jnp path (CPU) + analytic TPU roofline.
    B, H, T, dh = (1, 8, 1024, 64) if quick else (2, 8, 2048, 64)
    from repro.models import layers as L
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, dh), jnp.float32)
    f = jax.jit(lambda a, b, c: L.flash_attention(a, b, c, causal=True))
    s = _time(f, q, k, v, iters=3)
    flops = 4.0 * B * H * T * T * dh / 2      # causal halves the work
    bytes_ = 2.0 * (3 * B * T * H * dh + B * T * H * dh)
    rows.append(common.csv_row(
        f"kernel.flash_attention.{B}x{H}x{T}x{dh}", s * 1e6,
        f"flops={flops:.2e};bytes={bytes_:.2e};"
        f"tpu_roofline_us={max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6:.1f};"
        f"bound=compute"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
