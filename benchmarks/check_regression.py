"""Benchmark-regression gate: fail CI when a protected speedup slips.

Compares a fresh smoke run against a checked-in baseline for both
benchmark families:

* ``bench_decision_loop.py --smoke`` vs ``BENCH_decision_loop.json`` —
  the StateMatrix (and batched-run) throughput divided by the reference
  re-padding path (section ``speedup_vs_reference``);
* ``bench_fleet.py --smoke`` vs ``BENCH_fleet.json`` — the fleet
  ``run_batched`` throughput divided by the stepwise loop on the tenant
  sweep (section ``speedup_batched_vs_loop``);
* ``bench_reorg.py --smoke`` vs ``BENCH_reorg.json`` — the combined
  query+reorg cost of atomic-deferred migration divided by incremental
  migration under the same maintenance budget (section
  ``cost_ratio_atomic_over_incremental``; ratio > 1 means the
  incremental plane is paying off);
* ``bench_ingest.py --smoke`` vs ``BENCH_ingest.json`` — the combined
  query+reorg cost of the never-recluster and always-recluster arms
  divided by the clustering-debt-aware arm over the ingest scenarios
  (section ``cost_ratio_vs_debt_aware``; ratio > 1 means the debt-aware
  compaction policy is paying off);
* ``bench_kernels.py --smoke`` vs ``BENCH_kernels.json`` — the wall time
  of the pre-megakernel separate passes (per-frame ``fleet_scan``
  launches + reduction + per-tenant ``move_score``) divided by the fused
  decision pass on identical operands (section ``fused_vs_separate``;
  ratio > 1 means the fused dataflow is paying off);
* ``bench_serving.py --smoke`` vs ``BENCH_serving.json`` — the serving
  front end's sustained QPS divided by the direct engine loop on the
  same stream (section ``serving_qps_ratio``, floor-gated: overhead
  creep in the serving tier drags it down), and its p99/p50 latency
  tail amplification (section ``latency_tail``, **ceiling-gated**: a
  stall on a fraction of events inflates the tail while barely moving
  the QPS ratio);
* ``bench_router.py --smoke`` vs ``BENCH_router.json`` — the sharded
  router's critical-path throughput (total events over the slowest
  shard's individually-timed drain) at N shards divided by the 1-shard
  router (section ``router_scaling``; routing overhead creep or a
  placement bug collapsing tenants onto one shard drags it down);
* ``bench_forecast.py --smoke`` vs ``BENCH_forecast.json`` — the
  combined query+reorg cost of the reactive OREO fleet divided by the
  forecast-wrapped fleet over every drift and ingest scenario (section
  ``forecast_vs_reactive``; ratio > 1 means the predictive plane is
  paying off, and a drop means either the forecasters stopped firing
  where they should or the α-safety clamp stopped containing the
  damage where they shouldn't).

Raw queries/sec are not comparable across machines, so the gate checks
**ratios**, both sides measured in the same process on the same runner:
a slowdown isolated to the optimized path drags a speedup ratio down
wherever it runs, and the reorg cost ratios are deterministic given the
benchmark seeds, so any drop is a behavioral regression rather than
machine noise.

Fails (exit 1) if, for any config x mode present in both files, the
fresh floor-section ratio falls below ``(1 - tolerance)`` of the
baseline, or a ceiling-section ratio rises above ``(1 + tolerance)``
of the baseline.
Baselines prefer a dedicated smoke section (``smoke_baseline`` /
``fleet_smoke``: same smoke configuration, minimum over several runs on
the reference machine); top-level sections from the full sweep fill in
any keys the smoke section does not cover.

Usage:
    python benchmarks/check_regression.py \\
        --fresh .bench/bench_decision_loop_smoke.json \\
        --baseline BENCH_decision_loop.json [--tolerance 0.30]
    python benchmarks/check_regression.py \\
        --fresh .bench/bench_fleet_smoke.json \\
        --baseline BENCH_fleet.json [--tolerance 0.30]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: Floor-gated sections holding {config_key: {mode: ratio}} grids, per
#: family: bigger is better, the gate fails when a ratio drops.
SECTIONS = ("speedup_vs_reference", "speedup_batched_vs_loop",
            "cost_ratio_atomic_over_incremental",
            "cost_ratio_vs_debt_aware", "fused_vs_separate",
            "serving_qps_ratio", "router_scaling",
            "forecast_vs_reactive")
#: Ceiling-gated sections: smaller is better (latency tails), the gate
#: fails when a ratio rises above (1 + tolerance) * baseline.
CEILING_SECTIONS = ("latency_tail",)
#: Dedicated smoke-baseline sections a checked-in file may carry; their
#: grids win over the top-level (full-sweep) numbers for shared keys.
SMOKE_SECTIONS = ("smoke_baseline", "fleet_smoke", "reorg_smoke",
                  "ingest_smoke", "kernels_smoke", "serving_smoke",
                  "router_smoke", "forecast_smoke")


def load_grids(payload: dict, sections, prefer_smoke: bool) -> dict:
    """{config_key: {mode: ratio}} merged over ``sections``."""
    out = {}
    for section in sections:
        out.update(payload.get(section, {}))
    if prefer_smoke:
        for smoke_name in SMOKE_SECTIONS:
            smoke = payload.get(smoke_name, {})
            for section in sections:
                out.update(smoke.get(section, {}))     # smoke wins
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="JSON written by bench_decision_loop.py --smoke "
                         "or bench_fleet.py --smoke")
    ap.add_argument("--baseline", required=True,
                    help="checked-in BENCH_decision_loop.json or "
                         "BENCH_fleet.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE",
                                                 "0.30")),
                    help="allowed fractional slowdown (default 0.30)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh_payload = json.load(f)
    with open(args.baseline) as f:
        base_payload = json.load(f)
    fresh = load_grids(fresh_payload, SECTIONS, prefer_smoke=False)
    base = load_grids(base_payload, SECTIONS, prefer_smoke=True)
    fresh_ceil = load_grids(fresh_payload, CEILING_SECTIONS,
                            prefer_smoke=False)
    base_ceil = load_grids(base_payload, CEILING_SECTIONS,
                           prefer_smoke=True)

    shared = sorted(set(fresh) & set(base))
    shared_ceil = sorted(set(fresh_ceil) & set(base_ceil))
    if not shared and not shared_ceil:
        print(f"regression gate: no overlapping configs between "
              f"{args.fresh} ({sorted(fresh) + sorted(fresh_ceil)}) and "
              f"{args.baseline} ({sorted(base) + sorted(base_ceil)})",
              file=sys.stderr)
        return 1

    failed = False
    for key in shared:
        for mode in sorted(set(fresh[key]) & set(base[key])):
            got, want = fresh[key][mode], base[key][mode]
            floor = (1.0 - args.tolerance) * want
            verdict = "ok" if got >= floor else "REGRESSION"
            print(f"  {key}/{mode}: speedup x{got:.2f} "
                  f"(baseline x{want:.2f}, floor x{floor:.2f}) {verdict}")
            if got < floor:
                failed = True
    for key in shared_ceil:
        for mode in sorted(set(fresh_ceil[key]) & set(base_ceil[key])):
            got, want = fresh_ceil[key][mode], base_ceil[key][mode]
            ceiling = (1.0 + args.tolerance) * want
            verdict = "ok" if got <= ceiling else "REGRESSION"
            print(f"  {key}/{mode}: ratio x{got:.2f} "
                  f"(baseline x{want:.2f}, ceiling x{ceiling:.2f}) "
                  f"{verdict}")
            if got > ceiling:
                failed = True
    if failed:
        print(f"regression gate FAILED: a gated ratio moved more "
              f"than {args.tolerance:.0%} past the checked-in baseline "
              f"({args.baseline})", file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
