"""Benchmark-regression gate: fail CI when the decision-loop speedup slips.

Compares a fresh ``bench_decision_loop.py --smoke`` run against the
checked-in ``BENCH_decision_loop.json`` baseline.  Raw queries/sec are not
comparable across machines, so the gate checks **speedup ratios** — the
StateMatrix (and batched-run) throughput divided by the reference
re-padding path, both measured in the same process on the same runner.
That ratio is what PR 2 bought and what this gate protects: a slowdown
isolated to the optimized path drags the ratio down wherever it runs.

Fails (exit 1) if, for any config x mode present in both files, the fresh
speedup falls below ``(1 - tolerance)`` of the baseline speedup.  The
baseline's ``smoke_baseline`` section (recorded with the same smoke
configuration, minimum of several runs) is preferred; configs from the
full-sweep ``speedup_vs_reference`` section are used as a fallback for any
key the smoke baseline does not cover.

Usage:
    python benchmarks/check_regression.py \\
        --fresh .bench/bench_decision_loop_smoke.json \\
        --baseline BENCH_decision_loop.json [--tolerance 0.30]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_speedups(payload: dict, prefer_smoke: bool) -> dict:
    """{config_key: {mode: speedup}} from a bench_decision_loop payload."""
    out = {}
    if not prefer_smoke:
        out.update(payload.get("speedup_vs_reference", {}))
    else:
        smoke = payload.get("smoke_baseline", {})
        out.update(payload.get("speedup_vs_reference", {}))
        out.update(smoke.get("speedup_vs_reference", {}))   # smoke wins
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="JSON written by bench_decision_loop.py --smoke")
    ap.add_argument("--baseline", required=True,
                    help="checked-in BENCH_decision_loop.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE",
                                                 "0.30")),
                    help="allowed fractional slowdown (default 0.30)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = load_speedups(json.load(f), prefer_smoke=False)
    with open(args.baseline) as f:
        base = load_speedups(json.load(f), prefer_smoke=True)

    shared = sorted(set(fresh) & set(base))
    if not shared:
        print(f"regression gate: no overlapping configs between "
              f"{args.fresh} ({sorted(fresh)}) and "
              f"{args.baseline} ({sorted(base)})", file=sys.stderr)
        return 1

    failed = False
    for key in shared:
        for mode in sorted(set(fresh[key]) & set(base[key])):
            got, want = fresh[key][mode], base[key][mode]
            floor = (1.0 - args.tolerance) * want
            verdict = "ok" if got >= floor else "REGRESSION"
            print(f"  {key}/{mode}: speedup x{got:.2f} "
                  f"(baseline x{want:.2f}, floor x{floor:.2f}) {verdict}")
            if got < floor:
                failed = True
    if failed:
        print(f"regression gate FAILED: speedup vs reference dropped more "
              f"than {args.tolerance:.0%} below the checked-in baseline "
              f"({args.baseline})", file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
