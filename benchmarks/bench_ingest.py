"""Ingest benchmark: clustering-debt-aware compaction vs the two naive arms.

The benchmark axis the streaming ingest plane opens
(:mod:`repro.engine.ingest`): for every registered ingest scenario
(:data:`repro.core.workload.INGEST_SCENARIOS`), a multi-tenant fleet of
OREO tenants runs the same interleaved read/write event stream three
times, differing only in the compaction policy:

* **never**  — ``IngestConfig(auto_compact=False)``: appended rows stay
  unclustered delta partitions forever; every overlapping scan keeps
  paying for them;
* **always** — ``IngestConfig(debt_threshold=0.0)``: recluster eagerly
  at the first scan after every append, paying the full α charge per
  compaction no matter how little debt the deltas have accrued;
* **debt**   — ``IngestConfig(debt_threshold=1.0)`` (the default):
  compact only once the *realized* excess scan cost over a
  hypothetically-compacted table has itself reached α — the same
  pay-for-itself discipline D-UMTS applies to drift reorganizations.

All three arms see identical events (queries AND appended batches) and
identical drift-reorg decisions up to the extra compaction charges; the
combined query+reorg cost difference isolates the compaction policy.
Costs are deterministic given the seeds, which is what lets
``check_regression.py`` gate on the ``cost_ratio_vs_debt_aware`` grid
(ratio > 1: the debt-aware arm wins).

``--smoke`` is the CI configuration; the checked-in ``ingest_smoke``
section of ``BENCH_ingest.json`` holds the baseline ratios the
regression gate compares against.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, List

import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import INGEST_SCENARIOS, make_ingest_scenario
from repro.engine import (FleetEngine, InMemoryBackend, IngestConfig,
                          LayoutEngine, OreoPolicy, UnlimitedScheduler)

SCENARIOS = sorted(INGEST_SCENARIOS)

ARMS = {
    "never": IngestConfig(auto_compact=False),
    "always": IngestConfig(debt_threshold=0.0),
    "debt": IngestConfig(debt_threshold=1.0),
}


def make_tenant_data(num_tenants: int, rows: int, cols: int,
                     seed: int) -> Dict[str, np.ndarray]:
    return {f"t{t}": np.random.default_rng(seed + t).uniform(
        0, 100, size=(rows, cols)) for t in range(num_tenants)}


def tenant_engine(data: np.ndarray, alpha: float, delta: int,
                  partitions: int, ingest: IngestConfig) -> LayoutEngine:
    cfg = OreoConfig(
        alpha=alpha, seed=0, delta=delta,
        manager=lm.LayoutManagerConfig(target_partitions=partitions,
                                       window_size=80, gen_every=40))
    policy = OreoPolicy(data,
                        build_default_layout(0, data, partitions, sort_col=0),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        ingest=ingest)


def fleet_ingest_stats(fleet: FleetEngine) -> Dict:
    appended = pending = compactions = 0
    debt = excess = 0.0
    for tid in fleet.tenant_ids:
        s = fleet.tenant(tid).ingest_stats()
        appended += s["ingested_rows"]
        pending += s["pending_rows"]
        compactions += len(s["compactions"])
        debt += s["clustering_debt"]
        excess += s["total_excess"]
    return {"rows_appended": appended, "rows_pending": pending,
            "compactions": compactions,
            "clustering_debt": round(debt, 3),
            "total_excess": round(excess, 3)}


def bench_cell(scenario: str, tenant_data, col_lo, col_hi,
               queries_per_tenant: int, alpha: float, delta: int,
               partitions: int, seed: int) -> Dict:
    fs = make_ingest_scenario(scenario, col_lo, col_hi,
                              num_tenants=len(tenant_data),
                              queries_per_tenant=queries_per_tenant,
                              seed=seed)
    row: Dict = {
        "scenario": scenario,
        "tenants": len(fs.tenant_ids),
        "events": len(fs),
        "queries_per_tenant": queries_per_tenant,
        "rows_appended": fs.total_appended_rows,
        "arms": {},
    }
    combined: Dict[str, float] = {}
    for arm, cfg in ARMS.items():
        fleet = FleetEngine(
            {tid: tenant_engine(tenant_data[tid], alpha, delta, partitions,
                                cfg)
             for tid in fs.tenant_ids}, UnlimitedScheduler())
        t0 = time.perf_counter()
        res = fleet.run(fs)
        wall = time.perf_counter() - t0
        stats = fleet_ingest_stats(fleet)
        combined[arm] = res.total_cost
        row["arms"][arm] = {
            "total_cost": round(res.total_cost, 3),
            "query_cost": round(res.total_query_cost, 3),
            "reorg_cost": round(res.total_reorg_cost, 3),
            "reorgs": res.num_reorgs,
            "events_per_sec": round(res.ticks / wall, 1),
            **stats,
        }
    # the never arm must end with every appended row still unclustered
    assert row["arms"]["never"]["compactions"] == 0
    row["cost_ratio_vs_debt_aware"] = {
        arm: round(combined[arm] / max(combined["debt"], 1e-12), 4)
        for arm in ("never", "always")}
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: all ingest scenarios, small fleet")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args()

    if args.smoke:
        tenants, rows, cols, qpt = 3, 2_000, 6, 200
        alpha, delta, partitions = 2.5, 5, 8
    else:
        tenants, rows, cols, qpt = 4, 8_000, 8, 1_000
        alpha, delta, partitions = 4.0, 10, 16

    tenant_data = make_tenant_data(tenants, rows, cols, seed=100)
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    results: List[Dict] = []
    ratios: Dict[str, Dict[str, float]] = {}
    wins = {"never": 0, "always": 0}
    for scenario in SCENARIOS:
        row = bench_cell(scenario, tenant_data, col_lo, col_hi, qpt,
                         alpha, delta, partitions, seed=7)
        results.append(row)
        ratios[scenario] = row["cost_ratio_vs_debt_aware"]
        for arm in wins:
            if ratios[scenario][arm] > 1.0:
                wins[arm] += 1
        arms = row["arms"]
        print(f"{scenario:14s} "
              f"never={arms['never']['total_cost']:9.1f} "
              f"always={arms['always']['total_cost']:9.1f} "
              f"debt={arms['debt']['total_cost']:9.1f} "
              f"ratios: never x{ratios[scenario]['never']:.3f} "
              f"always x{ratios[scenario]['always']:.3f} "
              f"(compactions={arms['debt']['compactions']})", flush=True)
    print(f"debt-aware beats never in {wins['never']}/{len(SCENARIOS)} "
          f"and always in {wins['always']}/{len(SCENARIOS)} scenarios")
    # the headline claim the ingest plane ships under: debt-aware wins
    # the combined cost in at least 4/5 scenarios against BOTH arms
    assert wins["never"] >= 4 and wins["always"] >= 4, \
        f"debt-aware arm lost its edge: {wins}"

    payload = {
        "benchmark": "ingest",
        "units": "combined query+reorg cost (fraction-of-table + alpha per "
                 "reorg/compaction); ratio > 1 means debt-aware wins",
        "config": {
            "tenants": tenants, "rows": rows, "columns": cols,
            "queries_per_tenant": qpt, "alpha": alpha, "delta": delta,
            "partitions": partitions, "smoke": bool(args.smoke),
            "platform": platform.platform(), "numpy": np.__version__,
        },
        "results": results,
        "wins_vs_debt_aware": {**wins, "scenarios": len(SCENARIOS)},
        "cost_ratio_vs_debt_aware": ratios,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
