PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench bench-smoke quickstart install

install:
	pip install -r requirements.txt

test:
	$(PYTHON) -m pytest -x -q

bench:
	$(PYTHON) benchmarks/run.py --quick

bench-smoke:
	$(PYTHON) benchmarks/bench_decision_loop.py --smoke --out /tmp/bench_decision_loop_smoke.json

quickstart:
	$(PYTHON) examples/quickstart.py
