PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench quickstart install

install:
	pip install -r requirements.txt

test:
	$(PYTHON) -m pytest -x -q

bench:
	$(PYTHON) benchmarks/run.py --quick

quickstart:
	$(PYTHON) examples/quickstart.py
