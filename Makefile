PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

# Benchmark output lands inside the workspace (gitignored) so CI can pick
# it up as an artifact and feed the regression gate on any runner.
BENCH_DIR ?= .bench

.PHONY: test test-kernels lint bench bench-full bench-smoke bench-gate \
        bench-fleet-smoke bench-fleet-gate bench-reorg-smoke \
        bench-reorg-gate bench-ingest-smoke bench-ingest-gate \
        bench-kernels-smoke bench-kernels-gate bench-serving-smoke \
        bench-serving-gate bench-router-smoke bench-router-gate \
        bench-forecast-smoke bench-forecast-gate quickstart install

install:
	pip install -r requirements.txt

test:
	$(PYTHON) -m pytest -x -q

# Pallas interpret-mode parity suite (pruning / zorder / flash_attention /
# fleet_scan kernels vs their jnp oracles) — its own CI job so kernel
# breakage is attributed distinctly from engine breakage.
test-kernels:
	$(PYTHON) -m pytest tests/test_kernels.py -q

lint:
	ruff check src tests benchmarks

bench:
	$(PYTHON) benchmarks/run.py --quick

# Full-size benchmark grids (nightly CI): decision loop sweep + fleet
# scenario x scheduler x tenant-sweep grid + reorg atomic-vs-incremental
# grid, JSON into $(BENCH_DIR).
bench-full:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_decision_loop.py --out $(BENCH_DIR)/BENCH_decision_loop.json
	$(PYTHON) benchmarks/bench_fleet.py --out $(BENCH_DIR)/BENCH_fleet.json
	$(PYTHON) benchmarks/bench_reorg.py --out $(BENCH_DIR)/BENCH_reorg.json
	$(PYTHON) benchmarks/bench_ingest.py --out $(BENCH_DIR)/BENCH_ingest.json
	$(PYTHON) benchmarks/bench_kernels.py --out $(BENCH_DIR)/BENCH_kernels.json
	$(PYTHON) benchmarks/bench_serving.py --out $(BENCH_DIR)/BENCH_serving.json
	$(PYTHON) benchmarks/bench_router.py --out $(BENCH_DIR)/BENCH_router.json
	$(PYTHON) benchmarks/bench_forecast.py --out $(BENCH_DIR)/BENCH_forecast.json

bench-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_decision_loop.py --smoke --out $(BENCH_DIR)/bench_decision_loop_smoke.json

bench-gate: bench-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_decision_loop_smoke.json --baseline BENCH_decision_loop.json

bench-fleet-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_fleet.py --smoke --out $(BENCH_DIR)/bench_fleet_smoke.json

bench-fleet-gate: bench-fleet-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_fleet_smoke.json --baseline BENCH_fleet.json

bench-reorg-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_reorg.py --smoke --out $(BENCH_DIR)/bench_reorg_smoke.json

bench-reorg-gate: bench-reorg-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_reorg_smoke.json --baseline BENCH_reorg.json

bench-ingest-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_ingest.py --smoke --out $(BENCH_DIR)/bench_ingest_smoke.json

bench-ingest-gate: bench-ingest-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_ingest_smoke.json --baseline BENCH_ingest.json

bench-kernels-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_kernels.py --smoke --out $(BENCH_DIR)/bench_kernels_smoke.json

bench-kernels-gate: bench-kernels-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_kernels_smoke.json --baseline BENCH_kernels.json

bench-serving-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_serving.py --smoke --out $(BENCH_DIR)/bench_serving_smoke.json

bench-serving-gate: bench-serving-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_serving_smoke.json --baseline BENCH_serving.json

bench-router-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_router.py --smoke --out $(BENCH_DIR)/bench_router_smoke.json

bench-router-gate: bench-router-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_router_smoke.json --baseline BENCH_router.json

bench-forecast-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_forecast.py --smoke --out $(BENCH_DIR)/bench_forecast_smoke.json

bench-forecast-gate: bench-forecast-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_forecast_smoke.json --baseline BENCH_forecast.json

quickstart:
	$(PYTHON) examples/quickstart.py
