PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

# Benchmark output lands inside the workspace (gitignored) so CI can pick
# it up as an artifact and feed the regression gate on any runner.
BENCH_DIR ?= .bench

.PHONY: test lint bench bench-smoke bench-gate bench-fleet-smoke quickstart install

install:
	pip install -r requirements.txt

test:
	$(PYTHON) -m pytest -x -q

lint:
	ruff check src tests benchmarks

bench:
	$(PYTHON) benchmarks/run.py --quick

bench-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_decision_loop.py --smoke --out $(BENCH_DIR)/bench_decision_loop_smoke.json

bench-gate: bench-smoke
	$(PYTHON) benchmarks/check_regression.py --fresh $(BENCH_DIR)/bench_decision_loop_smoke.json --baseline BENCH_decision_loop.json

bench-fleet-smoke:
	mkdir -p $(BENCH_DIR)
	$(PYTHON) benchmarks/bench_fleet.py --smoke --out $(BENCH_DIR)/BENCH_fleet.json

quickstart:
	$(PYTHON) examples/quickstart.py
