"""Shared pytest configuration: pinned hypothesis profiles.

Property tests (``tests/test_property.py`` and the hypothesis-driven
cases elsewhere) must not be able to flake the CI gate: the ``ci``
profile derandomizes example generation (every run draws the same
examples) and disables deadlines (shared runners stall unpredictably).
It is selected automatically when ``CI`` is set in the environment —
GitHub Actions always sets it — and can be forced locally with
``pytest --hypothesis-profile=ci`` (or ``dev`` to explore fresh random
examples, the local default).

Hypothesis itself stays optional, exactly like the tests that use it
(``pytest.importorskip``): without it this module is a no-op.
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:                                    # pragma: no cover
    pass
else:
    settings.register_profile(
        "ci",
        derandomize=True,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile("ci" if os.environ.get("CI") else "dev")
