"""StateMatrix metadata plane: packed-tensor maintenance, golden parity with
the reference evaluation paths, and the pluggable compute backends."""
import numpy as np
import pytest

from repro.core import layouts
from repro.core import workload as wl
from repro.engine import InMemoryBackend, StateMatrix


def make_meta(rng, p, c=6, n=3000):
    data = rng.uniform(0, 1, (n, c))
    order = np.argsort(data[:, int(rng.integers(c))], kind="stable")
    assignment = np.empty(n, dtype=np.int64)
    assignment[order] = np.arange(n) * p // n
    return layouts.metadata_from_assignment(data, assignment, p)


def make_query(rng, c=6):
    """Random conjunctive range query; unconstrained columns are [-inf, inf]
    exactly like the workload generator produces."""
    lo = np.full(c, -np.inf)
    hi = np.full(c, np.inf)
    k = int(rng.integers(0, c + 1))
    for col in rng.choice(c, size=k, replace=False):
        lo[col] = rng.uniform(0, 0.7)
        hi[col] = lo[col] + rng.uniform(0, 0.4)
    return lo, hi


@pytest.mark.parametrize("counts", [(16, 16, 16, 16),   # uniform: batched path
                                    (16, 7, 32, 5)])    # ragged: per-state path
def test_estimate_bit_identical_to_reference_paths(counts):
    rng = np.random.default_rng(0)
    metas = [make_meta(rng, p) for p in counts]
    sm = StateMatrix()
    for i, m in enumerate(metas):
        sm.register(i, m)
    for _ in range(30):
        lo, hi = make_query(rng)
        got = sm.estimate(lo, hi)
        ref = layouts.eval_cost_states(metas, lo, hi)
        singles = np.array([float(layouts.eval_cost(m, lo, hi))
                            for m in metas])
        assert np.array_equal(got, ref)          # bit-for-bit
        assert np.array_equal(got, singles)


def test_register_deregister_slot_swap_keeps_exact_metadata():
    rng = np.random.default_rng(1)
    metas = {i: make_meta(rng, int(rng.integers(4, 24))) for i in range(6)}
    sm = StateMatrix()
    for i, m in metas.items():
        sm.register(i, m)
    sm.deregister(2)        # middle slot: last slot swaps into the hole
    sm.deregister(5)
    sm.deregister(99)       # unknown id: no-op
    assert sorted(sm.state_ids) == [0, 1, 3, 4]
    assert len(sm) == 4 and 2 not in sm and 0 in sm
    for i in (0, 1, 3, 4):
        view = sm.metadata(i)
        assert np.array_equal(view.mins, metas[i].mins)
        assert np.array_equal(view.maxs, metas[i].maxs)
        assert np.array_equal(view.rows, metas[i].rows)
    lo, hi = make_query(rng)
    live = [metas[i] for i in sm.state_ids]
    assert np.array_equal(sm.estimate(lo, hi),
                          layouts.eval_cost_states(live, lo, hi))


def test_register_overwrite_and_partition_growth():
    rng = np.random.default_rng(2)
    sm = StateMatrix()
    small = make_meta(rng, 6)
    sm.register(0, small)
    assert sm.partition_capacity == 6
    big = make_meta(rng, 40)        # forces the plane to regrow P_cap
    sm.register(1, big)
    assert sm.partition_capacity == 40
    replacement = make_meta(rng, 12)
    sm.register(0, replacement)     # overwrite in place
    assert len(sm) == 2
    lo, hi = make_query(rng)
    assert np.array_equal(
        sm.estimate(lo, hi),
        layouts.eval_cost_states([replacement, big], lo, hi))


def test_estimate_costs_subset_and_empty():
    rng = np.random.default_rng(3)
    metas = [make_meta(rng, 8) for _ in range(3)]
    sm = StateMatrix()
    for i, m in enumerate(metas):
        sm.register(10 + i, m)
    lo, hi = make_query(rng)
    subset = sm.estimate_costs([11, 10], lo, hi)
    assert set(subset) == {10, 11}
    assert subset[10] == float(layouts.eval_cost(metas[0], lo, hi))
    assert sm.estimate_costs([], lo, hi) == {}
    assert StateMatrix().estimate(lo, hi).shape == (0,)
    with pytest.raises(KeyError):
        sm.estimate_costs([77], lo, hi)


def test_backend_registry_mirrors_matrix():
    """InMemoryBackend register/deregister keeps dict and plane in sync, and
    numpy estimates equal the reference backend's bit-for-bit."""
    rng = np.random.default_rng(4)
    data = rng.uniform(0, 1, (2000, 6))
    mem = InMemoryBackend(data)                         # StateMatrix plane
    ref = InMemoryBackend(data, compute="reference")    # legacy re-padding
    lays = [layouts.Layout(layout_id=i, name=f"l{i}", technique="synthetic",
                           meta=make_meta(rng, p))
            for i, p in enumerate((8, 8, 20))]
    for b in (mem, ref):
        for lay in lays:
            b.register(lay)
    for _ in range(20):
        lo, hi = make_query(rng)
        q = wl.Query(lo=lo, hi=hi)
        assert mem.estimate_costs([0, 1, 2], q) == ref.estimate_costs(
            [0, 1, 2], q)
    mem.deregister(1)
    assert sorted(mem.state_matrix.state_ids) == [0, 2]
    assert mem.states == [0, 2]


def test_pallas_compute_backend_parity():
    """The kernel-backed plane agrees with numpy on f32-representable data
    (the kernel evaluates in float32)."""
    rng = np.random.default_rng(5)
    c = 6
    data = rng.uniform(0, 1, (2000, c)).astype(np.float32).astype(np.float64)
    sm_np = StateMatrix()
    sm_pl = StateMatrix(compute_backend="pallas")
    for i in range(3):
        order = np.argsort(data[:, i % c], kind="stable")
        assignment = np.empty(len(data), dtype=np.int64)
        assignment[order] = np.arange(len(data)) * 16 // len(data)
        meta = layouts.metadata_from_assignment(data, assignment, 16)
        sm_np.register(i, meta)
        sm_pl.register(i, meta)
    for _ in range(5):
        lo, hi = make_query(rng, c)
        lo = lo.astype(np.float32).astype(np.float64)
        hi = hi.astype(np.float32).astype(np.float64)
        np.testing.assert_allclose(sm_pl.estimate(lo, hi),
                                   sm_np.estimate(lo, hi), atol=1e-12)


def test_pallas_backend_serve_stays_exact():
    """The serve-score fusion memo is numpy-only: under compute="pallas" a
    serve() after estimate_costs must still return the exact float64 cost,
    not the kernel's float32 estimate."""
    rng = np.random.default_rng(6)
    data = rng.uniform(0, 1, (2000, 4))
    backend = InMemoryBackend(data, compute="pallas")
    lay = layouts.Layout(layout_id=0, name="l0", technique="synthetic",
                         meta=make_meta(rng, 8, c=4))
    backend.register(lay)
    backend.activate(0)
    lo, hi = make_query(rng, c=4)
    q = wl.Query(lo=lo, hi=hi)
    before = backend.serve(q)
    backend.estimate_costs([0], q)
    after = backend.serve(q)
    want = float(layouts.eval_cost(lay.serving_meta(), lo, hi))
    assert before == after == want


def test_unknown_compute_backend_rejected():
    with pytest.raises(ValueError):
        StateMatrix(compute_backend="cuda")
    with pytest.raises(ValueError):
        InMemoryBackend(np.zeros((4, 2)), compute="nope")


# ---------------------------------------------------------------------------
# float32 downcast guard on the kernel compute backends
# ---------------------------------------------------------------------------

def test_float32_exact_predicate():
    from repro.engine import compute
    assert compute.float32_exact(np.array([0.5, 1.0, -np.inf, np.inf]))
    assert compute.float32_exact(np.ones(3, np.float32))
    # one ulp above 1.0 in float64 is strictly between float32 neighbours
    assert not compute.float32_exact(np.array([np.nextafter(1.0, 2.0)]))


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_scan_matrix_f32_downcast_warns_and_stays_exact(backend):
    """A bound that is not exactly float32-representable must not be
    silently downcast: the kernel path warns and returns the exact numpy
    answer (regression test for the silent-float32 scan_matrix bug)."""
    from repro.engine import compute
    rng = np.random.default_rng(8)
    P, C, Q = 10, 4, 6
    p_min = rng.uniform(0, 1, (P, C)).astype(np.float32).astype(np.float64)
    p_max = p_min + 0.25
    q_lo = np.zeros((Q, C))
    q_hi = np.ones((Q, C))
    # exactly unrepresentable: sits between p_max's float32 neighbours, so
    # the old downcast flipped overlap verdicts at the boundary
    q_hi[0, 0] = np.nextafter(1.0, 2.0)
    want = compute.scan_matrix(q_lo, q_hi, p_min, p_max, backend="numpy")
    with pytest.warns(RuntimeWarning, match="float32"):
        got = compute.scan_matrix(q_lo, q_hi, p_min, p_max, backend=backend)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", ["pallas", "pallas_fused"])
def test_fleet_scan_matrix_f32_downcast_warns_and_stays_exact(backend):
    from repro.engine import compute
    rng = np.random.default_rng(9)
    T, N, C = 3, 8, 4
    mins = rng.uniform(0, 1, (T, N, C)).astype(np.float32).astype(np.float64)
    maxs = mins + 0.25
    q_lo = np.zeros((T, C))
    q_hi = np.ones((T, C))
    mins[1, 3, 2] = np.nextafter(0.5, 1.0)      # not float32-exact
    want = compute.fleet_scan_matrix(q_lo, q_hi, mins, maxs,
                                     backend="numpy")
    with pytest.warns(RuntimeWarning, match="float32"):
        got = compute.fleet_scan_matrix(q_lo, q_hi, mins, maxs,
                                        backend=backend)
    np.testing.assert_array_equal(got, want)


def test_pallas_fused_compute_backend_parity():
    """StateMatrix estimates under the megakernel backend == numpy on
    f32-representable data (same contract as the ``pallas`` backend)."""
    rng = np.random.default_rng(10)
    c = 6
    data = rng.uniform(0, 1, (2000, c)).astype(np.float32).astype(np.float64)
    sm_np = StateMatrix()
    sm_fu = StateMatrix(compute_backend="pallas_fused")
    for i in range(3):
        order = np.argsort(data[:, i % c], kind="stable")
        assignment = np.empty(len(data), dtype=np.int64)
        assignment[order] = np.arange(len(data)) * 16 // len(data)
        meta = layouts.metadata_from_assignment(data, assignment, 16)
        sm_np.register(i, meta)
        sm_fu.register(i, meta)
    for _ in range(5):
        lo, hi = make_query(rng, c)
        lo = lo.astype(np.float32).astype(np.float64)
        hi = hi.astype(np.float32).astype(np.float64)
        np.testing.assert_allclose(sm_fu.estimate(lo, hi),
                                   sm_np.estimate(lo, hi), atol=1e-12)
