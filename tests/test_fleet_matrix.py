"""Tests for the packed multi-tenant FleetMatrix plane and the batched
fleet step path: incremental mirroring (tenant attach/detach, state
add/evict), bit-identical fused estimation, golden run_batched-vs-loop
traces across every drift scenario x scheduler, and primed-estimate
staleness handling."""
import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, layouts,
                        make_generator, workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import (Decision, FleetEngine, FleetMatrix,
                          InMemoryBackend, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, StateMatrix,
                          ThresholdSwitchPolicy, TokenBucketScheduler,
                          UnlimitedScheduler)


def make_meta(rng, partitions, columns, rows_per=50):
    data = rng.uniform(0, 100, size=(partitions * rows_per, columns))
    assignment = np.repeat(np.arange(partitions), rows_per)
    return layouts.metadata_from_assignment(data, assignment, partitions)


def make_query(rng, columns, bounded=None):
    lo = np.full(columns, -np.inf)
    hi = np.full(columns, np.inf)
    cols = (rng.choice(columns, size=bounded, replace=False)
            if bounded is not None else range(columns))
    for c in cols:
        a, b = np.sort(rng.uniform(0, 100, size=2))
        lo[c], hi[c] = a, b
    return lo, hi


# ---------------------------------------------------------------------------
# Incremental mirroring
# ---------------------------------------------------------------------------

def test_attach_syncs_existing_states_and_follows_events():
    rng = np.random.default_rng(0)
    sm = StateMatrix()
    sm.register(1, make_meta(rng, 4, 3))
    sm.register(2, make_meta(rng, 6, 3))
    fm = FleetMatrix()
    fm.attach("a", sm)
    assert fm.tenant_ids == ["a"]
    assert fm.state_ids("a") == sm.state_ids == [1, 2]
    # post-attach events stream through the listener
    sm.register(3, make_meta(rng, 2, 3))
    assert fm.state_ids("a") == sm.state_ids
    sm.deregister(1)        # swap-with-last in both planes
    assert fm.state_ids("a") == sm.state_ids
    assert all(fm.slot("a", sid) == sm.slot(sid) for sid in sm.state_ids)
    fm.detach("a")
    sm.register(4, make_meta(rng, 2, 3))     # no listener anymore
    assert "a" not in fm


def test_mirror_bounds_match_local_plane_exactly():
    rng = np.random.default_rng(1)
    sm = StateMatrix()
    fm = FleetMatrix()
    fm.attach("a", sm)
    for sid, p in [(5, 4), (7, 8), (9, 3)]:
        sm.register(sid, make_meta(rng, p, 2))
    sm.deregister(7)
    for sid in sm.state_ids:
        got = fm._mins[fm.tenant_row("a"), fm.slot("a", sid)]
        meta = sm.metadata(sid)
        np.testing.assert_array_equal(got[:meta.num_partitions],
                                      meta.mins)
        assert np.all(np.isinf(got[meta.num_partitions:]))


def test_detach_swaps_last_tenant_row_into_hole():
    rng = np.random.default_rng(2)
    sms = {}
    fm = FleetMatrix()
    for tid in ["a", "b", "c"]:
        sms[tid] = StateMatrix()
        sms[tid].register(0, make_meta(rng, 4, 2))
        fm.attach(tid, sms[tid])
    assert [fm.tenant_row(t) for t in ["a", "b", "c"]] == [0, 1, 2]
    fm.detach("a")
    assert len(fm) == 2 and fm.tenant_row("c") == 0
    # the moved tenant still scores correctly after the row swap
    lo, hi = make_query(rng, 2)
    frame = fm.estimate_frame([("c", lo, hi)])
    np.testing.assert_array_equal(frame[0][1], sms["c"].estimate(lo, hi))
    # detach is idempotent for unknown ids; double attach rejected
    fm.detach("zz")
    with pytest.raises(ValueError):
        fm.attach("b", sms["b"])
    fm.detach_all()
    assert len(fm) == 0


def test_capacity_growth_preserves_plane():
    rng = np.random.default_rng(3)
    fm = FleetMatrix(tenant_capacity=1, state_capacity=1)
    sms = {}
    for t in range(5):                      # tenant rows grow
        tid = f"t{t}"
        sms[tid] = StateMatrix()
        fm.attach(tid, sms[tid])
        for s in range(4):                  # slots grow
            sms[tid].register(s, make_meta(rng, 2 + 3 * s, 2))  # pcap grows
    for tid, sm in sms.items():
        lo, hi = make_query(rng, 2)
        frame = fm.estimate_frame([(tid, lo, hi)])
        version, costs = frame[0][0], frame[0][1]
        assert version == sm.version
        np.testing.assert_array_equal(costs, sm.estimate(lo, hi))


def test_column_count_mismatch_rejected():
    rng = np.random.default_rng(4)
    sm2 = StateMatrix()
    sm2.register(0, make_meta(rng, 4, 2))
    sm3 = StateMatrix()
    sm3.register(0, make_meta(rng, 4, 3))
    fm = FleetMatrix()
    fm.attach("a", sm2)
    with pytest.raises(ValueError):
        fm.attach("b", sm3)


# ---------------------------------------------------------------------------
# Fused estimation: bit-identical to every tenant's own plane
# ---------------------------------------------------------------------------

def test_estimate_frames_bit_identical_mixed_shapes():
    """Random tenants with mixed partition counts (uniform and ragged
    planes, so both the fused einsum and the per-tenant fallback paths
    run), random partially-bounded queries, several frames per pass."""
    rng = np.random.default_rng(5)
    columns = 4
    fm = FleetMatrix()
    sms = {}
    for t in range(6):
        tid = f"t{t}"
        sm = StateMatrix()
        parts = ([4] * 3 if t % 2 == 0          # uniform plane
                 else [3, 6, 2])                # ragged plane
        for sid, p in enumerate(parts):
            sm.register(sid, make_meta(rng, p, columns))
        sms[tid] = sm
        fm.attach(tid, sm)
    tids = sorted(sms)
    for trial in range(10):
        frames = []
        for _ in range(3):
            frame = []
            for tid in rng.permutation(tids)[:4]:
                bounded = int(rng.integers(0, columns + 1))
                lo, hi = make_query(rng, columns, bounded=bounded)
                frame.append((str(tid), lo, hi))
            frames.append(frame)
        out = fm.estimate_frames(frames)
        for frame, results in zip(frames, out):
            for (tid, lo, hi), res in zip(frame, results):
                assert res is not None
                version, costs = res[0], res[1]
                sm = sms[tid]
                assert version == sm.version
                want = sm.estimate(lo, hi)
                assert np.array_equal(costs, want)      # bitwise


def test_estimate_frame_unknown_or_empty_tenants_yield_none():
    rng = np.random.default_rng(6)
    fm = FleetMatrix()
    sm = StateMatrix()
    fm.attach("a", sm)                      # attached but no states yet
    lo, hi = make_query(rng, 3)
    assert fm.estimate_frame([("a", lo, hi), ("ghost", lo, hi)]) \
        == [None, None]
    sm.register(0, make_meta(rng, 4, 3))
    res = fm.estimate_frame([("a", lo, hi), ("ghost", lo, hi)])
    assert res[0] is not None and res[1] is None


def test_estimate_frame_serve_shadow_score_rides_along():
    rng = np.random.default_rng(7)
    data = rng.uniform(0, 100, size=(400, 3))
    backend = InMemoryBackend(data)
    lay = build_default_layout(0, data, 4)
    backend.register(lay)
    backend.activate(0)                     # registers SERVING_SHADOW (-1)
    fm = FleetMatrix()
    fm.attach("a", backend.state_matrix)
    lo, hi = make_query(rng, 3)
    version, costs, serve = fm.estimate_frame([("a", lo, hi)])[0]
    q = wl.Query(lo=lo, hi=hi)
    assert serve == backend.serve(q)        # exact shadow score
    slot = backend.state_matrix.slot(InMemoryBackend.SERVING_SHADOW)
    assert serve == float(costs[slot])


def test_pallas_fleet_compute_close_to_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    rng = np.random.default_rng(8)
    sm = StateMatrix()
    for sid in range(3):
        sm.register(sid, make_meta(rng, 4, 3))
    exact = FleetMatrix(compute_backend="numpy")
    kern = FleetMatrix(compute_backend="pallas")
    exact.attach("a", sm)
    kern.attach("a", sm)
    lo, hi = make_query(rng, 3, bounded=2)
    want = exact.estimate_frame([("a", lo, hi)])[0][1]
    got = kern.estimate_frame([("a", lo, hi)])[0][1]
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# Primed estimates: consumed only when still valid
# ---------------------------------------------------------------------------

def test_primed_estimates_fall_back_on_version_churn():
    rng = np.random.default_rng(9)
    data = rng.uniform(0, 100, size=(600, 3))
    backend = InMemoryBackend(data)
    for sid in range(3):
        backend.register(build_default_layout(sid, data, 4,
                                              sort_col=sid % 3))
    q = wl.Query(*make_query(rng, 3, bounded=2))
    m = backend.state_matrix
    exact = backend.estimate_costs(range(3), q)
    # valid prime: bogus costs ARE consumed (proves the fast path runs)
    backend.prime_estimates(q, m.version, np.full(len(m), 0.5))
    assert all(v == 0.5 for v in backend.estimate_costs(range(3),
                                                        q).values())
    # stale prime (version bumped by state churn): exact path again
    backend.prime_estimates(q, m.version, np.full(len(m), 0.25))
    backend.register(build_default_layout(7, data, 4))
    assert backend.estimate_costs(range(3), q) == exact
    # different query object: prime ignored
    q2 = wl.Query(lo=q.lo.copy(), hi=q.hi.copy())
    backend.prime_estimates(q, m.version, np.full(len(m), 0.25))
    assert backend.estimate_costs(range(3), q2) \
        == backend.estimate_costs(range(3), q2)


def test_estimate_vector_matches_estimate_costs_and_serves_exact():
    rng = np.random.default_rng(10)
    data = rng.uniform(0, 100, size=(500, 3))
    backend = InMemoryBackend(data)
    for sid in range(3):
        backend.register(build_default_layout(sid, data, 4,
                                              sort_col=sid % 3))
    backend.activate(0)
    q = wl.Query(*make_query(rng, 3, bounded=2))
    vec = backend.estimate_vector(q)
    by_id = backend.estimate_costs(range(3), q)
    m = backend.state_matrix
    assert all(vec[m.slot(s)] == by_id[s] for s in range(3))
    # the fused serve memo is bit-exact vs a cold serve
    memo_serve = backend.serve(q)
    backend._serve_memo = None
    assert backend.serve(q) == memo_serve


def test_step_fast_trace_identical_to_step():
    rng = np.random.default_rng(11)
    data = rng.uniform(0, 100, size=(800, 4))
    queries = [wl.Query(*make_query(rng, 4, bounded=2)) for _ in range(40)]

    def engine():
        gen = make_generator("qdtree")
        cfg = OreoConfig(alpha=5.0, seed=3, delta=2,
                         manager=lm.LayoutManagerConfig(
                             target_partitions=4, window_size=20,
                             gen_every=10))
        policy = OreoPolicy(data, build_default_layout(0, data, 4), gen,
                            cfg)
        return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)

    a, b = engine(), engine()
    for q in queries:
        a.step(q)
        b.step_fast(q)
    ra, rb = a.result(), b.result()
    assert np.array_equal(ra.query_costs, rb.query_costs)
    assert ra.reorg_indices == rb.reorg_indices
    assert np.array_equal(ra.state_seq, rb.state_seq)


# ---------------------------------------------------------------------------
# run_batched: golden identity with the stepwise loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(100 + t).uniform(
        0, 100, size=(3_000, 6)) for t in range(3)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, alpha=10.0, delta=5, seed=2):
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8), gen, cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


SCHEDULERS = [
    ("unlimited", UnlimitedScheduler),
    ("k1", lambda: KConcurrentScheduler(1)),
    ("bucket", lambda: TokenBucketScheduler(rate=0.01, capacity=1.0,
                                            initial=0.0)),
]

ALL_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                 "flash_crowd", "template_churn"]


@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_run_batched_bit_identical_to_loop(scenario, tenant_data, bounds):
    """The acceptance gate: batched traces == stepwise traces, bit for
    bit, for every scenario under every scheduler (state churn included,
    exercising the primed-estimate fallback)."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario(scenario, lo, hi, num_tenants=3,
                                 queries_per_tenant=120, seed=7)
        loop = FleetEngine({tid: oreo_engine(tenant_data[tid])
                            for tid in fs.tenant_ids}, factory())
        r_loop = loop.run(fs)
        batched = FleetEngine({tid: oreo_engine(tenant_data[tid])
                               for tid in fs.tenant_ids}, factory())
        r_batched = batched.run_batched(fs)
        assert batched.fleet_matrix is not None
        for tid in fs.tenant_ids:
            a, b = r_loop.per_tenant[tid], r_batched.per_tenant[tid]
            assert np.array_equal(a.query_costs, b.query_costs)
            assert a.reorg_indices == b.reorg_indices
            assert np.array_equal(a.state_seq, b.state_seq)
        assert r_loop.swaps_deferred == r_batched.swaps_deferred
        assert r_loop.deferred_ticks == r_batched.deferred_ticks
        assert r_loop.scheduler_stats.get("grants") \
            == r_batched.scheduler_stats.get("grants")


def test_run_batched_requires_matrix_backed_backends(tenant_data):
    data = tenant_data["t0"]
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=5.0, seed=1, delta=2)
    policy = OreoPolicy(data, build_default_layout(0, data, 8), gen, cfg)
    engine = LayoutEngine(policy, InMemoryBackend(data,
                                                  compute="reference"))
    fleet = FleetEngine({"t0": engine})
    with pytest.raises(ValueError, match="reference"):
        fleet.run_batched([])


def test_run_batched_resumable_and_mixed_with_step(tenant_data, bounds):
    """run_batched can be interleaved with plain step() calls; the plane
    stays attached and maintained across calls."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                             queries_per_tenant=90, seed=3)
    events = list(fs)
    ref = FleetEngine({tid: oreo_engine(tenant_data[tid])
                       for tid in fs.tenant_ids})
    r_ref = ref.run(events)
    mixed = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids})
    cut = len(events) // 3
    mixed.run_batched(events[:cut])
    version_before = mixed.fleet_matrix.version
    for tid, q in events[cut:2 * cut]:
        mixed.step(tid, q)
    # stepping outside run_batched still streams into the plane
    assert mixed.fleet_matrix.version >= version_before
    r_mixed = mixed.run_batched(events[2 * cut:])
    for tid in fs.tenant_ids:
        a, b = r_ref.per_tenant[tid], r_mixed.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs)
        assert np.array_equal(a.state_seq, b.state_seq)


# ---------------------------------------------------------------------------
# Dynamic tenant membership
# ---------------------------------------------------------------------------

class FlipFlopPolicy:
    name = "FlipFlop"

    def __init__(self, layouts_, period, alpha=1.0):
        self.layouts = list(layouts_)
        self.period = period
        self.alpha = alpha
        self.cur = 0

    def bind(self, backend):
        for lay in self.layouts:
            backend.register(lay)
        return self.layouts[0].layout_id

    def decide(self, index, query, backend):
        if (index + 1) % self.period == 0:
            self.cur = 1 - self.cur
            return Decision(state=self.layouts[self.cur].layout_id,
                            reorg=True)
        return Decision(state=self.layouts[self.cur].layout_id)

    def info(self):
        return {}


def flipflop_engine(data, period=5, delta=2):
    lays = [build_default_layout(0, data, 8, sort_col=0),
            build_default_layout(1, data, 8, sort_col=1)]
    return LayoutEngine(FlipFlopPolicy(lays, period), InMemoryBackend(data),
                        delta=delta)


def full_scan(columns):
    return wl.Query(lo=np.full(columns, -np.inf),
                    hi=np.full(columns, np.inf))


def test_run_batched_identical_for_non_estimating_policies(tenant_data):
    """Regression: a policy that never calls estimate_costs (FlipFlop)
    cannot refresh the serve memo itself, so a swap landing at an earlier
    event of a multi-frame pass must invalidate the pass's pre-swap shadow
    scores — the version guard on the primed serve memo — or the batched
    trace silently serves stale costs."""
    d = tenant_data["t0"]
    rng = np.random.default_rng(4)
    events = []
    for i in range(120):
        lo = np.full(6, -np.inf)
        hi = np.full(6, np.inf)
        col = i % 6
        lo[col], hi[col] = np.sort(rng.uniform(0, 100, size=2))
        events.append(wl.QueryEvent("a", wl.Query(lo=lo, hi=hi)))
    for frames_per_pass in (1, 8, 64):
        loop = FleetEngine({"a": flipflop_engine(d, period=5, delta=2)})
        r_loop = loop.run(events)
        batched = FleetEngine({"a": flipflop_engine(d, period=5, delta=2)})
        r_batched = batched.run_batched(
            events, frames_per_pass=frames_per_pass)
        assert np.array_equal(r_loop.per_tenant["a"].query_costs,
                              r_batched.per_tenant["a"].query_costs), \
            f"stale serve memo leaked at frames_per_pass={frames_per_pass}"


def test_run_batched_rejects_unknown_compute_on_reuse(tenant_data):
    d = tenant_data["t0"]
    fleet = FleetEngine({"a": flipflop_engine(d)})
    q = full_scan(6)
    fleet.run_batched([wl.QueryEvent("a", q)])
    with pytest.raises(ValueError, match="compute"):
        fleet.run_batched([wl.QueryEvent("a", q)], compute="Pallas")


def test_add_and_remove_tenant_mid_flight(tenant_data):
    d = tenant_data["t0"]
    fleet = FleetEngine({"a": flipflop_engine(d)})
    q = full_scan(6)
    fleet.step("a", q)
    fleet.add_tenant("b", flipflop_engine(d))
    with pytest.raises(ValueError):
        fleet.add_tenant("b", flipflop_engine(d))
    fleet.step("b", q)
    assert set(fleet.tenant_ids) == {"a", "b"}
    engine = fleet.remove_tenant("b")
    assert engine.governor is None
    assert len(engine.result().query_costs) == 1
    assert fleet.tenant_ids == ["a"]
    # removed tenant is gone from the aggregate result
    assert set(fleet.result().per_tenant) == {"a"}
    with pytest.raises(KeyError):
        fleet.remove_tenant("b")


def test_remove_tenant_releases_scheduler_grants(tenant_data):
    d = tenant_data["t0"]
    sched = KConcurrentScheduler(1)
    fleet = FleetEngine({"a": flipflop_engine(d, period=1, delta=100),
                         "b": flipflop_engine(d, period=1, delta=100)},
                        sched)
    q = full_scan(6)
    fleet.step("a", q)      # a charges and acquires the single work unit
    fleet.step("b", q)      # b charges and queues behind a
    assert sched.in_flight == 1
    fleet.remove_tenant("a")
    assert sched.in_flight == 0     # a's grant returned to the pool
    fleet.step("b", q)              # b's queued work can now be granted
    assert sched.in_flight == 1


def test_add_tenant_attaches_to_existing_fleet_matrix(tenant_data):
    d = tenant_data["t0"]
    fleet = FleetEngine({"a": flipflop_engine(d)})
    q = full_scan(6)
    fleet.run_batched([wl.QueryEvent("a", q)])
    assert "a" in fleet.fleet_matrix
    fleet.add_tenant("b", flipflop_engine(d))
    assert "b" in fleet.fleet_matrix
    fleet.remove_tenant("b")
    assert "b" not in fleet.fleet_matrix


# ---------------------------------------------------------------------------
# pallas_fused backend: golden identity + the dense bulk decide path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_run_batched_pallas_fused_bit_identical_to_loop(scenario,
                                                        tenant_data,
                                                        bounds):
    """The megakernel backend honours the same bit-identity contract as
    compute="numpy": the float32 guard routes non-representable operands
    to the exact path, so fused-backend batched traces equal the stepwise
    loop under every scheduler."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario(scenario, lo, hi, num_tenants=3,
                                 queries_per_tenant=120, seed=7)
        loop = FleetEngine({tid: oreo_engine(tenant_data[tid])
                            for tid in fs.tenant_ids}, factory())
        r_loop = loop.run(fs)
        batched = FleetEngine({tid: oreo_engine(tenant_data[tid])
                               for tid in fs.tenant_ids}, factory())
        r_batched = batched.run_batched(fs, compute="pallas_fused")
        for tid in fs.tenant_ids:
            a, b = r_loop.per_tenant[tid], r_batched.per_tenant[tid]
            assert np.array_equal(a.query_costs, b.query_costs)
            assert a.reorg_indices == b.reorg_indices
            assert np.array_equal(a.state_seq, b.state_seq)
        assert r_loop.swaps_deferred == r_batched.swaps_deferred
        assert r_loop.deferred_ticks == r_batched.deferred_ticks
        assert r_loop.scheduler_stats.get("grants") \
            == r_batched.scheduler_stats.get("grants")


def threshold_engine(data, threshold, alpha=10.0, delta=2):
    space = [build_default_layout(sid, data, 8, sort_col=sid % data.shape[1])
             for sid in range(3)]
    return LayoutEngine(ThresholdSwitchPolicy(space, alpha=alpha,
                                              threshold=threshold),
                        InMemoryBackend(data), delta=delta)


@pytest.mark.parametrize("compute", ["numpy", "pallas_fused"])
@pytest.mark.parametrize("threshold", [0.0, 0.05, 1e9])
def test_threshold_bulk_path_bit_identical_to_loop(compute, threshold,
                                                   tenant_data, bounds):
    """Batch-decidable fleet (every policy implements decide_frames): the
    bulk decide path commits whole passes without per-event Python, and
    passes with switch/swap activity fall back — traces stay bit-identical
    to the loop under every scheduler, with and without reorgs."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                                 queries_per_tenant=120, seed=13)
        loop = FleetEngine({tid: threshold_engine(tenant_data[tid],
                                                  threshold)
                            for tid in fs.tenant_ids}, factory())
        r_loop = loop.run(fs)
        batched = FleetEngine({tid: threshold_engine(tenant_data[tid],
                                                     threshold)
                               for tid in fs.tenant_ids}, factory())
        r_batched = batched.run_batched(fs, compute=compute)
        for tid in fs.tenant_ids:
            a, b = r_loop.per_tenant[tid], r_batched.per_tenant[tid]
            assert np.array_equal(a.query_costs, b.query_costs)
            assert a.reorg_indices == b.reorg_indices
            assert np.array_equal(a.state_seq, b.state_seq)
        assert r_loop.swaps_deferred == r_batched.swaps_deferred
        assert r_loop.scheduler_stats.get("grants") \
            == r_batched.scheduler_stats.get("grants")


def test_bulk_path_engages_without_per_event_decide(tenant_data, bounds,
                                                    monkeypatch):
    """On a switch-free stretch the whole run must resolve through
    decide_frames — a single decide() call means the bulk path silently
    disengaged."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                             queries_per_tenant=100, seed=17)

    def boom(self, index, query, backend):
        raise AssertionError("bulk path disengaged: decide() was called")

    monkeypatch.setattr(ThresholdSwitchPolicy, "decide", boom)
    fleet = FleetEngine({tid: threshold_engine(tenant_data[tid], 1e9,
                                               delta=0)
                         for tid in fs.tenant_ids})
    result = fleet.run_batched(fs)
    assert all(len(r.query_costs) == 100
               for r in result.per_tenant.values())


def test_bulk_path_runs_megakernel_on_f32_exact_data(monkeypatch):
    """float32-exact plane + queries under compute="pallas_fused": the
    megakernel actually scores the passes (no silent numpy fallback), and
    the trace still equals the stepwise loop bit for bit."""
    from repro.engine import compute as engine_compute
    rng = np.random.default_rng(23)
    data = {f"t{t}": rng.uniform(0, 100, size=(2_000, 4)).astype(
        np.float32).astype(np.float64) for t in range(3)}
    events = []
    for i in range(90):
        for tid in data:
            lo = np.full(4, -np.inf)
            hi = np.full(4, np.inf)
            col = (i + int(tid[1])) % 4
            a, b = np.sort(rng.uniform(0, 100, size=2).astype(
                np.float32).astype(np.float64))
            lo[col], hi[col] = a, b
            events.append(wl.QueryEvent(tid, wl.Query(lo=lo, hi=hi)))
    loop = FleetEngine({tid: threshold_engine(d, 0.05) for tid, d
                        in data.items()})
    r_loop = loop.run(events)
    calls = []
    real = engine_compute.fused_frames_scan

    def spy(*args, **kw):
        calls.append(1)
        return real(*args, **kw)

    monkeypatch.setattr(engine_compute, "fused_frames_scan", spy)
    batched = FleetEngine({tid: threshold_engine(d, 0.05) for tid, d
                           in data.items()})
    r_batched = batched.run_batched(events, compute="pallas_fused")
    assert calls, "megakernel never ran on f32-exact operands"
    for tid in data:
        a, b = r_loop.per_tenant[tid], r_batched.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs)
        assert a.reorg_indices == b.reorg_indices
        assert np.array_equal(a.state_seq, b.state_seq)
