"""Tests for the incremental reorganization plane: micro-move planning,
budgeted execution with exact α-charge amortization, hybrid-layout
serving on both backends, golden incremental-vs-atomic identity across
every drift scenario x scheduler, and the skip-aware
PartitionStore.reorganize."""
import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, layouts,
                        make_generator, make_templates, workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.data.partition_store import PartitionStore
from repro.engine import (DiskBackend, FleetEngine, InMemoryBackend,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          TokenBucketScheduler, UnlimitedScheduler,
                          plan_migration)
from repro.engine.reorg.executor import closing_increment
from repro.engine.reorg.planner import plan_is_permutation_of_diff


def clustered_layout(data, layout_id, partitions, sort_col=0):
    return build_default_layout(layout_id, data, partitions,
                                sort_col=sort_col)


def qdtree_layout(data, layout_id, partitions, queries):
    return make_generator("qdtree")(layout_id, data, queries, partitions)


def random_queries(rng, col_lo, col_hi, n, bounded=2):
    tmpl = make_templates(1, col_lo.shape[0], rng,
                          cols_per_template=(bounded, bounded))[0]
    return [tmpl.sample(rng, col_lo, col_hi) for _ in range(n)]


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def test_plan_is_permutation_of_layout_diff():
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(2000, 4))
    src = clustered_layout(data, 0, 8, sort_col=0)
    tgt = clustered_layout(data, 1, 8, sort_col=1)
    plan = plan_migration(data, src, tgt,
                          random_queries(rng, data.min(0), data.max(0), 16))
    assert plan_is_permutation_of_diff(plan)
    moved = {m.target_partition for m in plan.moves}
    assert len(moved) == len(plan.moves)          # no duplicates
    assert plan.total_move_rows == sum(m.rows for m in plan.moves)


def test_plan_identical_layouts_has_no_moves():
    rng = np.random.default_rng(1)
    data = rng.uniform(0, 100, size=(1200, 3))
    src = clustered_layout(data, 0, 6, sort_col=2)
    tgt = clustered_layout(data, 1, 6, sort_col=2)     # same row sets
    plan = plan_migration(data, src, tgt)
    assert plan.moves == []
    assert plan.total_move_rows == 0
    assert set(plan.identical) == set(range(6))
    assert plan_is_permutation_of_diff(plan)


def test_plan_partial_overlap_skips_identical_partitions():
    """Identity is by *content*, not by label: a pure relabeling (two
    partitions swap ids) needs no physical moves at all, while a genuine
    content change moves exactly the affected partitions."""
    rng = np.random.default_rng(2)
    n, k = 2000, 8
    data = np.sort(rng.uniform(0, 100, size=(n, 1)), axis=0)
    src = clustered_layout(data, 0, k, sort_col=0)
    a = src.route(data)

    def layout_from(assignment, layout_id):
        meta = layouts.metadata_from_assignment(data, assignment, k)
        return layouts.Layout(layout_id=layout_id, name=f"t{layout_id}",
                              technique="test", meta=meta,
                              route=lambda rows, s=assignment: s)

    # pure relabeling: the two top partitions swap ids, row sets unchanged
    swapped = a.copy()
    swapped[a == k - 1] = k - 2
    swapped[a == k - 2] = k - 1
    plan = plan_migration(data, src, layout_from(swapped, 1))
    assert plan.moves == []
    assert plan.identical[k - 2] == k - 1
    assert plan.identical[k - 1] == k - 2
    assert plan_is_permutation_of_diff(plan)

    # genuine content change: the two top partitions' rows interleave
    mixed = a.copy()
    top = np.nonzero(a >= k - 2)[0]
    mixed[top] = k - 2 + (np.arange(len(top)) % 2)
    plan2 = plan_migration(data, src, layout_from(mixed, 2))
    assert sorted(m.target_partition for m in plan2.moves) == [k - 2, k - 1]
    assert set(plan2.identical) == set(range(k - 2))
    assert plan_is_permutation_of_diff(plan2)


def test_plan_greedy_order_sorted_by_benefit_per_row():
    rng = np.random.default_rng(3)
    data = rng.uniform(0, 100, size=(3000, 4))
    queries = random_queries(rng, data.min(0), data.max(0), 32)
    src = clustered_layout(data, 0, 8)
    tgt = qdtree_layout(data, 1, 8, queries)
    plan = plan_migration(data, src, tgt, queries)
    per_row = [m.benefit_per_row for m in plan.moves]
    assert per_row == sorted(per_row, reverse=True)


def test_hybrid_meta_endpoints_match_source_and_target():
    """No moves done -> hybrid scan costs equal the pure source layout;
    all moves done -> equal the pure target layout (bitwise: the extra
    empty partitions contribute exactly 0.0 to the einsum)."""
    rng = np.random.default_rng(4)
    data = rng.uniform(0, 100, size=(2500, 4))
    queries = random_queries(rng, data.min(0), data.max(0), 24)
    src = clustered_layout(data, 0, 8)
    tgt = qdtree_layout(data, 1, 8, queries)
    plan = plan_migration(data, src, tgt, queries)
    src_meta = src.materialize(data)
    none_done = plan.hybrid_meta(np.zeros(8, dtype=bool))
    all_done = plan.hybrid_meta(np.ones(8, dtype=bool))
    q_lo, q_hi = wl.stack_queries(queries)
    np.testing.assert_array_equal(
        layouts.eval_cost(none_done, q_lo, q_hi),
        layouts.eval_cost(src_meta, q_lo, q_hi))
    np.testing.assert_array_equal(
        layouts.eval_cost(all_done, q_lo, q_hi),
        layouts.eval_cost(plan.target_meta, q_lo, q_hi))


def test_hybrid_meta_is_exact_zone_maps_of_physical_hybrid():
    """For any done set, the hybrid metadata equals zone maps computed
    from scratch over the physically mixed assignment."""
    rng = np.random.default_rng(5)
    data = rng.uniform(0, 100, size=(2000, 3))
    queries = random_queries(rng, data.min(0), data.max(0), 16)
    src = clustered_layout(data, 0, 6)
    tgt = qdtree_layout(data, 1, 6, queries)
    plan = plan_migration(data, src, tgt, queries)
    done = np.zeros(6, dtype=bool)
    for m in plan.moves[:len(plan.moves) // 2 + 1]:
        done[m.target_partition] = True
    hybrid = plan.hybrid_meta(done)
    # ground truth: rows of done targets live at slot P_s + j, the rest
    # stay in their source partition slot
    a = np.where(done[plan.target_assignment],
                 plan.num_source_partitions + plan.target_assignment,
                 plan.source_assignment)
    want = layouts.metadata_from_assignment(
        data, a, plan.num_source_partitions + plan.num_target_partitions)
    np.testing.assert_array_equal(hybrid.rows, want.rows)
    np.testing.assert_array_equal(hybrid.mins, want.mins)
    np.testing.assert_array_equal(hybrid.maxs, want.maxs)


# ---------------------------------------------------------------------------
# Golden identity: incremental(∞ budget) == atomic, everywhere
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(100 + t).uniform(
        0, 100, size=(2_500, 6)) for t in range(3)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, incremental=False, rows_per_tick=None, alpha=10.0,
                delta=5, seed=2):
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8), gen, cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        incremental=incremental,
                        rows_per_tick=rows_per_tick)


SCHEDULERS = [
    ("unlimited", UnlimitedScheduler),
    ("k1", lambda: KConcurrentScheduler(1)),
    ("bucket", lambda: TokenBucketScheduler(rate=0.01, capacity=1.0,
                                            initial=0.0)),
]

ALL_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                 "flash_crowd", "template_churn"]


@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_incremental_bit_identical_to_atomic(scenario, tenant_data, bounds):
    """The acceptance gate: with an unbounded per-tick budget the
    incremental fleet's traces — query costs, reorg indices, state
    sequences, deferral counters, scheduler stats — are bit-identical to
    the atomic fleet's, for every scenario under every scheduler."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario(scenario, lo, hi, num_tenants=3,
                                 queries_per_tenant=100, seed=7)
        atomic = FleetEngine({tid: oreo_engine(tenant_data[tid])
                              for tid in fs.tenant_ids}, factory())
        ra = atomic.run(fs)
        incr = FleetEngine({tid: oreo_engine(tenant_data[tid],
                                             incremental=True)
                            for tid in fs.tenant_ids}, factory())
        assert incr.incremental
        ri = incr.run(fs)
        for tid in fs.tenant_ids:
            a, b = ra.per_tenant[tid], ri.per_tenant[tid]
            assert np.array_equal(a.query_costs, b.query_costs)
            assert a.reorg_indices == b.reorg_indices
            assert np.array_equal(a.state_seq, b.state_seq)
        assert ra.swaps_deferred == ri.swaps_deferred
        assert ra.deferred_ticks == ri.deferred_ticks
        assert ra.scheduler_stats == ri.scheduler_stats
        # every migration completed within its begin step and charged
        # exactly alpha
        for tid in fs.tenant_ids:
            ex = incr.tenant(tid).reorg_executor
            for mig in ex.migrations:
                assert mig.completed_at == mig.begun_at
                assert mig.charged == mig.alpha


def test_incremental_run_batched_identical_to_loop(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                             queries_per_tenant=100, seed=3)
    for rpt in (None, 150):
        loop = FleetEngine({tid: oreo_engine(tenant_data[tid],
                                             incremental=True,
                                             rows_per_tick=rpt)
                            for tid in fs.tenant_ids})
        rl = loop.run(fs)
        batched = FleetEngine({tid: oreo_engine(tenant_data[tid],
                                                incremental=True,
                                                rows_per_tick=rpt)
                               for tid in fs.tenant_ids})
        rb = batched.run_batched(fs)
        for tid in fs.tenant_ids:
            assert np.array_equal(rl.per_tenant[tid].query_costs,
                                  rb.per_tenant[tid].query_costs)
            assert np.array_equal(rl.per_tenant[tid].state_seq,
                                  rb.per_tenant[tid].state_seq)


def test_incremental_standalone_engine_identical_to_atomic():
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(2000, 5))
    tmpls = make_templates(2, 5, rng)
    stream = wl.generate_workload(tmpls, data.min(0), data.max(0),
                                  total_queries=200, seed=1,
                                  segment_length=(60, 90))
    ra = oreo_engine(data).run(stream)
    rb = oreo_engine(data, incremental=True).run(stream)
    assert np.array_equal(ra.query_costs, rb.query_costs)
    assert ra.reorg_indices == rb.reorg_indices
    assert np.array_equal(ra.state_seq, rb.state_seq)
    assert ra.total_cost == rb.total_cost


def test_disk_backend_incremental_identical_to_atomic(tmp_path):
    rng = np.random.default_rng(1)
    data = rng.uniform(0, 100, size=(5000, 4))
    tmpls = make_templates(2, 4, rng)
    stream = wl.generate_workload(tmpls, data.min(0), data.max(0),
                                  total_queries=80, seed=2,
                                  segment_length=(30, 50))

    def run(sub, incremental, rpt=None):
        cfg = OreoConfig(alpha=8.0, delta=6, seed=1,
                         manager=lm.LayoutManagerConfig(
                             target_partitions=6, window_size=30,
                             gen_every=15))
        backend = DiskBackend(data, str(tmp_path / sub), background=False)
        policy = OreoPolicy(data, build_default_layout(0, data, 6),
                            make_generator("qdtree"), cfg)
        engine = LayoutEngine(policy, backend, delta=cfg.delta,
                              incremental=incremental, rows_per_tick=rpt)
        result = engine.run(stream)
        backend.close()
        return result, engine

    ra, _ = run("atomic", False)
    rb, _ = run("incr", True)
    assert np.array_equal(ra.query_costs, rb.query_costs)
    rc, engine = run("tight", True, rpt=1000)
    # tight budget: still completes, costs may differ mid-migration but
    # the per-query costs stay valid fractions
    assert np.all((np.asarray(rc.query_costs) >= 0)
                  & (np.asarray(rc.query_costs) <= 1))
    assert all(m.charged == m.alpha
               for m in engine.reorg_executor.migrations
               if m.completed_at >= 0)


# ---------------------------------------------------------------------------
# Budgeted execution semantics
# ---------------------------------------------------------------------------

def test_tight_budget_spreads_moves_and_bounds_per_tick_rows():
    rng = np.random.default_rng(6)
    data = rng.uniform(0, 100, size=(2000, 5))
    tmpls = make_templates(2, 5, rng)
    stream = wl.generate_workload(tmpls, data.min(0), data.max(0),
                                  total_queries=200, seed=1,
                                  segment_length=(60, 90))
    engine = oreo_engine(data, incremental=True, rows_per_tick=137)
    engine.run(stream)
    ex = engine.reorg_executor
    completed = [m for m in ex.migrations if m.completed_at >= 0]
    assert completed
    for mig in completed:
        assert mig.completed_at > mig.begun_at       # actually spread out
        assert len(mig.charges) > 1
        # per-step rows moved never exceed the budget... except that a
        # single move is atomic; moves here are ~250 rows < several ticks
        # of banked budget, so each landing step reports <= banked rows.
        for _, rows, _ in mig.charges:
            assert rows <= mig.total_rows


def test_kconcurrent_holds_unit_for_whole_migration(tenant_data):
    d = tenant_data["t0"]
    sched = KConcurrentScheduler(1)
    fleet = FleetEngine(
        {"a": oreo_engine(d, incremental=True, rows_per_tick=50, delta=0,
                          seed=5),
         "b": oreo_engine(d, incremental=True, rows_per_tick=50, delta=0,
                          seed=6)},
        sched)
    lo, hi = d.min(0), d.max(0)
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=2,
                             queries_per_tenant=150, seed=9)
    events = [ev for ev in fs if ev.tenant_id in ("t0", "t1")]
    renamed = [wl.QueryEvent("a" if tid == "t0" else "b", q)
               for tid, q in events]
    fleet.run(renamed)
    # while any migration was in flight the single unit was held: at no
    # point did both tenants migrate concurrently
    ex_a = fleet.tenant("a").reorg_executor
    ex_b = fleet.tenant("b").reorg_executor
    spans_a = [(m.begun_at, m.completed_at) for m in ex_a.migrations
               if m.completed_at > m.begun_at]
    spans_b = [(m.begun_at, m.completed_at) for m in ex_b.migrations
               if m.completed_at > m.begun_at]
    # the scheduler unit is held exactly while a migration is in flight:
    # whatever is still migrating at stream end still holds its unit
    in_flight_a = sum(m.completed_at < 0 for m in ex_a.migrations)
    in_flight_b = sum(m.completed_at < 0 for m in ex_b.migrations)
    assert fleet._held == {"a": in_flight_a, "b": in_flight_b}
    assert sched.in_flight == in_flight_a + in_flight_b
    # k=1 held across whole migrations means the two tenants never both
    # migrate at once (cross-check via completed spans on the fleet clock
    # is impossible with per-tenant indices, but the unit accounting above
    # plus at least one genuinely spread-out migration pins the behavior)
    assert spans_a or spans_b                   # budgeted spans existed


def test_token_bucket_rows_mode_meters_rows():
    sched = TokenBucketScheduler(rate=1.0, capacity=500.0, initial=100.0,
                                 rows_per_token=1.0)
    assert sched.try_acquire("a")               # admission free
    assert sched.grant_rows("a", 60) == 60
    assert sched.grant_rows("a", 60) == 40      # bucket drained
    assert sched.grant_rows("a", 60) == 0
    sched.tick(1)                               # +1 token = +1 row
    sched.tick(2)
    assert sched.grant_rows("a", 60) == 2


def test_rows_per_tick_requires_incremental(tenant_data):
    with pytest.raises(ValueError, match="incremental"):
        oreo_engine(tenant_data["t0"], incremental=False, rows_per_tick=10)


def test_incremental_rejects_reference_backend(tenant_data):
    d = tenant_data["t0"]
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=5.0, seed=1)
    policy = OreoPolicy(d, build_default_layout(0, d, 8), gen, cfg)
    with pytest.raises(ValueError, match="reference"):
        LayoutEngine(policy, InMemoryBackend(d, compute="reference"),
                     incremental=True)


def test_fleet_rejects_mixed_modes(tenant_data):
    d = tenant_data["t0"]
    with pytest.raises(ValueError, match="mix"):
        FleetEngine({"a": oreo_engine(d), "b": oreo_engine(d,
                                                           incremental=True)})
    with pytest.raises(ValueError, match="opposite"):
        FleetEngine({"a": oreo_engine(d)}, incremental=True)
    fleet = FleetEngine({"a": oreo_engine(d, incremental=True)})
    with pytest.raises(ValueError, match="incremental"):
        fleet.add_tenant("b", oreo_engine(d))


def test_incremental_run_rejects_batch_serve(tenant_data):
    engine = oreo_engine(tenant_data["t0"], incremental=True)
    with pytest.raises(ValueError, match="batch_serve"):
        engine.run([], batch_serve=True)


# ---------------------------------------------------------------------------
# Hybrid serving through the metadata plane
# ---------------------------------------------------------------------------

def test_hybrid_serving_updates_shadow_through_listener_events(tenant_data):
    """Mid-migration the backend's SERVING_SHADOW carries the hybrid zone
    maps (so estimates, serve fusion and FleetMatrix mirrors all see the
    hybrid state), and serve() equals eval_cost over the hybrid meta."""
    rng = np.random.default_rng(8)
    d = tenant_data["t0"]
    engine = oreo_engine(d, incremental=True, rows_per_tick=120, delta=0,
                         alpha=2.0)
    backend = engine.backend
    tmpls = make_templates(2, 6, rng)
    stream = wl.generate_workload(tmpls, d.min(0), d.max(0),
                                  total_queries=300, seed=4,
                                  segment_length=(80, 120))
    saw_hybrid = 0
    for q in stream:
        engine.step(q)
        ex = engine.reorg_executor
        if ex.active is not None and ex.done_mask is not None \
                and ex.done_mask.any():
            saw_hybrid += 1
            plan = ex._active
            hybrid = plan.hybrid_meta(ex.done_mask)
            want = float(layouts.eval_cost(hybrid, q.lo, q.hi))
            shadow = backend.state_matrix.metadata(
                InMemoryBackend.SERVING_SHADOW)
            np.testing.assert_array_equal(shadow.rows, hybrid.rows)
            got = backend.serve(q)
            assert got == want
    assert saw_hybrid > 0, "budget never left a migration in flight"


def test_partition_store_reorganize_skips_identical(tmp_path):
    rng = np.random.default_rng(9)
    data = rng.uniform(0, 100, (3000, 4))
    store = PartitionStore(str(tmp_path / "tbl"))
    store.write(data, build_default_layout(0, data, 6))
    stats = store.reorganize(build_default_layout(1, data, 6))
    assert stats.partitions_skipped == 6
    assert stats.partitions_rewritten == 0
    assert stats.rows_rewritten == 0
    stats2 = store.reorganize(build_default_layout(2, data, 6, sort_col=1))
    assert stats2.partitions_rewritten > 0
    assert stats2.partitions_rewritten + stats2.partitions_skipped == 6
    # scans stay correct after the carried-over files
    tmpl = make_templates(1, 4, rng)[0]
    q = tmpl.sample(rng, data.min(0), data.max(0))
    rows, _ = store.scan(q)
    mask = ((data >= q.lo[None]) & (data <= q.hi[None])).all(axis=1)
    assert len(rows) == mask.sum()
    assert float(stats) == stats.seconds


def test_partition_store_reorganize_into_more_partitions(tmp_path):
    """Regression: growing the partition count must not try to carry over
    files that never existed — an added *empty* partition compares equal
    to a missing old partition but has no file to copy."""
    rng = np.random.default_rng(10)
    data = rng.uniform(0, 100, (1000, 3))
    store = PartitionStore(str(tmp_path / "tbl"))
    store.write(data, build_default_layout(0, data, 4))
    wide = build_default_layout(1, data, 8)
    # force partition 7 empty: route everything into 0..6
    route = wide.route

    def squeezed(rows):
        return np.minimum(route(rows), 6)

    squeezed_layout = layouts.Layout(
        layout_id=1, name="squeezed", technique="test",
        meta=layouts.metadata_from_assignment(data, squeezed(data), 8),
        route=squeezed)
    stats = store.reorganize(squeezed_layout)
    assert stats.partitions_rewritten + stats.partitions_skipped == 8
    meta = store.metadata()
    assert meta.num_partitions == 8 and meta.rows[7] == 0
    rows, _ = store.scan(wl.Query(lo=data.min(0), hi=data.max(0)))
    assert len(rows) == len(data)


def test_closing_increment_lands_bitwise():
    for charged, alpha in [(0.0, 8.0), (7.9999999999999, 8.0),
                           (2.6666666666666665, 8.0), (0.1, 1.0),
                           (1e-30, 1.0), (9.000000000000002, 9.0)]:
        inc = closing_increment(charged, alpha)
        assert charged + inc == alpha
