"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import _backend
from repro.kernels.decision_fused import decision_fused as df
from repro.kernels.decision_fused import ops as df_ops
from repro.kernels.decision_fused import ref as df_ref
from repro.kernels.flash_attention import flash_attention as fa
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.fleet_scan import fleet_scan, ops as fleet_ops
from repro.kernels.fleet_scan import ref as fleet_ref
from repro.kernels.move_score import move_score, ops as move_ops
from repro.kernels.move_score import ref as move_ref
from repro.kernels.pruning import pruning, ref as prune_ref
from repro.kernels.zorder import ref as z_ref, zorder


# ---------------------------------------------------------------------------
# pruning kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,P,C", [(8, 8, 4), (64, 32, 12), (130, 60, 7),
                                   (256, 128, 58), (17, 5, 1)])
def test_pruning_matches_ref(Q, P, C):
    rng = np.random.default_rng(Q * 1000 + P)
    p_min = rng.uniform(0, 1, (P, C)).astype(np.float32)
    p_max = p_min + rng.uniform(0, 0.5, (P, C)).astype(np.float32)
    q_lo = rng.uniform(0, 1, (Q, C)).astype(np.float32)
    q_hi = q_lo + rng.uniform(0, 0.5, (Q, C)).astype(np.float32)
    got = pruning.scan_matrix_pallas(q_lo, q_hi, p_min, p_max, interpret=True)
    want = prune_ref.scan_matrix(q_lo, q_hi, p_min, p_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("Q,P,C,bq,bp,col_chunk", [
    (130, 60, 7, 128, 128, 8),    # Q and P ragged vs the block size
    (33, 17, 5, 16, 16, 2),       # ragged everywhere, C % col_chunk != 0
    (64, 32, 9, 32, 32, 4),       # C not a multiple of col_chunk
    (7, 3, 1, 8, 8, 8),           # tiny: blocks clamp to the problem size
    (128, 128, 8, 128, 128, 8),   # exact multiples (no padding at all)
])
def test_pruning_ragged_padding_parity(Q, P, C, bq, bp, col_chunk):
    """Kernel == numpy reference on every ragged Q/P/C padding edge, with
    interpret auto-selected (None -> interpreter on CPU-only hosts)."""
    rng = np.random.default_rng(Q * 7919 + P * 31 + C)
    p_min = rng.uniform(0, 1, (P, C)).astype(np.float32)
    p_max = p_min + rng.uniform(0, 0.5, (P, C)).astype(np.float32)
    q_lo = rng.uniform(0, 1, (Q, C)).astype(np.float32)
    q_hi = q_lo + rng.uniform(0, 0.5, (Q, C)).astype(np.float32)
    got = pruning.scan_matrix_pallas(q_lo, q_hi, p_min, p_max, bq=bq, bp=bp,
                                     col_chunk=col_chunk, interpret=None)
    want = prune_ref.scan_matrix(q_lo, q_hi, p_min, p_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_pruning_interpret_autodetect_matches_backend():
    """interpret=None resolves to the interpreter exactly when JAX has no
    accelerator backend."""
    from repro.engine import scan_matrix as engine_scan_matrix
    rng = np.random.default_rng(0)
    p_min = rng.uniform(0, 1, (12, 4)).astype(np.float32)
    p_max = p_min + 0.2
    q_lo = rng.uniform(0, 1, (9, 4)).astype(np.float32)
    q_hi = q_lo + 0.3
    want = np.asarray(prune_ref.scan_matrix(q_lo, q_hi, p_min, p_max))
    # the engine's unified entry point routes through the same auto-detection
    got = engine_scan_matrix(q_lo, q_hi, p_min, p_max, backend="pallas")
    assert np.array_equal(got, want > 0.5)


@pytest.mark.parametrize("bq,bp,col_chunk", [(32, 32, 4), (128, 64, 8),
                                             (16, 128, 3)])
def test_pruning_block_sweep(bq, bp, col_chunk):
    rng = np.random.default_rng(0)
    Q, P, C = 96, 80, 10
    p_min = rng.uniform(0, 1, (P, C)).astype(np.float32)
    p_max = p_min + 0.2
    q_lo = rng.uniform(0, 1, (Q, C)).astype(np.float32)
    q_hi = q_lo + 0.3
    got = pruning.scan_matrix_pallas(q_lo, q_hi, p_min, p_max, bq=bq, bp=bp,
                                     col_chunk=col_chunk, interpret=True)
    want = prune_ref.scan_matrix(q_lo, q_hi, p_min, p_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_pruning_agrees_with_core_cost_model():
    """Kernel semantics == the simulator's numpy cost model."""
    from repro.core import layouts as core_layouts
    rng = np.random.default_rng(3)
    P, C, Q = 24, 6, 40
    p_min = rng.uniform(0, 100, (P, C))
    p_max = p_min + rng.uniform(0, 30, (P, C))
    rows = rng.integers(100, 1000, P).astype(np.float64)
    meta = core_layouts.PartitionMetadata(mins=p_min, maxs=p_max, rows=rows)
    q_lo = rng.uniform(0, 100, (Q, C))
    q_hi = q_lo + rng.uniform(0, 50, (Q, C))
    want = core_layouts.partitions_scanned(meta, q_lo, q_hi)
    got = pruning.scan_matrix_pallas(q_lo.astype(np.float32),
                                     q_hi.astype(np.float32),
                                     p_min.astype(np.float32),
                                     p_max.astype(np.float32),
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(got) > 0.5, want)


# ---------------------------------------------------------------------------
# fleet_scan kernel (fused multi-tenant scan matrix)
# ---------------------------------------------------------------------------

def _fleet_case(T, N, C, seed):
    rng = np.random.default_rng(seed)
    p_min = rng.uniform(0, 1, (T, N, C)).astype(np.float32)
    p_max = p_min + rng.uniform(0, 0.5, (T, N, C)).astype(np.float32)
    q_lo = rng.uniform(0, 1, (T, C)).astype(np.float32)
    q_hi = q_lo + rng.uniform(0, 0.5, (T, C)).astype(np.float32)
    return q_lo, q_hi, p_min, p_max


@pytest.mark.parametrize("T,N,C", [(1, 8, 4), (4, 64, 8), (32, 56, 6),
                                   (17, 130, 7), (3, 5, 1)])
def test_fleet_scan_matches_ref(T, N, C):
    q_lo, q_hi, p_min, p_max = _fleet_case(T, N, C, T * 1000 + N)
    got = fleet_scan.scan_fleet_pallas(q_lo, q_hi, p_min, p_max,
                                       interpret=True)
    want = fleet_ref.scan_fleet(q_lo, q_hi, p_min, p_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("T,N,C,bt,bn,col_chunk", [
    (17, 130, 7, 8, 128, 8),    # T and N ragged vs the block sizes
    (5, 33, 5, 4, 16, 2),       # ragged everywhere, C % col_chunk != 0
    (8, 64, 9, 8, 32, 4),       # C not a multiple of col_chunk
    (1, 3, 1, 8, 8, 8),         # tiny: blocks clamp to the problem size
    (8, 128, 8, 8, 128, 8),     # exact multiples (no padding at all)
])
def test_fleet_scan_ragged_padding_parity(T, N, C, bt, bn, col_chunk):
    """Kernel == jnp oracle on every ragged T/N/C padding edge, with
    interpret auto-selected (None -> interpreter on CPU-only hosts)."""
    q_lo, q_hi, p_min, p_max = _fleet_case(T, N, C, T * 7919 + N * 31 + C)
    got = fleet_scan.scan_fleet_pallas(q_lo, q_hi, p_min, p_max, bt=bt,
                                       bn=bn, col_chunk=col_chunk,
                                       interpret=None)
    want = fleet_ref.scan_fleet(q_lo, q_hi, p_min, p_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fleet_scan_per_tenant_rows_match_pruning_kernel():
    """Each tenant lane of the fused kernel equals the single-table
    pruning kernel run on that tenant's own bounds and query."""
    T, N, C = 6, 40, 5
    q_lo, q_hi, p_min, p_max = _fleet_case(T, N, C, 99)
    fused = np.asarray(fleet_scan.scan_fleet_pallas(q_lo, q_hi, p_min,
                                                    p_max, interpret=True))
    for t in range(T):
        single = pruning.scan_matrix_pallas(q_lo[t:t + 1], q_hi[t:t + 1],
                                            p_min[t], p_max[t],
                                            interpret=True)
        np.testing.assert_array_equal(fused[t], np.asarray(single)[0])


def test_fleet_scan_matches_engine_exact_path():
    """Kernel semantics == the engine's exact float64 fleet overlap (on
    float32-representable bounds), across the (C, T, S, P) layout."""
    from repro.engine import compute as engine_compute
    rng = np.random.default_rng(12)
    T, S, P, C = 4, 3, 8, 4
    mins = rng.uniform(0, 1, (T, S, P, C)).astype(np.float32).astype(
        np.float64)
    maxs = mins + rng.uniform(0, 0.5, (T, S, P, C)).astype(
        np.float32).astype(np.float64)
    q_lo = rng.uniform(0, 1, (T, C)).astype(np.float32).astype(np.float64)
    q_hi = q_lo + 0.25
    minsT = np.ascontiguousarray(np.moveaxis(mins, 3, 0))
    maxsT = np.ascontiguousarray(np.moveaxis(maxs, 3, 0))
    want = engine_compute.fleet_masked_overlap(minsT, maxsT, q_lo, q_hi)
    got = engine_compute.fleet_scan_matrix(
        q_lo, q_hi, mins.reshape(T, S * P, C), maxs.reshape(T, S * P, C),
        backend="pallas").reshape(T, S, P)
    np.testing.assert_array_equal(got, want)


def test_fleet_scan_fractions_weights_rows():
    rng = np.random.default_rng(13)
    T, N, C = 3, 16, 4
    q_lo, q_hi, p_min, p_max = _fleet_case(T, N, C, 13)
    rows = rng.integers(1, 100, (T, N)).astype(np.float32)
    frac = np.asarray(fleet_ops.fleet_scan_fractions(
        jnp.asarray(q_lo), jnp.asarray(q_hi), jnp.asarray(p_min),
        jnp.asarray(p_max), jnp.asarray(rows)))
    scan = np.asarray(fleet_ref.scan_fleet(q_lo, q_hi, p_min, p_max))
    want = (scan * rows).sum(1) / np.maximum(rows.sum(1), 1.0)
    np.testing.assert_allclose(frac, want, rtol=1e-6)
    assert np.all(frac >= 0) and np.all(frac <= 1)


def test_fleet_ops_wrapper_dispatches():
    q_lo, q_hi, p_min, p_max = _fleet_case(2, 8, 3, 7)
    via_kernel = fleet_ops.scan_fleet(q_lo, q_hi, p_min, p_max,
                                      use_kernel=True, interpret=True)
    via_oracle = fleet_ops.scan_fleet(q_lo, q_hi, p_min, p_max,
                                      use_kernel=False)
    np.testing.assert_array_equal(np.asarray(via_kernel),
                                  np.asarray(via_oracle))


# ---------------------------------------------------------------------------
# move_score kernel (per-partition scan frequencies for the reorg planner)
# ---------------------------------------------------------------------------

def _move_case(Q, S, P, C, seed):
    rng = np.random.default_rng(seed)
    p_min = rng.uniform(0, 1, (S, P, C)).astype(np.float32)
    p_max = p_min + rng.uniform(0, 0.5, (S, P, C)).astype(np.float32)
    q_lo = rng.uniform(0, 1, (Q, C)).astype(np.float32)
    q_hi = q_lo + rng.uniform(0, 0.5, (Q, C)).astype(np.float32)
    return q_lo, q_hi, p_min, p_max


@pytest.mark.parametrize("Q,S,P,C", [(8, 2, 16, 4), (32, 2, 64, 8),
                                     (13, 3, 37, 5), (1, 2, 5, 1),
                                     (64, 4, 130, 7)])
def test_move_score_matches_ref(Q, S, P, C):
    q_lo, q_hi, p_min, p_max = _move_case(Q, S, P, C, Q * 1000 + P)
    got = move_score.move_scores_pallas(q_lo, q_hi, p_min, p_max,
                                        interpret=True)
    want = move_ref.move_scores(q_lo, q_hi, p_min, p_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("Q,S,P,C,bp,col_chunk", [
    (16, 2, 130, 7, 128, 8),    # P ragged vs the block size
    (9, 3, 33, 5, 16, 2),       # ragged everywhere, C % col_chunk != 0
    (24, 2, 64, 9, 32, 4),      # C not a multiple of col_chunk
    (3, 1, 3, 1, 8, 8),         # tiny: blocks clamp to the problem size
    (16, 2, 128, 8, 128, 8),    # exact multiples (no padding at all)
])
def test_move_score_ragged_padding_parity(Q, S, P, C, bp, col_chunk):
    """Kernel == jnp oracle on every ragged P/C padding edge, with
    interpret auto-selected (None -> interpreter on CPU-only hosts)."""
    q_lo, q_hi, p_min, p_max = _move_case(Q, S, P, C, Q * 7919 + P * 31 + C)
    got = move_score.move_scores_pallas(q_lo, q_hi, p_min, p_max, bp=bp,
                                        col_chunk=col_chunk, interpret=None)
    want = move_ref.move_scores(q_lo, q_hi, p_min, p_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_move_score_agrees_with_planner_numpy_path():
    """Kernel frequencies == the planner's exact numpy scan frequencies
    (on float32-representable bounds)."""
    from repro.core import layouts as core_layouts
    from repro.engine.reorg.planner import scan_frequencies
    rng = np.random.default_rng(21)
    P, C, Q = 24, 4, 30
    metas = []
    for _ in range(2):
        mins = rng.uniform(0, 100, (P, C)).astype(np.float32).astype(
            np.float64)
        maxs = mins + rng.uniform(0, 30, (P, C)).astype(np.float32).astype(
            np.float64)
        rows = rng.integers(10, 100, P).astype(np.float64)
        metas.append(core_layouts.PartitionMetadata(mins=mins, maxs=maxs,
                                                    rows=rows))
    q_lo = rng.uniform(0, 100, (Q, C)).astype(np.float32).astype(np.float64)
    q_hi = q_lo + 20.0
    exact = scan_frequencies(metas, q_lo, q_hi, compute="numpy")
    kernel = scan_frequencies(metas, q_lo, q_hi, compute="pallas")
    for a, b in zip(exact, kernel):
        np.testing.assert_allclose(b, a, rtol=1e-6, atol=1e-7)


def test_move_ops_wrapper_dispatches():
    q_lo, q_hi, p_min, p_max = _move_case(12, 2, 20, 3, 7)
    via_kernel = move_ops.move_scan_frequencies(q_lo, q_hi, p_min, p_max,
                                                use_kernel=True,
                                                interpret=True)
    via_oracle = move_ops.move_scan_frequencies(q_lo, q_hi, p_min, p_max,
                                                use_kernel=False)
    np.testing.assert_allclose(np.asarray(via_kernel),
                               np.asarray(via_oracle), rtol=1e-6)


# ---------------------------------------------------------------------------
# zorder kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,m,bits", [(100, 3, 10), (1024, 2, 16),
                                      (4097, 3, 8), (64, 1, 16), (33, 4, 8)])
def test_zorder_matches_ref(N, m, bits):
    rng = np.random.default_rng(N)
    vals = rng.uniform(-5, 5, (N, m)).astype(np.float32)
    lo = vals.min(0)
    hi = vals.max(0)
    got = zorder.zorder_keys_pallas(vals, lo, hi, bits=bits, interpret=True)
    want = z_ref.zorder_keys(jnp.asarray(vals), jnp.asarray(lo),
                             jnp.asarray(hi), bits=bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zorder_matches_core_numpy():
    """Kernel keys sort rows identically to the simulator's numpy Z-order."""
    from repro.core import zorder as core_z
    rng = np.random.default_rng(7)
    vals = rng.uniform(0, 100, (512, 3))
    lo, hi = vals.min(0), vals.max(0)
    codes = core_z.quantize_columns(vals, lo, hi)
    want = core_z.interleave_bits(codes)
    got = zorder.zorder_keys_pallas(vals.astype(np.float32),
                                    lo.astype(np.float32),
                                    hi.astype(np.float32),
                                    bits=10, interpret=True)
    # Different bit depths (16 vs 10) -> compare induced orderings coarsely:
    # keys must be monotone under the same sort for a decimated prefix.
    order_ref = np.argsort(np.asarray(want), kind="stable")
    order_got = np.argsort(np.asarray(got), kind="stable")
    # identical leading-bit structure => high rank correlation
    from scipy import stats  # noqa: F401  (optional)
    ranks_ref = np.empty(512); ranks_ref[order_ref] = np.arange(512)
    ranks_got = np.empty(512); ranks_got[order_got] = np.arange(512)
    corr = np.corrcoef(ranks_ref, ranks_got)[0, 1]
    assert corr > 0.98, corr


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,S,dh,causal", [
    (128, 128, 64, True), (256, 256, 64, True), (64, 64, 128, True),
    (128, 128, 64, False), (96, 96, 64, True),   # non-multiple of block
])
def test_flash_attention_matches_ref(T, S, dh, causal):
    key = jax.random.PRNGKey(T + S)
    BH = 4
    q = jax.random.normal(key, (BH, T, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, dh),
                          jnp.float32)
    got = fa.flash_attention_pallas(q, k, v, causal=causal, bq=64, bk=64,
                                    interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-3),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, rtol):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 128, 64), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 64), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 64), dtype)
    got = fa.flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64,
                                    interpret=True)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_attention_gqa_wrapper_matches_model_layer():
    """ops.attention (GQA expand + kernel) == models.layers.flash_attention."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(5)
    B, T, Hq, Hkv, dh = 2, 128, 8, 2, 32
    q = jax.random.normal(key, (B, T, Hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, dh),
                          jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, use_kernel=True, bq=64,
                           bk=64)
    want = L.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_prefix_lm():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (2, 128, 32), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 32),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 32),
                          jnp.float32)
    got = fa.flash_attention_pallas(q, k, v, causal=True, prefix_len=32,
                                    bq=64, bk=64, interpret=True)
    want = fa_ref.attention(q, k, v, causal=True, prefix_len=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# decision_fused megakernel (scan + serve-shadow cost + move freq, one pass)
# ---------------------------------------------------------------------------

def _fused_case(B, T, S, P, C, W, seed):
    rng = np.random.default_rng(seed)
    p_min = rng.uniform(0, 1, (T, S, P, C)).astype(np.float32)
    p_max = p_min + rng.uniform(0, 0.5, (T, S, P, C)).astype(np.float32)
    q_lo = rng.uniform(0, 1, (B, T, C)).astype(np.float32)
    q_hi = q_lo + rng.uniform(0, 0.5, (B, T, C)).astype(np.float32)
    rows = rng.integers(1, 1000, (T, S, P)).astype(np.float32)
    inv = (1.0 / np.maximum(rows.sum(-1), 1.0)).astype(np.float32)
    w_lo = rng.uniform(0, 1, (W, C)).astype(np.float32)
    w_hi = w_lo + rng.uniform(0, 0.5, (W, C)).astype(np.float32)
    return q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi


def _assert_fused_triple(got, want):
    g_scan, g_cost, g_freq = got
    w_scan, w_cost, w_freq = want
    np.testing.assert_array_equal(np.asarray(g_scan), np.asarray(w_scan))
    np.testing.assert_allclose(np.asarray(g_cost), np.asarray(w_cost),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(g_freq), np.asarray(w_freq),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("B,T,S,P,C,W", [
    (1, 1, 1, 1, 1, 1), (2, 3, 2, 8, 4, 4), (4, 8, 3, 16, 6, 8),
    (3, 5, 4, 33, 5, 7), (2, 4, 2, 128, 8, 16),
])
def test_fused_decision_matches_ref(B, T, S, P, C, W):
    ops = _fused_case(B, T, S, P, C, W, B * 1000 + T * 100 + P)
    got = df.fused_decision_pallas(*ops, interpret=True)
    want = df_ref.fused_decision(*[jnp.asarray(a) for a in ops])
    _assert_fused_triple(got, want)


@pytest.mark.parametrize("B,T,S,P,C,W,bt,bp,col_chunk", [
    (2, 17, 2, 130, 7, 4, 4, 128, 8),   # T and P ragged vs the block sizes
    (3, 5, 3, 33, 5, 6, 2, 16, 2),      # ragged everywhere, C % chunk != 0
    (2, 8, 2, 64, 9, 8, 4, 32, 4),      # C not a multiple of col_chunk
    (1, 1, 1, 3, 1, 1, 4, 128, 8),      # tiny: blocks clamp to the problem
    (2, 8, 2, 128, 8, 4, 4, 128, 8),    # exact multiples (no padding)
])
def test_fused_decision_ragged_padding_parity(B, T, S, P, C, W, bt, bp,
                                              col_chunk):
    """Megakernel == jnp oracle on every ragged T/P/C padding edge, with
    interpret auto-selected (None -> interpreter on CPU-only hosts)."""
    ops = _fused_case(B, T, S, P, C, W, T * 7919 + P * 31 + C)
    got = df.fused_decision_pallas(*ops, bt=bt, bp=bp, col_chunk=col_chunk,
                                   interpret=None)
    want = df_ref.fused_decision(*[jnp.asarray(a) for a in ops])
    _assert_fused_triple(got, want)


def test_fused_decision_partial_outputs():
    """Outputs not requested come back None; the requested ones are
    unchanged by which siblings ride along."""
    q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi = _fused_case(
        2, 4, 2, 20, 4, 6, 55)
    full = df.fused_decision_pallas(q_lo, q_hi, p_min, p_max, rows, inv,
                                    w_lo, w_hi, interpret=True)
    scan_only = df.fused_decision_pallas(q_lo, q_hi, p_min, p_max,
                                         interpret=True)
    assert scan_only[1] is None and scan_only[2] is None
    np.testing.assert_array_equal(np.asarray(scan_only[0]),
                                  np.asarray(full[0]))
    cost_only = df.fused_decision_pallas(q_lo, q_hi, p_min, p_max, rows,
                                         inv, emit_scan=False,
                                         interpret=True)
    assert cost_only[0] is None and cost_only[2] is None
    np.testing.assert_array_equal(np.asarray(cost_only[1]),
                                  np.asarray(full[1]))
    freq_only = df.fused_decision_pallas(q_lo, q_hi, p_min, p_max,
                                         w_lo=w_lo, w_hi=w_hi,
                                         emit_scan=False, interpret=True)
    assert freq_only[0] is None and freq_only[1] is None
    np.testing.assert_array_equal(np.asarray(freq_only[2]),
                                  np.asarray(full[2]))
    with pytest.raises(ValueError, match="nothing to emit"):
        df.fused_decision_pallas(q_lo, q_hi, p_min, p_max, emit_scan=False,
                                 interpret=True)


def test_fused_decision_matches_three_separate_kernels():
    """The megakernel's three outputs == the three kernels it fuses,
    bit for bit on the 0/1 scan and to float tolerance on the reductions."""
    B, T, S, P, C, W = 3, 6, 2, 40, 5, 8
    q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi = _fused_case(
        B, T, S, P, C, W, 99)
    scan, cost, freq = df.fused_decision_pallas(
        q_lo, q_hi, p_min, p_max, rows, inv, w_lo, w_hi, interpret=True)
    scan = np.asarray(scan)
    # scan: one fleet_scan launch per frame over the (T, S*P, C) plane
    pm2 = p_min.reshape(T, S * P, C)
    px2 = p_max.reshape(T, S * P, C)
    for b in range(B):
        sep = fleet_scan.scan_fleet_pallas(q_lo[b], q_hi[b], pm2, px2,
                                           interpret=True)
        np.testing.assert_array_equal(
            scan[b], np.asarray(sep).reshape(T, S, P))
    # scan again: one pruning launch per (frame, tenant, state) table
    for t in range(T):
        for s in range(S):
            single = pruning.scan_matrix_pallas(
                q_lo[:, t], q_hi[:, t], p_min[t, s], p_max[t, s],
                interpret=True)
            np.testing.assert_array_equal(scan[:, t, s], np.asarray(single))
    # cost: the scanned-row fraction the scan implies
    want_cost = (scan * rows[None]).sum(-1) * inv[None]
    np.testing.assert_allclose(np.asarray(cost), want_cost, rtol=1e-6,
                               atol=1e-7)
    # freq: one move_score launch per tenant over the shared window
    for t in range(T):
        sep = move_score.move_scores_pallas(w_lo, w_hi, p_min[t], p_max[t],
                                            interpret=True)
        np.testing.assert_allclose(np.asarray(freq)[t], np.asarray(sep),
                                   rtol=1e-6, atol=1e-7)


def test_fused_ops_wrapper_dispatches():
    ops = _fused_case(2, 3, 2, 12, 4, 5, 7)
    via_kernel = df_ops.fused_decision(*ops, use_kernel=True,
                                       interpret=True)
    via_oracle = df_ops.fused_decision(*ops, use_kernel=False)
    _assert_fused_triple(via_kernel, via_oracle)


# ---------------------------------------------------------------------------
# shared interpret auto-detection (_backend.resolve_interpret)
# ---------------------------------------------------------------------------

def test_resolve_interpret_explicit_passthrough():
    assert _backend.resolve_interpret(True) is True
    assert _backend.resolve_interpret(False) is False


def test_resolve_interpret_follows_detected_backend(monkeypatch):
    """interpret=None compiles on accelerators and interprets on CPU-only
    hosts — the seam every kernel shares."""
    monkeypatch.setattr(_backend, "default_backend", lambda: "tpu")
    assert _backend.resolve_interpret(None) is False
    monkeypatch.setattr(_backend, "default_backend", lambda: "gpu")
    assert _backend.resolve_interpret(None) is False
    monkeypatch.setattr(_backend, "default_backend", lambda: "cpu")
    assert _backend.resolve_interpret(None) is True


def test_all_kernels_share_backend_seam(monkeypatch):
    """Monkeypatching the one detected-backend seam changes auto-detect
    for every kernel module (no copy-pasted detection left behind)."""
    calls = []

    def spy():
        calls.append(1)
        return "cpu"

    monkeypatch.setattr(_backend, "default_backend", spy)
    q_lo, q_hi, p_min, p_max = _fleet_case(2, 8, 3, 3)
    fleet_scan.scan_fleet_pallas(q_lo, q_hi, p_min, p_max, interpret=None)
    move_score.move_scores_pallas(q_lo, q_hi, p_min, p_max, interpret=None)
    pruning.scan_matrix_pallas(q_lo, q_hi, p_min[0], p_max[0],
                               interpret=None)
    df.fused_decision_pallas(q_lo[None], q_hi[None], p_min[:, None],
                             p_max[:, None], interpret=None)
    assert len(calls) >= 4
