"""Property tests for the routing plane's placement layer
(repro.engine.placement).

Covers the three placement invariants the router leans on — consistent-
hash structural stability (adding/removing a shard relocates only the
tenants whose arc moved, ~1/N of them), lookup purity in
``(ring, overrides)``, and the ShardLoadMeter's hysteresis contract —
plus a route → migrate → route round trip preserving per-tenant traces
and α charge ledgers bitwise under arbitrary migration sequences.

Each property runs twice, per the test_wal idiom: as a seeded
deterministic sweep (always on, cannot flake the gate) and as a
Hypothesis property when hypothesis is installed (derandomized under
the CI profile registered in conftest.py).
"""
import itertools

import numpy as np
import pytest

from repro.core import build_default_layout
from repro.core.workload import make_drift_scenario
from repro.engine import (Decision, FleetEngine, FleetRouter,
                          HashRing, InMemoryBackend, LayoutEngine,
                          PartitionDirectory, RebalanceConfig,
                          ShardLoadMeter)


def random_shards(rng, max_shards=8):
    n = int(rng.integers(1, max_shards + 1))
    ids = rng.choice(40, size=n, replace=False)
    return [f"s{i}" for i in ids]


def random_tenants(rng, max_tenants=40):
    n = int(rng.integers(1, max_tenants + 1))
    return [f"tenant-{i}" for i in rng.choice(10_000, size=n,
                                              replace=False)]


# ---------------------------------------------------------------------------
# HashRing: purity + structural stability (deterministic sweeps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_ring_lookup_is_pure_sweep(seed):
    """Two rings built from the same shard set (any insertion order)
    agree on every key, and repeated lookups never change — placement
    is a pure function of (key, shard set, replicas)."""
    rng = np.random.default_rng(seed)
    for _ in range(10):
        shards = random_shards(rng)
        replicas = int(rng.integers(1, 65))
        a = HashRing(shards, replicas=replicas)
        b = HashRing(reversed(shards), replicas=replicas)
        for t in random_tenants(rng):
            assert a.lookup(t) == a.lookup(t) == b.lookup(t)
            assert a.lookup(t) in shards


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_ring_removal_only_moves_tenants_of_removed_shard_sweep(seed):
    rng = np.random.default_rng(10 + seed)
    for _ in range(10):
        shards = random_shards(rng)
        tenants = random_tenants(rng)
        ring = HashRing(shards)
        before = {t: ring.lookup(t) for t in tenants}
        victim = shards[int(rng.integers(len(shards)))]
        ring.remove_shard(victim)
        if len(shards) == 1:
            with pytest.raises(ValueError):
                ring.lookup(tenants[0])
            continue
        for t in tenants:
            after = ring.lookup(t)
            if before[t] != victim:
                assert after == before[t]   # untouched arcs never move
            else:
                assert after != victim


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_ring_addition_only_moves_tenants_onto_new_shard_sweep(seed):
    rng = np.random.default_rng(20 + seed)
    for _ in range(10):
        shards = random_shards(rng)
        tenants = random_tenants(rng)
        ring = HashRing(shards)
        before = {t: ring.lookup(t) for t in tenants}
        ring.add_shard("s99")
        for t in tenants:
            after = ring.lookup(t)
            assert after == before[t] or after == "s99"
    with pytest.raises(ValueError, match="already on the ring"):
        ring.add_shard("s99")


def test_ring_relocation_rate_is_about_one_over_n():
    """Growing N → N+1 shards relocates ~1/(N+1) of tenants (the
    consistent-hashing contract), never more than a small multiple of
    it at our replica count."""
    tenants = [f"t{i}" for i in range(2000)]
    for n in (2, 4, 8):
        ring = HashRing([f"s{i}" for i in range(n)])
        before = {t: ring.lookup(t) for t in tenants}
        ring.add_shard(f"s{n}")
        moved = [t for t in tenants if ring.lookup(t) != before[t]]
        frac = len(moved) / len(tenants)
        ideal = 1.0 / (n + 1)
        assert 0.2 * ideal <= frac <= 3.0 * ideal
        assert all(ring.lookup(t) == f"s{n}" for t in moved)


def test_ring_validation():
    with pytest.raises(ValueError, match="replicas"):
        HashRing(["s0"], replicas=0)
    with pytest.raises(KeyError):
        HashRing(["s0"]).remove_shard("s1")
    assert len(HashRing(["s0", "s1"])) == 2
    assert HashRing(["s1", "s0"]).shard_ids == ["s0", "s1"]


def test_ring_stability_hypothesis():
    """The removal/addition stability properties under Hypothesis-driven
    shard sets and tenant keys."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    shard_sets = st.lists(st.integers(0, 40), min_size=2, max_size=8,
                          unique=True).map(
                              lambda xs: [f"s{i}" for i in xs])
    tenant_keys = st.lists(st.text(min_size=1, max_size=12), min_size=1,
                           max_size=40, unique=True)

    @settings(max_examples=50, deadline=None)
    @given(shards=shard_sets, tenants=tenant_keys)
    def prop(shards, tenants):
        ring = HashRing(shards)
        before = {t: ring.lookup(t) for t in tenants}
        ring.add_shard("s99")
        assert all(ring.lookup(t) in (before[t], "s99") for t in tenants)
        ring.remove_shard("s99")
        assert all(ring.lookup(t) == before[t] for t in tenants)
        victim = shards[0]
        ring.remove_shard(victim)
        for t in tenants:
            if before[t] != victim:
                assert ring.lookup(t) == before[t]

    prop()


# ---------------------------------------------------------------------------
# PartitionDirectory: overrides over the ring, pure in (ring, overrides)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_directory_lookup_pure_in_ring_and_overrides_sweep(seed):
    rng = np.random.default_rng(30 + seed)
    for _ in range(10):
        shards = random_shards(rng)
        tenants = random_tenants(rng)
        ring = HashRing(shards)
        k = int(rng.integers(0, min(8, len(tenants)) + 1))
        pinned = {t: shards[int(rng.integers(len(shards)))]
                  for t in rng.choice(tenants, size=k, replace=False)}
        a = PartitionDirectory(ring, overrides=pinned)
        b = PartitionDirectory(HashRing(shards), overrides=dict(pinned))
        for t in tenants:
            assert a.lookup(t) == b.lookup(t)
            assert a.lookup(t) == pinned.get(t, ring.lookup(t))
        assert a.placement(tenants) == b.placement(tenants)


def test_directory_assign_clear_roundtrip():
    shards = ["s0", "s1", "s2"]
    directory = PartitionDirectory(HashRing(shards))
    for tenant in (f"t{i}" for i in range(20)):
        home = directory.lookup(tenant)
        directory.assign(tenant, home)      # pinning the ring's answer
        assert tenant not in directory.overrides
        elsewhere = next(s for s in shards if s != home)
        directory.assign(tenant, elsewhere)
        assert directory.lookup(tenant) == elsewhere
        assert directory.overrides[tenant] == elsewhere
        directory.clear(tenant)
        assert directory.lookup(tenant) == home
    directory.clear("never-pinned")         # clearing nothing is a no-op


# ---------------------------------------------------------------------------
# ShardLoadMeter: hysteresis contract
# ---------------------------------------------------------------------------

def fill_window(meter, hot_events, cold_events):
    for i in range(hot_events):
        meter.observe("s0", f"t{i % 4}")
    for i in range(cold_events):
        meter.observe("s1", f"u{i % 4}")


def test_meter_fires_once_then_rearms_below_low():
    cfg = RebalanceConfig(window=64, high=1.5, low=1.1, queue_weight=0.0)
    meter = ShardLoadMeter(["s0", "s1"], cfg)
    assert not meter.window_complete
    fill_window(meter, 64, 0)                   # imbalance 2.0 > high
    assert meter.window_complete
    tenant, hot, cold = meter.suggest()
    assert (hot, cold) == ("s0", "s1")
    assert tenant.startswith("t")
    assert not meter.armed                      # disarmed after firing
    fill_window(meter, 64, 0)                   # still skewed: no re-fire
    assert meter.suggest() is None
    assert not meter.armed
    fill_window(meter, 33, 31)                  # ~balanced: below low
    assert meter.suggest() is None              # re-arms, doesn't fire
    assert meter.armed
    fill_window(meter, 64, 0)                   # skew again: fires again
    assert meter.suggest() is not None
    assert meter.moves_suggested == 2
    assert meter.windows_evaluated == 4


def test_meter_refuses_move_that_relocates_the_hotspot():
    """A single tenant hotter than the whole skew is not movable —
    shipping it to the cold shard would just move the problem."""
    cfg = RebalanceConfig(window=16, high=1.2, low=1.05, queue_weight=0.0)
    meter = ShardLoadMeter(["s0", "s1"], cfg)
    for _ in range(16):
        meter.observe("s0", "whale")            # one tenant is all the load
    assert meter.suggest() is None
    assert meter.armed                          # nothing fired


def test_meter_queue_depth_weighs_into_loads():
    cfg = RebalanceConfig(window=8, high=1.5, low=1.1, queue_weight=2.0)
    meter = ShardLoadMeter(["s0", "s1"], cfg)
    for i in range(8):
        meter.observe("s0" if i % 2 else "s1", f"t{i}")
    meter.note_queue_depth("s0", 10)
    assert meter.loads()["s0"] == pytest.approx(4 + 20)
    assert meter.imbalance() > 1.5
    stats = meter.stats()
    assert stats["windows_evaluated"] == 0
    assert stats["armed"] is True


def test_rebalance_config_validation():
    with pytest.raises(ValueError, match="hysteresis"):
        RebalanceConfig(high=1.1, low=1.2)
    with pytest.raises(ValueError, match="window"):
        RebalanceConfig(window=0)


# ---------------------------------------------------------------------------
# Route → migrate → route: the α ledger survives arbitrary re-homing
# ---------------------------------------------------------------------------

class _EveryKPolicy:
    """Charges a reorganization between two layouts every ``k`` queries."""

    name = "EveryK"

    def __init__(self, layouts_, k):
        self.layouts = list(layouts_)
        self.k = k
        self.alpha = 1.0
        self.cur = 0

    def bind(self, backend):
        for lay in self.layouts:
            backend.register(lay)
        return self.layouts[0].layout_id

    def decide(self, index, query, backend):
        if (index + 1) % self.k == 0:
            self.cur = 1 - self.cur
            return Decision(state=self.layouts[self.cur].layout_id,
                            reorg=True)
        return Decision(state=self.layouts[self.cur].layout_id)

    def info(self):
        return {}


def _small_engine(seed):
    data = np.random.default_rng(seed).uniform(0, 100, size=(600, 4))
    lays = [build_default_layout(0, data, 4, sort_col=0),
            build_default_layout(1, data, 4, sort_col=1)]
    return LayoutEngine(_EveryKPolicy(lays, 7), InMemoryBackend(data),
                        delta=3, incremental=True, rows_per_tick=50)


TENANTS = [f"t{i}" for i in range(4)]


def roundtrip_matches_unsharded(moves, qpt):
    """Run one migration sequence through a 3-shard router and compare
    every trace + ledger bitwise against the unsharded fleet."""
    lo, hi = np.zeros(4), np.full(4, 100.0)
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                             queries_per_tenant=qpt, seed=13)
    events = list(fs)

    ref = FleetEngine({t: _small_engine(i) for i, t in enumerate(TENANTS)})
    ref.run(events)

    router = FleetRouter({t: _small_engine(i)
                          for i, t in enumerate(TENANTS)}, num_shards=3)
    chunk = max(1, len(events) // (len(moves) + 1))
    step = 0
    for ti, si in moves:
        for ev in events[step:step + chunk]:
            router.submit(ev)
        router.drain()
        step += chunk
        router.migrate_tenant(TENANTS[ti], f"s{si}")
    for ev in events[step:]:
        router.submit(ev)
    router.drain()

    for i, t in enumerate(TENANTS):
        a, b = ref.tenant(t), router.tenant(t)
        ra, rb = a.result(), b.result()
        assert np.array_equal(ra.query_costs, rb.query_costs)
        assert ra.reorg_indices == rb.reorg_indices
        assert [m.charges for m in a.reorg_executor.migrations] \
            == [m.charges for m in b.reorg_executor.migrations]


def test_route_migrate_route_roundtrip_sweep():
    """Deterministic sweep: single moves, ping-pong pairs, and a long
    every-tenant shuffle all preserve traces and ledgers bitwise."""
    for moves in ([(0, 1)],
                  [(0, 1), (0, 2)],               # ping-pong one tenant
                  [(0, 1), (1, 1), (2, 0)],
                  list(itertools.product(range(4), (1,)))):
        roundtrip_matches_unsharded(moves, qpt=21)


def test_route_migrate_route_roundtrip_hypothesis():
    """The same round trip under Hypothesis-driven move sequences."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(moves=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2)),
                          min_size=1, max_size=5),
           qpt=st.integers(7, 28))
    def prop(moves, qpt):
        roundtrip_matches_unsharded(moves, qpt)

    prop()
