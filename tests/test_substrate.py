"""Training substrate tests: optimizer, checkpoint/restart (bit-exact
resume), fault tolerance, gradient compression, OREO data pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import OreoDataPipeline, mixture_recipe, synth_corpus
from repro.data.partition_store import PartitionStore
from repro.models import build_model
from repro.train import (FaultTolerantTrainer, OptimizerConfig, TrainOptions,
                         build_train_step, checkpoint, compression,
                         init_train_state)
from repro.train.optimizer import global_norm, schedule


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_arch("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=100)
    options = TrainOptions(microbatches=1)
    step = jax.jit(build_train_step(model, opt_cfg, options))
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg, options)

    def batch_fn(i):
        r = np.random.default_rng(i)              # deterministic in step
        toks = r.integers(0, cfg.vocab, (4, 32), dtype=np.int32)
        return {"tokens": jnp.asarray(toks),
                "targets": jnp.asarray(np.roll(toks, -1, 1))}

    return cfg, model, step, state, batch_fn


def test_loss_decreases(tiny_setup):
    cfg, model, step, state, batch_fn = tiny_setup
    losses = []
    batch = batch_fn(0)                           # overfit one batch
    for i in range(25):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_schedule_warmup_cosine():
    cfg = OptimizerConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                          total_steps=100)
    assert float(schedule(jnp.asarray(0), cfg)) == pytest.approx(0.0)
    assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1e-3)
    assert float(schedule(jnp.asarray(100), cfg)) == pytest.approx(1e-4)


def test_microbatch_accumulation_matches_full_batch(tiny_setup):
    """grad-accum over 4 microbatches == single 4x batch step."""
    cfg, model, _, state, batch_fn = tiny_setup
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=100)
    s1 = build_train_step(model, opt_cfg, TrainOptions(microbatches=1))
    s4 = build_train_step(model, opt_cfg, TrainOptions(microbatches=4))
    batch = {k: jnp.concatenate([batch_fn(i)[k] for i in range(4)])
             for k in ("tokens", "targets")}
    st1, m1 = jax.jit(s1)(state, batch)
    st4, m4 = jax.jit(s4)(state, batch)
    # losses are means over the same tokens
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d1 = jax.tree.leaves(st1["params"])[3]
    d4 = jax.tree.leaves(st4["params"])[3]
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d4, np.float32), atol=5e-3)


def test_checkpoint_roundtrip(tiny_setup):
    cfg, model, step, state, batch_fn = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(state, td, step=7)
        assert checkpoint.latest_step(td) == 7
        restored = checkpoint.restore(td, 7, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last(tiny_setup):
    _, _, _, state, _ = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(state, td, step=s, keep_last=2)
        assert checkpoint.all_steps(td) == [4, 5]


def test_fault_tolerant_resume_bit_exact(tiny_setup):
    """A mid-run failure + restore replays to the same final loss."""
    cfg, model, step, state, batch_fn = tiny_setup

    with tempfile.TemporaryDirectory() as td:
        clean = FaultTolerantTrainer(step, state, batch_fn,
                                     ckpt_dir=td + "/a", ckpt_every=5)
        final_clean = clean.run(20)

        fail_at = {"armed": True}

        def fault_hook(s):
            if s == 13 and fail_at["armed"]:
                fail_at["armed"] = False
                raise RuntimeError("injected node failure")

        faulty = FaultTolerantTrainer(step, state, batch_fn,
                                      ckpt_dir=td + "/b", ckpt_every=5,
                                      fault_hook=fault_hook)
        final_faulty = faulty.run(20)
        assert faulty.restarts == 1
        for a, b in zip(jax.tree.leaves(final_clean["params"]),
                        jax.tree.leaves(final_faulty["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gradient_compression_error_feedback():
    """EF int8 roundtrip: per-step error bounded; residual carries it."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(0, 0.1, (64, 64)), jnp.float32)}
    residual = compression.init_residual(grads)
    total_in, total_out = np.zeros((64, 64)), np.zeros((64, 64))
    for i in range(20):
        g = {"w": jnp.asarray(rng.normal(0, 0.1, (64, 64)), jnp.float32)}
        deq, residual = compression.ef_int8_roundtrip(g, residual)
        total_in += np.asarray(g["w"])
        total_out += np.asarray(deq["w"])
    # error feedback keeps the accumulated signal: residual bounds the gap
    gap = np.abs(total_in - total_out)
    assert gap.max() <= np.abs(np.asarray(residual["w"])).max() + 1e-5


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))


# ---------------------------------------------------------------------------
# OREO data pipeline + partition store
# ---------------------------------------------------------------------------

def test_oreo_pipeline_yields_batches_and_improves_scan():
    meta, tokens = synth_corpus(n_docs=20_000, doc_len=32, vocab=100, seed=0)
    recipe = mixture_recipe(meta, total_steps=1500, seed=1,
                            segment_length=(300, 500))
    pipe = OreoDataPipeline(meta, tokens, recipe, batch_size=4, seq_len=32,
                            alpha=40.0)
    first_100 = []
    for i, batch in enumerate(pipe):
        assert batch["tokens"].shape == (4, 32)
        assert batch["targets"].shape == (4, 32)
        if i < 100:
            first_100.append(pipe.stats.scan_fraction_sum)
        if i >= 1400:
            break
    assert pipe.stats.queries >= 1400
    early = first_100[-1] / 100
    late = pipe.stats.mean_scan_fraction
    # layout adaptation should not make scanning worse over time
    assert late <= early * 1.2


def test_partition_store_scan_correctness(tmp_path):
    from repro.core import build_default_layout, make_templates
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, (5000, 6))
    store = PartitionStore(str(tmp_path / "tbl"))
    store.write(data, build_default_layout(0, data, 8))
    t = make_templates(1, 6, rng)[0]
    q = t.sample(rng, data.min(0), data.max(0))
    rows, stats = store.scan(q)
    mask = ((data >= q.lo[None]) & (data <= q.hi[None])).all(axis=1)
    assert len(rows) == mask.sum()
    assert stats.partitions_read <= stats.partitions_total
    assert stats.rows_read >= len(rows)


def test_prefetcher_preserves_order():
    from repro.train.elastic import Prefetcher
    items = list(range(50))
    out = list(Prefetcher(iter(items), depth=3))
    assert out == items
