"""Tests for the §VIII / appendix extensions (multi-copy, asymmetric 2-state)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.extensions import (MultiCopyDUMTS, offline_two_state,
                                   two_state_asymmetric)


def _rotating_costs(T, n, seed=0, period=150):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.3, 1.0, size=(T, n))
    for t in range(T):
        costs[t, (t // period) % n] = rng.uniform(0.0, 0.1)
    return costs


def test_multicopy_dominates_single_copy_on_query_cost():
    """Holding 2 copies can only lower per-query cost vs 1 copy (same seed)."""
    T, n = 1200, 4
    costs = _rotating_costs(T, n)
    totals = {}
    for kappa in (1, 2, 3):
        d = MultiCopyDUMTS(alpha=20.0, initial_states=range(n), kappa=kappa,
                           seed=0)
        q = 0.0
        for t in range(T):
            _, c = d.observe({i: float(costs[t, i]) for i in range(n)})
            q += c
        totals[kappa] = (q, d.total_reorg_cost)
    assert totals[2][0] <= totals[1][0]
    assert totals[3][0] <= totals[2][0]


def test_multicopy_held_set_is_valid():
    d = MultiCopyDUMTS(alpha=5.0, initial_states=[0, 1, 2], kappa=2, seed=1)
    rng = np.random.default_rng(0)
    for _ in range(300):
        d.observe({i: float(rng.uniform(0, 1)) for i in sorted(d.states)})
        assert len(d.held) == 2
        assert all(h in d.states for h in d.held)
    d.add_state(7)
    d.observe({i: 0.5 for i in sorted(d.states)})
    assert 7 in d.states


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 200), alpha_ab=st.floats(1.0, 20.0),
       alpha_ba=st.floats(1.0, 20.0))
def test_two_state_asymmetric_competitive(seed, alpha_ab, alpha_ba):
    """Online two-state cost <= 3 * OPT + switch-cost additive slack."""
    rng = np.random.default_rng(seed)
    T = 400
    a = rng.uniform(0, 1, T)
    b = rng.uniform(0, 1, T)
    # epochs where one state is clearly better
    a[100:200] *= 0.05
    b[250:350] *= 0.05
    online, seq = two_state_asymmetric(a, b, alpha_ab, alpha_ba)
    opt = offline_two_state(a, b, alpha_ab, alpha_ba)
    assert len(seq) == T
    assert online <= 3.0 * opt + (alpha_ab + alpha_ba)


def test_two_state_tracks_cheap_state():
    a = np.full(300, 0.9)
    b = np.full(300, 0.1)
    total, seq = two_state_asymmetric(a, b, 5.0, 5.0)
    assert seq[-1] == 1                      # settled in the cheap state
    assert total < 0.9 * 300                 # beat staying put
