"""Serving-layer tests: greedy generation + slot batcher."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import Request, SlotBatcher, greedy_generate


def test_greedy_generate_shapes_and_determinism():
    cfg = get_arch("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out1 = greedy_generate(model, params, prompt, steps=6)
    out2 = greedy_generate(model, params, prompt, steps=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert np.all(np.asarray(out1) >= 0) and np.all(
        np.asarray(out1) < cfg.vocab)


def test_greedy_generate_matches_forward_argmax():
    """First generated token == argmax of the full-forward last logits."""
    cfg = get_arch("chatglm3-6b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, cfg.vocab)
    out = greedy_generate(model, params, prompt, steps=1)
    logits = model.forward(params, {"tokens": prompt})
    want = jnp.argmax(logits[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))


def test_slot_batcher_lifecycle():
    b = SlotBatcher(num_slots=2)
    for rid in range(5):
        b.submit(Request(rid, np.zeros(4, np.int32), max_new_tokens=3))
    assert b.pending == 5 and b.active == 0
    b.fill_slots()
    assert b.active == 2 and b.pending == 3
    for _ in range(3):                      # 3 decode steps finish both
        b.record_tokens(np.array([7, 8]))
    assert len(b.completed) == 2
    assert b.completed[0].generated == [7, 7, 7]
    b.fill_slots()
    assert b.active == 2 and b.pending == 1
