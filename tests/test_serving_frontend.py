"""Tests for the serving front end (repro.serve.frontend).

Covers: golden bit-identity of frontend-driven traces vs driving the
fleet directly (all 10 scenarios — 5 drift + 5 ingest — under all 3
schedulers), the overload circuit breaker (sheds reorg/compaction work
only, α-charge ledgers bitwise untouched, zero queries dropped,
re-closes after the overload window with scheduler grants resuming),
the plane-versioned read-through cache (hits are bit-exact, serving
changes invalidate), token-bucket admission, overflow policies, the
SlotBatcher deque fix, and a hypothesis property test over arbitrary
admission-limit settings.
"""
import collections

import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, make_generator,
                        workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import (QueryEvent, make_drift_scenario,
                                 make_ingest_scenario)
from repro.engine import (Decision, FleetEngine, IngestConfig,
                          InMemoryBackend, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, ThresholdSwitchPolicy,
                          TokenBucketScheduler, UnlimitedScheduler)
from repro.serve import (AdmissionResult, FrontendConfig, Request,
                         ServeFrontend, SlotBatcher)


# ---------------------------------------------------------------------------
# Helpers / fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(500 + t).uniform(
        0, 100, size=(2_000, 5)) for t in range(2)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, ingest=None, alpha=10.0, delta=5, seed=2):
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        ingest=ingest)


SCHEDULERS = [
    ("unlimited", UnlimitedScheduler),
    ("k1", lambda: KConcurrentScheduler(1)),
    ("bucket", lambda: TokenBucketScheduler(rate=0.01, capacity=1.0,
                                            initial=0.0)),
]

DRIFT_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                   "flash_crowd", "template_churn"]
INGEST_SCENARIOS = ["trickle", "append_heavy", "mixed_rw", "ingest_burst",
                    "bulk_load"]


def make_stream(scenario, lo, hi, qpt=60, seed=7):
    if scenario in DRIFT_SCENARIOS:
        return make_drift_scenario(scenario, lo, hi, num_tenants=2,
                                   queries_per_tenant=qpt, seed=seed)
    return make_ingest_scenario(scenario, lo, hi, num_tenants=2,
                                queries_per_tenant=qpt, seed=seed)


def build_fleet(fs, tenant_data, scenario, factory=UnlimitedScheduler,
                **engine_kw):
    ingest = IngestConfig() if scenario in INGEST_SCENARIOS else None
    return FleetEngine({tid: oreo_engine(tenant_data[tid], ingest=ingest,
                                         **engine_kw)
                        for tid in fs.tenant_ids}, factory())


def assert_same_trace(a, b):
    assert np.array_equal(a.query_costs, b.query_costs)
    assert a.reorg_indices == b.reorg_indices
    assert np.array_equal(a.state_seq, b.state_seq)


PERMISSIVE = dict(queue_capacity=100_000, breaker_open_frac=None,
                  record_latency=False)


class FlipFlopPolicy:
    """Forces a swap every ``period`` queries (serving-change workhorse)."""

    name = "FlipFlop"

    def __init__(self, layouts_, period):
        self.layouts = list(layouts_)
        self.period = period
        self.alpha = 1.0
        self.cur = 0

    def bind(self, backend):
        for lay in self.layouts:
            backend.register(lay)
        return self.layouts[0].layout_id

    def decide(self, index, query, backend):
        if (index + 1) % self.period == 0:
            self.cur = 1 - self.cur
            return Decision(state=self.layouts[self.cur].layout_id,
                            reorg=True)
        return Decision(state=self.layouts[self.cur].layout_id)

    def info(self):
        return {}


# ---------------------------------------------------------------------------
# Golden identity: frontend == driving the fleet directly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", DRIFT_SCENARIOS + INGEST_SCENARIOS)
def test_frontend_bit_identical_to_direct_run(scenario, tenant_data,
                                              bounds):
    """All 10 scenarios x all 3 schedulers: a permissive frontend (cache
    on, breaker off, no throttling) reproduces the direct-run trace bit
    for bit — including delta-bearing ingest tenants, where every
    serving compose bumps the plane version and the cache must go
    conservative rather than stale."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_stream(scenario, lo, hi)
        ref = build_fleet(fs, tenant_data, scenario, factory).run(fs)
        fleet = build_fleet(fs, tenant_data, scenario, factory)
        fe = ServeFrontend(fleet, FrontendConfig(**PERMISSIVE))
        got = fe.run(fs)
        for tid in fs.tenant_ids:
            assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
        assert ref.swaps_deferred == got.swaps_deferred
        assert ref.deferred_ticks == got.deferred_ticks
        assert ref.scheduler_stats.get("grants") \
            == got.scheduler_stats.get("grants")
        assert got.scheduler == ref.scheduler       # proxy keeps the name


def test_frontend_batched_mode_matches_run_batched(tenant_data, bounds):
    lo, hi = bounds
    fs = make_stream("sudden_shift", lo, hi)
    ref = build_fleet(fs, tenant_data, "sudden_shift").run_batched(fs)
    fleet = build_fleet(fs, tenant_data, "sudden_shift")
    fe = ServeFrontend(fleet, FrontendConfig(batched=True, **PERMISSIVE))
    got = fe.run(fs)
    for tid in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])


# ---------------------------------------------------------------------------
# Overload: the breaker sheds reorg work, never serve work
# ---------------------------------------------------------------------------

OVERLOAD = dict(queue_capacity=48, overflow_policy="block",
                breaker_open_frac=0.5, breaker_close_frac=0.1,
                breaker_min_open_events=16, pump_chunk=4,
                record_latency=False)


def test_breaker_sheds_reorgs_but_alpha_ledger_untouched(tenant_data,
                                                         bounds):
    """The golden α-accounting test: under induced overload the breaker
    defers at least one reorganization, yet every tenant's charge ledger
    (reorg indices AND charged costs) is bitwise identical to the
    unshedded run, and zero queries are dropped.  flash_crowd drives
    estimate-driven decisions, so deferred swaps cannot feed back into
    charge timing (decisions never read the serving layout)."""
    lo, hi = bounds
    fs = make_stream("flash_crowd", lo, hi, qpt=120)
    ref = build_fleet(fs, tenant_data, "flash_crowd",
                      lambda: KConcurrentScheduler(1)).run(fs)
    fleet = build_fleet(fs, tenant_data, "flash_crowd",
                        lambda: KConcurrentScheduler(1))
    fe = ServeFrontend(fleet, FrontendConfig(**OVERLOAD))
    got = fe.run(fs)
    stats = fe.stats()
    assert stats["breaker"]["opens"] >= 1           # overload happened
    assert stats["shed_count"] >= 1                 # >=1 reorg deferred
    for tid in fs.tenant_ids:
        a, b = ref.per_tenant[tid], got.per_tenant[tid]
        # zero queries dropped
        assert len(b.query_costs) == 120
        # charge ledger bitwise identical under shedding
        assert a.reorg_indices == b.reorg_indices
        assert a.total_reorg_cost == b.total_reorg_cost
        assert np.array_equal(a.state_seq, b.state_seq)
    assert stats["processed"] == len(fs.events)


def test_breaker_recloses_and_grants_resume(tenant_data, bounds):
    lo, hi = bounds
    fs = make_stream("flash_crowd", lo, hi, qpt=120)
    fleet = build_fleet(fs, tenant_data, "flash_crowd",
                        lambda: KConcurrentScheduler(1))
    fe = ServeFrontend(fleet, FrontendConfig(**OVERLOAD))
    fe.run(fs)
    stats = fe.stats()
    assert stats["breaker"]["opens"] >= 1
    # the overload window ended: breaker re-closed with the queue drained
    assert stats["breaker"]["closes"] == stats["breaker"]["opens"]
    assert not fe._shedder.shedding
    assert fe.queue_depth == 0
    # grants kept flowing after re-close: feed a benign tail (round-robin
    # so every tenant can apply its granted swap and free the K=1 unit)
    # and check that everything the breaker parked gets granted
    for i in range(400):
        if not fleet._waiting:
            break
        tid = fs.tenant_ids[i % len(fs.tenant_ids)]
        q = fs.per_tenant[tid].queries[0]
        fe.submit_blocking(QueryEvent(tid, wl.Query(lo=q.lo.copy(),
                                                    hi=q.hi.copy())))
        fe.flush()
    assert not fleet._waiting
    assert fe.stats()["scheduler"].get("grants", 0) >= 1


# ---------------------------------------------------------------------------
# Versioned read-through cache
# ---------------------------------------------------------------------------

def hot_queries(lo, hi, n, distinct=4, seed=11):
    """n queries drawn from `distinct` bound-sets (fresh objects each
    time, so hits prove bounds-keyed caching, not the identity memo)."""
    rng = np.random.default_rng(seed)
    base = []
    for _ in range(distinct):
        qlo = np.full(lo.shape[0], -np.inf)
        qhi = np.full(lo.shape[0], np.inf)
        col = int(rng.integers(0, lo.shape[0]))
        a, b = np.sort(rng.uniform(lo[col], hi[col], size=2))
        qlo[col], qhi[col] = a, b
        base.append((qlo, qhi))
    out = []
    for i in range(n):
        qlo, qhi = base[i % distinct]
        out.append(wl.Query(lo=qlo.copy(), hi=qhi.copy()))
    return out


def test_cache_hits_are_bit_exact(tenant_data, bounds):
    lo, hi = bounds
    d = tenant_data["t0"]
    space = [build_default_layout(sid, d, 8, sort_col=sid % d.shape[1])
             for sid in range(3)]

    def build():
        return FleetEngine({"a": LayoutEngine(
            ThresholdSwitchPolicy(space, alpha=10.0, threshold=1e9),
            InMemoryBackend(d), delta=2)})

    events = [QueryEvent("a", q) for q in hot_queries(lo, hi, 80)]
    ref = build().run(events)
    fe = ServeFrontend(build(), FrontendConfig(**PERMISSIVE))
    got = fe.run(events)
    assert_same_trace(ref.per_tenant["a"], got.per_tenant["a"])
    cache = fe.stats()["cache"]
    # 4 distinct bound-sets, stable serving plane: everything after the
    # first round is a hit
    assert cache["hits"] >= 70
    assert cache["misses"] <= 10


def test_cache_invalidates_on_serving_change(tenant_data, bounds):
    """A policy that swaps every 3 queries bumps the plane version at
    every activation: repeated identical bounds must re-miss after each
    swap (conservative), and the trace still equals the direct run."""
    lo, hi = bounds
    d = tenant_data["t0"]
    lays = [build_default_layout(0, d, 8, sort_col=0),
            build_default_layout(1, d, 8, sort_col=1)]

    def build():
        return FleetEngine({"a": LayoutEngine(FlipFlopPolicy(lays, 3),
                                              InMemoryBackend(d),
                                              delta=0)})

    events = [QueryEvent("a", q) for q in hot_queries(lo, hi, 30,
                                                      distinct=1)]
    ref = build().run(events)
    fe = ServeFrontend(build(), FrontendConfig(**PERMISSIVE))
    got = fe.run(events)
    assert_same_trace(ref.per_tenant["a"], got.per_tenant["a"])
    cache = fe.stats()["cache"]
    # one bound-set, but a swap every 3rd query invalidates: many misses
    assert cache["misses"] >= 10
    assert cache["hits"] >= 10      # between swaps the entry still serves


def test_cache_disabled_and_lru_bound(tenant_data, bounds):
    lo, hi = bounds
    d = tenant_data["t0"]
    space = [build_default_layout(0, d, 8)]

    def build():
        return FleetEngine({"a": LayoutEngine(
            ThresholdSwitchPolicy(space, alpha=10.0, threshold=1e9),
            InMemoryBackend(d), delta=2)})

    fe = ServeFrontend(build(), FrontendConfig(cache_entries=0,
                                               **PERMISSIVE))
    fe.run([QueryEvent("a", q) for q in hot_queries(lo, hi, 10)])
    assert fe.stats()["cache"] is None
    # bounded LRU: 2 entries cannot hold 4 distinct bound-sets
    fe2 = ServeFrontend(build(), FrontendConfig(cache_entries=2,
                                                **PERMISSIVE))
    fe2.run([QueryEvent("a", q) for q in hot_queries(lo, hi, 40)])
    cache = fe2.stats()["cache"]
    assert cache["entries"] <= 2
    assert cache["evictions"] > 0


# ---------------------------------------------------------------------------
# Admission control + overflow policies
# ---------------------------------------------------------------------------

def test_token_bucket_admission_throttles_per_tenant(tenant_data, bounds):
    lo, hi = bounds
    d = tenant_data["t0"]
    space = [build_default_layout(0, d, 8)]
    fleet = FleetEngine({"a": LayoutEngine(
        ThresholdSwitchPolicy(space, alpha=10.0, threshold=1e9),
        InMemoryBackend(d), delta=2)})
    fe = ServeFrontend(fleet, FrontendConfig(
        admission_rate=0.5, admission_capacity=1.0, admission_initial=1.0,
        queue_capacity=1000, breaker_open_frac=None, record_latency=False))
    qs = hot_queries(lo, hi, 10)
    outcomes = [fe.submit(QueryEvent("a", q)) for q in qs]
    assert any(not r.admitted and r.reason == "throttled"
               for r in outcomes)
    assert fe.stats()["throttled"] >= 1
    # blocking submit terminates (rate > 0 refills per attempt) and
    # nothing that was admitted is ever lost
    for q in qs:
        assert fe.submit_blocking(QueryEvent("a", q)).admitted
    fe.flush()
    assert fe.stats()["processed"] == fe.stats()["admitted"]
    assert fe.queue_depth == 0


def test_admission_rate_zero_rejected_by_config():
    with pytest.raises(ValueError, match="admission_rate"):
        FrontendConfig(admission_rate=0.0)
    with pytest.raises(ValueError, match="overflow_policy"):
        FrontendConfig(overflow_policy="drop")
    with pytest.raises(ValueError, match="breaker_open_frac"):
        FrontendConfig(breaker_open_frac=1.5)


def test_overflow_reject_refuses_at_ingress(tenant_data, bounds):
    lo, hi = bounds
    d = tenant_data["t0"]
    space = [build_default_layout(0, d, 8)]
    fleet = FleetEngine({"a": LayoutEngine(
        ThresholdSwitchPolicy(space, alpha=10.0, threshold=1e9),
        InMemoryBackend(d), delta=2)})
    fe = ServeFrontend(fleet, FrontendConfig(
        queue_capacity=4, overflow_policy="reject",
        breaker_open_frac=None, record_latency=False))
    qs = hot_queries(lo, hi, 6)
    outcomes = [fe.submit(QueryEvent("a", q)) for q in qs]
    assert [r.admitted for r in outcomes] == [True] * 4 + [False] * 2
    assert outcomes[-1] == AdmissionResult(False, "queue_full")
    assert fe.stats()["rejected"] == 2
    assert fe.queue_depth == 4          # refused events never enqueued
    fe.flush()
    assert fe.stats()["processed"] == 4


def test_overflow_block_levels_load(tenant_data, bounds):
    lo, hi = bounds
    d = tenant_data["t0"]
    space = [build_default_layout(0, d, 8)]
    fleet = FleetEngine({"a": LayoutEngine(
        ThresholdSwitchPolicy(space, alpha=10.0, threshold=1e9),
        InMemoryBackend(d), delta=2)})
    fe = ServeFrontend(fleet, FrontendConfig(
        queue_capacity=4, overflow_policy="block", pump_chunk=2,
        breaker_open_frac=None, record_latency=False))
    for q in hot_queries(lo, hi, 20):
        assert fe.submit(QueryEvent("a", q)).admitted
        assert fe.queue_depth <= 4      # the bound holds throughout
    fe.flush()
    assert fe.stats()["processed"] == 20


# ---------------------------------------------------------------------------
# SlotBatcher ingress queue (deque fix)
# ---------------------------------------------------------------------------

def test_slot_batcher_queue_is_deque_and_fifo():
    b = SlotBatcher(num_slots=2)
    assert isinstance(b.queue, collections.deque)
    for rid in range(6):
        b.submit(Request(rid, np.zeros(4, np.int32), max_new_tokens=1))
    b.fill_slots()
    b.record_tokens(np.array([1, 1]))     # finishes slots 0/1 (rid 0, 1)
    b.fill_slots()
    b.record_tokens(np.array([2, 2]))
    # strict FIFO through the deque: completion follows submission order
    assert [r.request_id for r in b.completed] == [0, 1, 2, 3]
    assert b.pending == 2


# ---------------------------------------------------------------------------
# Property: any admission-limit setting — shedding only ever defers
# reorg/compaction work; admitted queries are never dropped
# ---------------------------------------------------------------------------

def _sample_admission_config(rng):
    """One arbitrary point in the admission-limit space."""
    open_frac = (None if rng.random() < 0.25
                 else float(rng.uniform(0.2, 0.9)))
    return FrontendConfig(
        queue_capacity=int(rng.integers(4, 65)),
        overflow_policy=("block", "reject")[int(rng.integers(2))],
        admission_rate=(None if rng.random() < 0.25
                        else float(rng.uniform(0.25, 4.0))),
        admission_capacity=float(rng.uniform(1.0, 8.0)),
        breaker_open_frac=open_frac,
        breaker_close_frac=(0.0 if open_frac is None else open_frac / 2),
        breaker_min_open_events=int(rng.integers(0, 33)),
        pump_chunk=int(rng.integers(1, 17)),
        record_latency=False)


@pytest.fixture(scope="module")
def property_workload(tenant_data, bounds):
    lo, hi = bounds
    fs = make_stream("flash_crowd", lo, hi, qpt=40, seed=19)
    ref = build_fleet(fs, tenant_data, "flash_crowd",
                      lambda: KConcurrentScheduler(1)).run(fs)
    return fs, {tid: ref.per_tenant[tid] for tid in fs.tenant_ids}


@pytest.mark.parametrize("case", range(10))
def test_any_admission_setting_never_drops_queries(case, property_workload,
                                                   tenant_data):
    """Seeded property sweep (the repo's property idiom when hypothesis
    is unavailable; cf. tests/test_wal.py): under ANY admission-limit
    setting, shedding only ever defers reorg/compaction work — admitted
    queries are never dropped and the α-charge ledger stays that of the
    unshedded reference."""
    fs, ref = property_workload
    fleet = build_fleet(fs, tenant_data, "flash_crowd",
                        lambda: KConcurrentScheduler(1))
    fe = ServeFrontend(fleet,
                       _sample_admission_config(
                           np.random.default_rng(1000 + case)))
    got = fe.run(fs)
    stats = fe.stats()
    for tid in fs.tenant_ids:
        # every admitted query was served: zero drops under ANY setting
        assert len(got.per_tenant[tid].query_costs) == 40
        # shedding is *only* reorg deferral: the charge ledger and the
        # decision trace are those of the unshedded reference
        assert got.per_tenant[tid].reorg_indices == ref[tid].reorg_indices
        assert np.array_equal(got.per_tenant[tid].state_seq,
                              ref[tid].state_seq)
    assert stats["processed"] == len(fs.events)
    assert fe.queue_depth == 0
    # breaker hysteresis is consistent: anything opened either re-closed
    # or is still flagged open — never a close without an open
    if stats["breaker"] is not None:
        b = stats["breaker"]
        assert b["closes"] == b["opens"] - (1 if b["is_open"] else 0)
