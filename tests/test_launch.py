"""Launch-layer tests: logical-spec resolution + HLO cost parser."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_cost
from repro.launch import mesh as mesh_lib


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------

def test_resolve_spec_single_pod():
    assert mesh_lib.resolve_spec(P("fsdp", "model"), False) == \
        P("data", "model")
    assert mesh_lib.resolve_spec(P("batch", None), False) == P("data", None)
    assert mesh_lib.resolve_spec(P(None, "batch", "seq2"), False) == \
        P(None, "data", ("data", "model"))


def test_resolve_spec_multi_pod():
    assert mesh_lib.resolve_spec(P("batch", None), True) == \
        P(("pod", "data"), None)
    assert mesh_lib.resolve_spec(P("fsdp", "model"), True) == \
        P("data", "model")


def test_resolve_tree_preserves_structure():
    tree = {"a": P("batch"), "b": {"c": P(None, "model")}}
    out = mesh_lib.resolve_tree(tree, False)
    assert out["a"] == P("data")
    assert out["b"]["c"] == P(None, "model")


def test_batch_axes():
    assert mesh_lib.batch_axes(False) == ("data",)
    assert mesh_lib.batch_axes(True) == ("pod", "data")


# ---------------------------------------------------------------------------
# HLO cost parser: trip-count weighting on a known program
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_scan_trip_counts():
    """A scan of N matmuls must report ~N x the flops of one matmul."""
    d, n_iters = 64, 10

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((8, d), jnp.float32)
    ws = jnp.ones((n_iters, d, d), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    rec = hlo_cost.analyze(compiled.as_text())
    one_matmul = 2 * 8 * d * d
    assert rec["flops_per_device"] == pytest.approx(n_iters * one_matmul,
                                                    rel=0.05)


def test_hlo_cost_no_loops():
    def f(a, b):
        return a @ b

    a = jnp.ones((32, 16), jnp.float32)
    b = jnp.ones((16, 8), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    rec = hlo_cost.analyze(compiled.as_text())
    assert rec["flops_per_device"] == pytest.approx(2 * 32 * 16 * 8, rel=0.01)
    # bytes: at least inputs + outputs once
    assert rec["bytes_per_device"] >= (32 * 16 + 16 * 8 + 32 * 8) * 4


def test_hlo_cost_nested_scans_multiply():
    d, outer, inner = 32, 4, 5

    def f(x, ws):
        def outer_body(x, wgrp):
            def inner_body(x, w):
                return x @ w, None
            out, _ = jax.lax.scan(inner_body, x, wgrp)
            return out, None
        out, _ = jax.lax.scan(outer_body, x, ws)
        return out

    x = jnp.ones((4, d), jnp.float32)
    ws = jnp.ones((outer, inner, d, d), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    rec = hlo_cost.analyze(compiled.as_text())
    assert rec["flops_per_device"] == pytest.approx(
        outer * inner * 2 * 4 * d * d, rel=0.05)


def test_shape_bytes_parser():
    assert hlo_cost._shape_bytes("bf16[2,3]{1,0}") == 12
    assert hlo_cost._shape_bytes("(f32[4], s8[8])") == 24
    assert hlo_cost._shape_bytes("pred[]") == 1      # scalar: one element
