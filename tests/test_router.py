"""Tests for the sharded routing plane (repro.engine.router + fleet
migration hooks).

Covers: golden bit-identity of the 1-shard router vs a plain FleetEngine
(all 10 scenarios — 5 drift + 5 ingest — under all 3 schedulers),
multi-shard trace identity under the unlimited scheduler, live tenant
migration mid-stream with bitwise-preserved traces and α charge ledgers
(including an in-flight incremental migration transplanted with its
partially-summed ledger — the FleetEngine.remove_tenant regression),
the EventSink protocol (ServeFrontend over a router ≡ over a fleet),
declarative SchedulerSpec construction with the single-use instance
shim, hysteresis-gated load rebalancing, and the process-parallel
ProcessShardSet agreeing with the inline router.
"""
import functools

import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, make_generator,
                        workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario, make_ingest_scenario
from repro.engine import (EventSink, FleetEngine, FleetRouter, IngestConfig,
                          InMemoryBackend, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, RebalanceConfig,
                          SchedulerSpec, TokenBucketScheduler,
                          UnlimitedScheduler, as_scheduler_spec)
from repro.serve import FrontendConfig, ServeFrontend


# ---------------------------------------------------------------------------
# Helpers / fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(700 + t).uniform(
        0, 100, size=(2_000, 5)) for t in range(8)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, ingest=None, incremental=False, rows_per_tick=None,
                alpha=10.0, delta=5, seed=2):
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta,
                        ingest=ingest, incremental=incremental,
                        rows_per_tick=rows_per_tick)


SCHEDULER_SPECS = [
    ("unlimited", SchedulerSpec.unlimited()),
    ("k1", SchedulerSpec.k_concurrent(1)),
    ("bucket", SchedulerSpec.token_bucket(rate=0.01, capacity=1.0,
                                          initial=0.0)),
]

DRIFT_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                   "flash_crowd", "template_churn"]
INGEST_SCENARIOS = ["trickle", "append_heavy", "mixed_rw", "ingest_burst",
                    "bulk_load"]


def make_stream(scenario, lo, hi, num_tenants=2, qpt=60, seed=7):
    if scenario in DRIFT_SCENARIOS:
        return make_drift_scenario(scenario, lo, hi,
                                   num_tenants=num_tenants,
                                   queries_per_tenant=qpt, seed=seed)
    return make_ingest_scenario(scenario, lo, hi, num_tenants=num_tenants,
                                queries_per_tenant=qpt, seed=seed)


def make_tenants(fs, tenant_data, scenario, **engine_kw):
    ingest = IngestConfig() if scenario in INGEST_SCENARIOS else None
    return {tid: oreo_engine(tenant_data[tid], ingest=ingest, **engine_kw)
            for tid in fs.tenant_ids}


def assert_same_trace(a, b):
    assert np.array_equal(a.query_costs, b.query_costs)
    assert a.reorg_indices == b.reorg_indices
    assert np.array_equal(a.state_seq, b.state_seq)


# ---------------------------------------------------------------------------
# Golden identity: 1-shard router == plain fleet, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", DRIFT_SCENARIOS + INGEST_SCENARIOS)
def test_one_shard_router_bit_identical_to_fleet(scenario, tenant_data,
                                                 bounds):
    """All 10 scenarios x all 3 schedulers: a 1-shard router is trace-
    bitwise invisible — per-tenant traces, deferral counters, and the
    scheduler stats all equal the plain fleet's."""
    lo, hi = bounds
    for _, spec in SCHEDULER_SPECS:
        fs = make_stream(scenario, lo, hi)
        ref = FleetEngine(make_tenants(fs, tenant_data, scenario),
                          spec.build()).run(fs)
        router = FleetRouter(make_tenants(fs, tenant_data, scenario),
                             num_shards=1, scheduler=spec)
        got = router.run(fs)
        for tid in fs.tenant_ids:
            assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
        assert ref.ticks == got.ticks
        assert ref.swaps_deferred == got.swaps_deferred
        assert ref.deferred_ticks == got.deferred_ticks
        assert ref.scheduler_stats == got.scheduler_stats
        assert ref.scheduler == got.scheduler


def test_multi_shard_router_matches_unsharded_unlimited(tenant_data,
                                                        bounds):
    """Under the unlimited scheduler sharding is invisible: 8 tenants
    over 4 shards reproduce the unsharded traces bitwise, with the
    fleet counters summing across shards."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=8,
                             queries_per_tenant=80, seed=7)
    ref = FleetEngine(make_tenants(fs, tenant_data, "sudden_shift")).run(fs)
    router = FleetRouter(make_tenants(fs, tenant_data, "sudden_shift"),
                         num_shards=4)
    got = router.run(fs)
    assert len(set(router.placement().values())) > 1   # actually sharded
    for tid in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
    assert got.ticks == ref.ticks
    assert set(got.scheduler_stats["shards"]) == set(router.shard_ids)


def test_router_run_batched_matches_run(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("gradual_drift", lo, hi, num_tenants=4,
                             queries_per_tenant=60, seed=3)
    a = FleetRouter(make_tenants(fs, tenant_data, "gradual_drift"),
                    num_shards=2).run(fs)
    b = FleetRouter(make_tenants(fs, tenant_data, "gradual_drift"),
                    num_shards=2).run_batched(fs)
    for tid in fs.tenant_ids:
        assert np.array_equal(a.per_tenant[tid].query_costs,
                              b.per_tenant[tid].query_costs)
        assert np.array_equal(a.per_tenant[tid].state_seq,
                              b.per_tenant[tid].state_seq)


def test_router_topology_and_validation(tenant_data):
    with pytest.raises(ValueError, match="at least one tenant"):
        FleetRouter({})
    tenants = {tid: oreo_engine(d) for tid, d in tenant_data.items()}
    router = FleetRouter(tenants, num_shards=4)
    assert router.shard_ids == ["s0", "s1", "s2", "s3"]
    assert router.num_shards == 4
    assert sorted(router.tenant_ids) == sorted(tenant_data)
    placement = router.placement()
    for tid, sid in placement.items():
        assert router.shard_of(tid) == sid
        assert tid in router.shard(sid).tenant_ids
        assert router.tenant(tid) is tenants[tid]
    with pytest.raises(KeyError):
        router.shard_of("nope")
    with pytest.raises(KeyError):
        router.submit(wl.QueryEvent("nope", wl.Query(
            np.zeros(5), np.ones(5))))
    with pytest.raises(KeyError):
        router.migrate_tenant("t0", "s9")


def test_router_rejects_mixed_incremental_modes(tenant_data):
    tenants = {"t0": oreo_engine(tenant_data["t0"]),
               "t1": oreo_engine(tenant_data["t1"], incremental=True)}
    with pytest.raises(ValueError, match="mix incremental and atomic"):
        FleetRouter(tenants, num_shards=2)


# ---------------------------------------------------------------------------
# Live migration: traces and charge ledgers survive re-sharding bitwise
# ---------------------------------------------------------------------------

def test_migration_mid_stream_preserves_traces_bitwise(tenant_data, bounds):
    """Move half the tenants between shards mid-stream; every per-tenant
    trace still equals the never-sharded run bit for bit, and submits
    after the move route to the new home via a directory override."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=8,
                             queries_per_tenant=80, seed=7)
    ref = FleetEngine(make_tenants(fs, tenant_data, "sudden_shift")).run(fs)
    router = FleetRouter(make_tenants(fs, tenant_data, "sudden_shift"),
                         num_shards=4)
    events = list(fs)
    half = len(events) // 2
    for ev in events[:half]:
        router.submit(ev)
    router.drain()
    moved = []
    for tid in fs.tenant_ids[:4]:
        src = router.shard_of(tid)
        dst = next(s for s in router.shard_ids if s != src)
        assert router.migrate_tenant(tid, dst)
        assert router.shard_of(tid) == dst
        moved.append(tid)
    assert router.migrations == 4
    assert not router.migrate_tenant(moved[0], router.shard_of(moved[0]))
    for ev in events[half:]:
        router.submit(ev)
    router.drain()
    got = router.result()
    for tid in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
    stats = router.stats()
    assert stats["migrations"] == 4
    assert stats["queue_depth"] == 0


def test_migration_carries_queued_events(tenant_data, bounds):
    """Events already queued for the tenant move with it (taken from the
    source inbox, replayed on the target) — nothing is lost or
    reordered."""
    lo, hi = bounds
    fs = make_drift_scenario("cyclic_diurnal", lo, hi, num_tenants=4,
                             queries_per_tenant=60, seed=5)
    ref = FleetEngine(make_tenants(fs, tenant_data, "cyclic_diurnal")).run(fs)
    router = FleetRouter(make_tenants(fs, tenant_data, "cyclic_diurnal"),
                         num_shards=2)
    for ev in fs:                       # queue everything, drain nothing
        router.submit(ev)
    tid = fs.tenant_ids[0]
    src = router.shard_of(tid)
    dst = next(s for s in router.shard_ids if s != src)
    assert router.migrate_tenant(tid, dst)
    router.drain()
    got = router.result()
    for t in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[t], got.per_tenant[t])


def test_remove_tenant_refuses_queued_inbox_events(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=2,
                             queries_per_tenant=10, seed=1)
    fleet = FleetEngine(make_tenants(fs, tenant_data, "sudden_shift"))
    for ev in fs:
        fleet.submit(ev)
    tid = fs.tenant_ids[0]
    with pytest.raises(ValueError, match="take_inbox"):
        fleet.remove_tenant(tid)
    inbox = fleet.take_inbox(tid)
    assert [ev.tenant_id for ev in inbox] == [tid] * len(inbox)
    assert fleet.queue_depth == len(list(fs)) - len(inbox)
    fleet.remove_tenant(tid)            # now legal
    assert tid not in fleet.tenant_ids


# ---------------------------------------------------------------------------
# The remove_tenant regression: detach mid-(incremental)-migration
# ---------------------------------------------------------------------------

def drive_until_in_flight(fleet, tid, events):
    """Feed events one at a time until ``tid`` has a partially-charged
    in-flight incremental migration; returns the remaining events."""
    events = list(events)
    while events:
        fleet.submit(events.pop(0))
        fleet.drain()
        ex = fleet.tenant(tid).reorg_executor
        active = ex.active
        if active is not None and 0.0 < active.charged < active.alpha:
            return events
    raise AssertionError("no partially-charged migration materialized")


def test_detach_mid_migration_transplants_partial_ledger(tenant_data,
                                                         bounds):
    """Detach a tenant while an incremental migration is in flight with a
    partially-summed charge ledger, re-attach it to a second fleet, and
    finish the stream there: the trace and every MigrationRecord charge
    ledger are bitwise identical to the never-detached run, with each
    ledger still telescoping to exactly α."""
    lo, hi = bounds
    tid = "t0"
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=1,
                             queries_per_tenant=200, seed=9)
    events = list(fs)
    def make():
        return FleetEngine({tid: oreo_engine(
            tenant_data[tid], incremental=True, rows_per_tick=40)})

    ref_fleet = make()
    ref = ref_fleet.run(events)

    fleet1 = make()
    remaining = drive_until_in_flight(fleet1, tid, events)
    record = fleet1.tenant(tid).reorg_executor.active
    partial = list(record.charges)
    assert 0.0 < record.charged < record.alpha

    engine = fleet1.remove_tenant(tid)
    assert tid not in fleet1.tenant_ids
    assert engine.reorg_executor.active is record       # still in flight

    fleet2 = FleetEngine({}, incremental=True)
    fleet2.add_tenant(tid, engine)
    for ev in remaining:
        fleet2.submit(ev)
    fleet2.drain()
    got = fleet2.result()

    assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
    ref_migs = ref_fleet.tenant(tid).reorg_executor.migrations
    got_migs = fleet2.tenant(tid).reorg_executor.migrations
    assert len(ref_migs) == len(got_migs)
    for a, b in zip(ref_migs, got_migs):
        assert a.charges == b.charges                   # bitwise ledger
        assert a.completed_at == b.completed_at
        if b.completed_at >= 0:
            assert b.charged == b.alpha                 # telescopes to α
    # the transplanted record kept its pre-detach prefix untouched
    assert any(m.charges[:len(partial)] == partial for m in got_migs)


def test_detach_with_finish_closes_ledger_on_alpha(tenant_data, bounds):
    """remove_tenant(finish=True) completes the in-flight migration at
    the detach index; the ledger closes bitwise on α and the tenant is
    immediately re-attachable with no executor state in flight."""
    lo, hi = bounds
    tid = "t0"
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=1,
                             queries_per_tenant=200, seed=9)
    fleet1 = FleetEngine({tid: oreo_engine(
        tenant_data[tid], incremental=True, rows_per_tick=40)})
    remaining = drive_until_in_flight(fleet1, tid, list(fs))
    record = fleet1.tenant(tid).reorg_executor.active
    detach_index = fleet1.tenant(tid)._index

    engine = fleet1.remove_tenant(tid, finish=True)
    assert engine.reorg_executor.active is None
    assert record.charged == record.alpha               # closed bitwise
    assert record.completed_at == detach_index
    assert sum(rows for _, rows, _ in record.charges) == record.total_rows

    fleet2 = FleetEngine({}, incremental=True)
    fleet2.add_tenant(tid, engine)
    for ev in remaining:
        fleet2.submit(ev)
    fleet2.drain()
    res = fleet2.result().per_tenant[tid]
    costs = np.asarray(res.query_costs)
    assert np.all((costs >= 0) & (costs <= 1))
    for mig in fleet2.tenant(tid).reorg_executor.migrations:
        if mig.completed_at >= 0:
            assert mig.charged == mig.alpha


def test_router_migration_of_incremental_tenants_bitwise(tenant_data,
                                                         bounds):
    """End to end through the router: incremental tenants with a tight
    row budget, migrated mid-stream, still reproduce the unsharded
    traces and ledgers bitwise."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                             queries_per_tenant=100, seed=11)
    def make():
        return {tid: oreo_engine(tenant_data[tid], incremental=True,
                                 rows_per_tick=60)
                for tid in fs.tenant_ids}

    ref_fleet = FleetEngine(make())
    ref = ref_fleet.run(fs)
    router = FleetRouter(make(), num_shards=2)
    events = list(fs)
    third = len(events) // 3
    for ev in events[:third]:
        router.submit(ev)
    router.drain()
    for tid in fs.tenant_ids:
        src = router.shard_of(tid)
        dst = next(s for s in router.shard_ids if s != src)
        router.migrate_tenant(tid, dst)
    for ev in events[third:]:
        router.submit(ev)
    router.drain()
    got = router.result()
    for tid in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
        a = ref_fleet.tenant(tid).reorg_executor.migrations
        b = router.tenant(tid).reorg_executor.migrations
        assert [m.charges for m in a] == [m.charges for m in b]


# ---------------------------------------------------------------------------
# EventSink: the serving tier sits over a fleet or a router unchanged
# ---------------------------------------------------------------------------

PERMISSIVE = dict(queue_capacity=100_000, breaker_open_frac=None,
                  record_latency=False)


def test_fleet_and_router_satisfy_event_sink(tenant_data):
    fleet = FleetEngine({"t0": oreo_engine(tenant_data["t0"])})
    router = FleetRouter({"t0": oreo_engine(tenant_data["t0"])})
    assert isinstance(fleet, EventSink)
    assert isinstance(router, EventSink)
    assert fleet.shard_fleets() == [fleet]
    assert router.shard_fleets() == [router.shard("s0")]


def test_frontend_over_one_shard_router_matches_fleet(tenant_data, bounds):
    """ServeFrontend(FleetRouter) at 1 shard ≡ ServeFrontend(FleetEngine):
    the serving tier cannot tell them apart, trace-bitwise."""
    lo, hi = bounds
    for scenario in ("sudden_shift", "trickle"):
        fs = make_stream(scenario, lo, hi)
        fleet = FleetEngine(make_tenants(fs, tenant_data, scenario))
        ref = ServeFrontend(fleet, FrontendConfig(**PERMISSIVE)).run(fs)
        router = FleetRouter(make_tenants(fs, tenant_data, scenario))
        got = ServeFrontend(router, FrontendConfig(**PERMISSIVE)).run(fs)
        for tid in fs.tenant_ids:
            assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
        assert ref.scheduler_stats == got.scheduler_stats


def test_frontend_over_multi_shard_router(tenant_data, bounds):
    """A multi-shard router behind the frontend still reproduces the
    unsharded traces (unlimited scheduler), and the frontend's
    scheduler stats nest per shard."""
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                             queries_per_tenant=60, seed=7)
    fleet = FleetEngine(make_tenants(fs, tenant_data, "sudden_shift"))
    ref = ServeFrontend(fleet, FrontendConfig(**PERMISSIVE)).run(fs)
    router = FleetRouter(make_tenants(fs, tenant_data, "sudden_shift"),
                         num_shards=2)
    fe = ServeFrontend(router, FrontendConfig(**PERMISSIVE))
    got = fe.run(fs)
    for tid in fs.tenant_ids:
        assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
    sched = fe.stats()["scheduler"]
    assert len(sched["shards"]) == 2    # per-shard scheduler stats nest


# ---------------------------------------------------------------------------
# SchedulerSpec: declarative construction + the single-use instance shim
# ---------------------------------------------------------------------------

def test_scheduler_spec_builds_fresh_instances():
    spec = SchedulerSpec.k_concurrent(2)
    a, b = spec.build(), spec.build()
    assert a is not b
    assert isinstance(a, KConcurrentScheduler)
    assert a.k == 2
    assert spec.name == a.name
    bucket = SchedulerSpec.token_bucket(rate=0.5, capacity=2.0,
                                        initial=1.0)
    sched = bucket.build()
    assert isinstance(sched, TokenBucketScheduler)
    assert isinstance(SchedulerSpec.unlimited().build(),
                      UnlimitedScheduler)
    with pytest.raises(ValueError, match="unknown scheduler kind"):
        SchedulerSpec(kind="nope").build()


def test_fleet_engine_accepts_spec(tenant_data):
    fleet = FleetEngine({"t0": oreo_engine(tenant_data["t0"])},
                        SchedulerSpec.k_concurrent(1))
    assert isinstance(fleet.scheduler, KConcurrentScheduler)


def test_instance_shim_warns_and_is_single_use(tenant_data):
    with pytest.warns(DeprecationWarning, match="SchedulerSpec"):
        shim = as_scheduler_spec(KConcurrentScheduler(1))
    built = shim.build()
    assert isinstance(built, KConcurrentScheduler)
    with pytest.raises(ValueError, match="cannot be shared"):
        shim.build()
    with pytest.raises(TypeError):
        as_scheduler_spec(object())


def test_router_with_instance_scheduler_refuses_multiple_shards(
        tenant_data):
    """A bare scheduler instance cannot be shared across shards — the
    single-use shim lets a 1-shard router keep working and makes a
    multi-shard router fail loudly instead of silently sharing state."""
    def tenants():
        return {tid: oreo_engine(d)
                for tid, d in list(tenant_data.items())[:4]}

    with pytest.warns(DeprecationWarning):
        router = FleetRouter(tenants(), num_shards=1,
                             scheduler=KConcurrentScheduler(1))
    assert isinstance(router.shard("s0").scheduler, KConcurrentScheduler)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="cannot be shared"):
            FleetRouter(tenants(), num_shards=2,
                        scheduler=KConcurrentScheduler(1))


# ---------------------------------------------------------------------------
# Load-skew rebalancing: hysteresis-gated, at drain boundaries only
# ---------------------------------------------------------------------------

def test_rebalancer_moves_hot_tenant_once(tenant_data, bounds):
    """Skew every event onto one shard: after a full window the meter
    fires exactly once (hysteresis disarms), the move lands as a
    directory override, and traffic follows the tenant."""
    lo, hi = bounds
    tenants = {tid: oreo_engine(d) for tid, d in tenant_data.items()}
    cfg = RebalanceConfig(window=64, high=1.3, low=1.05)
    router = FleetRouter(tenants, num_shards=2, rebalance=cfg)
    by_shard = {}
    for tid in router.tenant_ids:
        by_shard.setdefault(router.shard_of(tid), []).append(tid)
    hot = max(by_shard, key=lambda s: len(by_shard[s]))
    assert len(by_shard[hot]) >= 2      # 8 tenants over 2 shards
    rng = np.random.default_rng(3)

    def q():
        lo_q = rng.uniform(lo, hi)
        return wl.Query(lo_q, np.minimum(lo_q + 5.0, hi))

    # two windows of traffic pinned to the hot shard, spread over its
    # tenants so the hottest tenant's share fits under the mean
    for _ in range(3):
        for _ in range(cfg.window):
            for tid in by_shard[hot]:
                router.submit(wl.QueryEvent(tid, q()))
        router.drain()
    assert router.migrations == 1       # armed once, then disarmed
    overrides = router.directory.overrides
    assert len(overrides) == 1
    moved_tid, new_home = next(iter(overrides.items()))
    assert new_home != hot
    assert router.shard_of(moved_tid) == new_home
    stats = router.stats()
    assert stats["rebalancer"]["moves_suggested"] == 1
    assert stats["rebalancer"]["armed"] is False
    # traffic now follows the override
    router.submit(wl.QueryEvent(moved_tid, q()))
    assert router.shard(new_home).queue_depth == 1


def test_rebalancer_idle_without_config(tenant_data, bounds):
    lo, hi = bounds
    router = FleetRouter({tid: oreo_engine(d)
                          for tid, d in tenant_data.items()}, num_shards=2)
    assert router.maybe_rebalance() is None
    assert router.stats()["rebalancer"] is None


# ---------------------------------------------------------------------------
# Process-parallel shards (repro.launch.shard_host)
# ---------------------------------------------------------------------------

def _make_tenant_engine(seed):
    """Module-level so spawn workers can unpickle it."""
    data = np.random.default_rng(700 + seed).uniform(
        0, 100, size=(2_000, 5))
    return oreo_engine(data)


def test_process_shard_set_matches_inline_router(tenant_data, bounds):
    """Two spawned shard processes under the router's placement produce
    the same merged result as the inline router — and migration works
    across process boundaries."""
    shard_host = pytest.importorskip("repro.launch.shard_host")
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                             queries_per_tenant=40, seed=7)
    factories = {f"t{t}": functools.partial(_make_tenant_engine, t)
                 for t in range(4)}
    inline = FleetRouter({tid: f() for tid, f in factories.items()},
                         num_shards=2)
    ref = inline.run(fs)
    with shard_host.ProcessShardSet(factories, num_shards=2) as procs:
        assert procs.shard_ids == inline.shard_ids
        for tid in factories:
            assert procs.shard_of(tid) == inline.shard_of(tid)
        for ev in fs:
            procs.submit(ev)
        procs.drain()
        got = procs.result()
        for tid in fs.tenant_ids:
            assert_same_trace(ref.per_tenant[tid], got.per_tenant[tid])
        assert got.ticks == ref.ticks
        # migrate one tenant across processes and keep serving
        tid = fs.tenant_ids[0]
        dst = next(s for s in procs.shard_ids if s != procs.shard_of(tid))
        assert procs.migrate_tenant(tid, dst)
        assert procs.shard_of(tid) == dst
        extra = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                                    queries_per_tenant=10, seed=8)
        for ev in extra:
            procs.submit(ev)
        assert procs.drain() == len(list(extra))
        assert procs.stats()["migrations"] == 1
