"""Tests for the crash-safe manifest WAL (repro.data.wal).

The property the ingest plane's durability rests on: recovery is a pure
left fold over the logged records, so replay is idempotent and
crash-point-invariant — for ANY prefix of the log, replaying the prefix
(the "crash") and then continuing with the remaining records yields a
manifest bitwise equal to the uninterrupted run's.  Exercised both as a
Hypothesis property (when hypothesis is installed) and as a seeded
deterministic sweep (always).
"""
import json
import os

import numpy as np
import pytest

from repro.data.wal import (INITIAL_STATE, ManifestWAL, apply_record,
                            canonical_manifest, replay_records)


def _manifest(k, p):
    return {"num_partitions": p,
            "mins": [[float(k)]] * p, "maxs": [[float(k + 1)]] * p,
            "rows": [1] * p, "layout": f"L{k}"}


def _random_records(rng, n):
    """A plausible mutation history: swaps, deltas, migrations."""
    records = []
    batch_id = 0
    for k in range(n):
        roll = rng.integers(0, 4)
        if roll == 0:
            records.append({"op": "init" if not records else "swap",
                            "store": f"v{k:05d}",
                            "manifest": _manifest(k, int(rng.integers(1, 4)))})
        elif roll == 1:
            records.append({"op": "append_delta", "batch_id": batch_id,
                            "file": f"delta_{batch_id:05d}.npz",
                            "mins": [float(rng.integers(0, 5))],
                            "maxs": [float(rng.integers(5, 10))],
                            "rows": int(rng.integers(1, 50))})
            batch_id += 1
        elif roll == 2:
            records.append({"op": "migration_begin", "store": f"m{k:05d}",
                            "target_state": int(rng.integers(0, 6)),
                            "num_targets": int(rng.integers(1, 8))})
        else:
            records.append({"op": "migration_apply",
                            "done": [int(j) for j in
                                     rng.integers(0, 8,
                                                  int(rng.integers(1, 4)))]})
    return records


# ---------------------------------------------------------------------------
# Reducer semantics
# ---------------------------------------------------------------------------

def test_apply_record_is_pure():
    state = dict(INITIAL_STATE)
    before = canonical_manifest(state)
    apply_record(state, {"op": "append_delta", "batch_id": 0, "file": "f",
                         "mins": [0.0], "maxs": [1.0], "rows": 3})
    assert canonical_manifest(state) == before      # input untouched


def test_swap_clears_deltas_and_migration():
    records = [
        {"op": "init", "store": "v1", "manifest": _manifest(0, 2)},
        {"op": "append_delta", "batch_id": 0, "file": "d0",
         "mins": [0.0], "maxs": [1.0], "rows": 5},
        {"op": "migration_begin", "store": "v2", "target_state": 3,
         "num_targets": 4},
        {"op": "migration_apply", "done": [1, 2]},
        {"op": "swap", "store": "v2", "manifest": _manifest(1, 4)},
    ]
    state = replay_records(records)
    assert state["serving"] == "v2"
    assert state["deltas"] == [] and state["migration"] is None
    mid = replay_records(records[:4])
    assert [d["batch_id"] for d in mid["deltas"]] == [0]
    assert mid["migration"]["done"] == [1, 2]


def test_migration_apply_accumulates_sorted_union():
    state = replay_records([
        {"op": "migration_begin", "store": "m", "target_state": 0,
         "num_targets": 8},
        {"op": "migration_apply", "done": [5, 2]},
        {"op": "migration_apply", "done": [2, 7]},
    ])
    assert state["migration"]["done"] == [2, 5, 7]


def test_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown WAL op"):
        apply_record(dict(INITIAL_STATE), {"op": "frobnicate"})


# ---------------------------------------------------------------------------
# File-level WAL
# ---------------------------------------------------------------------------

def test_wal_roundtrip_matches_pure_fold(tmp_path):
    rng = np.random.default_rng(0)
    records = _random_records(rng, 40)
    wal = ManifestWAL(str(tmp_path / "wal"), snapshot_every=7)
    for r in records:
        wal.append(r)
    assert (canonical_manifest(wal.replay())
            == canonical_manifest(replay_records(records)))
    # reopening (a "restart") replays to the same state
    again = ManifestWAL(str(tmp_path / "wal"), snapshot_every=7)
    assert (canonical_manifest(again.replay())
            == canonical_manifest(replay_records(records)))


def test_wal_snapshot_bounds_replay(tmp_path):
    wal = ManifestWAL(str(tmp_path / "wal"), snapshot_every=5)
    records = _random_records(np.random.default_rng(1), 23)
    for r in records:
        wal.append(r)
    assert os.path.exists(str(tmp_path / "wal" / ManifestWAL.SNAPSHOT))
    applied, snap_state = wal._snapshot_point()
    assert applied >= 20                    # 4 snapshots happened
    # snapshot + tail fold == full fold
    assert (canonical_manifest(wal.replay())
            == canonical_manifest(replay_records(records)))
    # and the snapshot itself is a faithful prefix fold
    assert (canonical_manifest(snap_state)
            == canonical_manifest(replay_records(records[:applied])))


def test_wal_drops_torn_tail(tmp_path):
    wal = ManifestWAL(str(tmp_path / "wal"), snapshot_every=1000)
    records = _random_records(np.random.default_rng(2), 10)
    for r in records:
        wal.append(r)
    with open(wal._log_path, "a") as f:
        f.write('{"op": "swap", "store": "vXX", "manif')   # crash mid-append
    reopened = ManifestWAL(str(tmp_path / "wal"), snapshot_every=1000)
    assert len(reopened.records()) == 10
    assert (canonical_manifest(reopened.replay())
            == canonical_manifest(replay_records(records)))
    # continuing after the torn tail is NOT supported on the same file
    # (the torn line would corrupt the next append) — the backends only
    # reopen a WAL at recovery time, never to keep writing; what matters
    # is that replay is unharmed.


def test_wal_removes_torn_snapshot_tmp(tmp_path):
    root = tmp_path / "wal"
    root.mkdir()
    torn = root / (ManifestWAL.SNAPSHOT + ".tmp")
    torn.write_text('{"applied": 3, "sta')          # crash mid-snapshot
    wal = ManifestWAL(str(root))
    assert not torn.exists()
    assert canonical_manifest(wal.replay()) == canonical_manifest(
        json.loads(json.dumps(INITIAL_STATE)))


# ---------------------------------------------------------------------------
# S2: replay is idempotent and crash-point-invariant
# ---------------------------------------------------------------------------

def _crash_then_continue(root, records, cut, snapshot_every):
    """Write a prefix, 'crash' (drop the handle), recover by replaying,
    then continue appending through the recovered WAL.  Returns the final
    replayed state's canonical bytes."""
    wal = ManifestWAL(root, snapshot_every=snapshot_every)
    for r in records[:cut]:
        wal.append(r)
    del wal                                         # the crash
    recovered = ManifestWAL(root, snapshot_every=snapshot_every)
    mid = recovered.replay()
    # replay is idempotent: folding again changes nothing
    assert canonical_manifest(recovered.replay()) == canonical_manifest(mid)
    # and the recovered state is exactly the prefix fold
    assert (canonical_manifest(mid)
            == canonical_manifest(replay_records(records[:cut])))
    for r in records[cut:]:
        recovered.append(r)
    return canonical_manifest(recovered.replay())


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_replay_crash_point_invariant_sweep(tmp_path, seed):
    """Deterministic sweep of the S2 property: every crash point of a
    random history replays-then-continues to the uninterrupted fold,
    bitwise, across snapshot cadences."""
    rng = np.random.default_rng(100 + seed)
    records = _random_records(rng, 25)
    oracle = canonical_manifest(replay_records(records))
    for snapshot_every in (1, 3, 1000):
        for cut in range(len(records) + 1):
            root = str(tmp_path / f"wal_{snapshot_every}_{cut}")
            assert _crash_then_continue(root, records, cut,
                                        snapshot_every) == oracle


def test_replay_crash_point_invariant_hypothesis(tmp_path):
    """The same property under Hypothesis-driven histories."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    counter = [0]

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 40),
           cut_frac=st.floats(0.0, 1.0), snapshot_every=st.integers(1, 9))
    def prop(seed, n, cut_frac, snapshot_every):
        records = _random_records(np.random.default_rng(seed), n)
        cut = int(round(cut_frac * len(records)))
        counter[0] += 1
        root = str(tmp_path / f"hyp_{counter[0]}")
        assert (_crash_then_continue(root, records, cut, snapshot_every)
                == canonical_manifest(replay_records(records)))

    prop()
