"""Tests for the streaming ingest plane (repro.engine.ingest).

Covers delta partitions and their immediate scan visibility, the
clustering-debt meter and debt-triggered compactions (atomic and
incremental), the mixed read/write fleet paths (loop and batched,
bit-identical), the zero-ingest golden identity (S3: ingest enabled but
unused changes nothing, across every drift scenario x scheduler), the
durable DiskBackend WAL recovery, and the PartitionStore orphan-tmp
reclamation (S1).
"""
import json
import os

import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, layouts,
                        make_generator, make_templates, workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import (INGEST_SCENARIOS, IngestBatch,
                                 make_drift_scenario, make_ingest_scenario)
from repro.data.partition_store import PartitionStore
from repro.data.wal import canonical_manifest
from repro.engine import (DebtMeter, DiskBackend, FleetEngine,
                          InMemoryBackend, IngestConfig, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, TokenBucketScheduler,
                          UnlimitedScheduler)
from repro.engine.ingest import DeltaLog


# ---------------------------------------------------------------------------
# Helpers / fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(300 + t).uniform(
        0, 100, size=(2_000, 5)) for t in range(2)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, incremental=False, ingest=None, alpha=10.0, delta=5,
                seed=2, backend=None, sort_col=None):
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data,
                        build_default_layout(0, data, 8, sort_col=sort_col),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, backend or InMemoryBackend(data),
                        delta=cfg.delta, incremental=incremental,
                        ingest=ingest)


def simple_engine(data, ingest=None, incremental=False, alpha=2.0, delta=1,
                  backend=None, **kw):
    return oreo_engine(data, incremental=incremental, ingest=ingest,
                       alpha=alpha, delta=delta, backend=backend, **kw)


def queries_for(rng, data, n, bounded=2):
    tmpl = make_templates(1, data.shape[1], rng,
                          cols_per_template=(bounded, bounded))[0]
    return [tmpl.sample(rng, data.min(0), data.max(0)) for _ in range(n)]


SCHEDULERS = [
    ("unlimited", UnlimitedScheduler),
    ("k1", lambda: KConcurrentScheduler(1)),
    ("bucket", lambda: TokenBucketScheduler(rate=0.01, capacity=1.0,
                                            initial=0.0)),
]

ALL_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                 "flash_crowd", "template_churn"]


def assert_same_trace(a, b):
    assert np.array_equal(a.query_costs, b.query_costs)
    assert a.reorg_indices == b.reorg_indices
    assert np.array_equal(a.state_seq, b.state_seq)


# ---------------------------------------------------------------------------
# S1: PartitionStore reclaims orphaned tmp dirs
# ---------------------------------------------------------------------------

def test_partition_store_reclaims_orphan_tmp(tmp_path):
    """A crash mid-write/mid-reorganize leaves "<root>.tmp" behind; open
    must reclaim it (the live directory was never touched)."""
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 10, size=(200, 2))
    root = str(tmp_path / "store")
    layout = build_default_layout(0, data, 4)
    PartitionStore(root).write(data, layout)

    orphan = tmp_path / "store.tmp"
    orphan.mkdir()
    (orphan / "part_00000.npz").write_bytes(b"partial garbage from a crash")
    (orphan / "manifest.json").write_text('{"torn')

    store = PartitionStore(root)                    # reopen: reclaims
    assert not orphan.exists()
    # the live store is intact and fully usable
    meta = store.metadata()
    assert meta.num_partitions == 4
    out, stats = store.scan(queries_for(rng, data, 1, bounded=1)[0])
    assert stats.partitions_total == 4
    # and a subsequent reorganize stages through a fresh tmp unharmed
    store.reorganize(build_default_layout(1, data, 4, sort_col=1))
    assert store.metadata().num_partitions == 4


# ---------------------------------------------------------------------------
# DeltaLog / DebtMeter units
# ---------------------------------------------------------------------------

def test_delta_log_compose_identity_without_batches():
    rng = np.random.default_rng(1)
    data = rng.uniform(0, 100, size=(500, 3))
    meta = build_default_layout(0, data, 4).materialize(data)
    d = DeltaLog(len(data))
    assert d.compose(meta) is meta          # the zero-ingest identity
    assert d.source_assignment(np.zeros(500, np.int64), 4, 500) is None


def test_delta_log_append_compose_absorb():
    rng = np.random.default_rng(2)
    data = rng.uniform(0, 100, size=(500, 3))
    layout = build_default_layout(0, data, 4)
    meta = layout.materialize(data)
    d = DeltaLog(len(data))
    rows1 = rng.uniform(0, 100, size=(40, 3))
    rows2 = rng.uniform(0, 100, size=(60, 3))
    b1 = d.append(rows1, 500)
    b2 = d.append(rows2, 540)
    assert (b1.batch_id, b2.batch_id) == (0, 1)
    assert d.delta_rows == 100 and d.num_batches == 2
    composed = d.compose(meta)
    assert composed.num_partitions == 6
    assert composed.total_rows == 600
    np.testing.assert_array_equal(composed.mins[4], rows1.min(axis=0))
    np.testing.assert_array_equal(composed.maxs[5], rows2.max(axis=0))
    # source assignment: batch k -> pseudo-partition 4 + k
    assign = d.source_assignment(layout.route(data), 4, 600)
    assert assign.shape == (600,)
    assert set(assign[500:540]) == {4} and set(assign[540:]) == {5}
    # absorbing a prefix keeps later batches pending and bumps generation
    gen = d.generation
    d.absorb_up_to(540)
    assert d.generation == gen + 1
    assert [b.batch_id for b in d.batches] == [1]
    assert d.clustered_len == 540
    d.absorb_up_to(600)
    assert not d.pending and d.compose(meta) is meta


def test_delta_log_rejects_empty_batches():
    d = DeltaLog(10)
    with pytest.raises(ValueError):
        d.append(np.zeros((0, 3)), 10)
    with pytest.raises(ValueError):
        d.append(np.zeros(5), 10)


def test_debt_meter_accrues_only_positive_excess():
    rng = np.random.default_rng(3)
    data = rng.uniform(0, 100, size=(400, 2))
    layout = build_default_layout(0, data, 4)
    meta = layout.materialize(data)
    meter = DebtMeter()
    assert not meter.active
    assert meter.observe(0.5, np.zeros(2), np.ones(2)) == 0.0   # inactive
    rows = rng.uniform(0, 100, size=(50, 2))
    meter.on_append(meta, rows, np.asarray(layout.route(rows), np.int64))
    assert meter.active
    # the compacted table has the same totals as base + batch
    assert meter._compacted.total_rows == 450
    q_lo, q_hi = np.full(2, -np.inf), np.full(2, np.inf)
    ideal = float(layouts.eval_cost(meter._compacted, q_lo, q_hi))
    inc = meter.observe(ideal + 0.25, q_lo, q_hi)
    assert inc == pytest.approx(0.25)
    assert meter.observe(ideal - 0.5, q_lo, q_hi) == 0.0    # clamped at 0
    assert meter.debt == pytest.approx(0.25)
    cfg = IngestConfig(debt_threshold=1.0)
    assert not meter.triggered(alpha=10.0, config=cfg)
    assert meter.triggered(alpha=0.2, config=cfg)
    assert not meter.triggered(alpha=0.2,
                               config=IngestConfig(auto_compact=False))
    meter.reset()
    assert meter.debt == 0.0 and not meter.active


# ---------------------------------------------------------------------------
# Engine-level ingest semantics
# ---------------------------------------------------------------------------

def test_engine_requires_ingest_capable_backend():
    rng = np.random.default_rng(4)
    data = rng.uniform(0, 100, size=(300, 3))
    with pytest.raises(ValueError, match="reference"):
        simple_engine(data, ingest=IngestConfig(),
                      backend=InMemoryBackend(data, compute="reference"))
    eng = simple_engine(data)
    with pytest.raises(RuntimeError, match="without ingest"):
        eng.ingest(np.zeros((2, 3)))


def test_engine_rejects_incremental_ingest_on_disk_backend(tmp_path):
    rng = np.random.default_rng(5)
    data = rng.uniform(0, 100, size=(300, 3))
    backend = DiskBackend(data, str(tmp_path / "d"), background=False)
    with pytest.raises(ValueError, match="delta_source"):
        simple_engine(data, ingest=IngestConfig(), incremental=True,
                      backend=backend)
    backend.close()


def test_ingested_rows_visible_to_next_query():
    """Appended rows raise the very next serve cost by exactly the delta
    partition's contribution (wide bounds -> always scanned)."""
    rng = np.random.default_rng(6)
    data = rng.uniform(0, 100, size=(1000, 3))
    eng = simple_engine(data, ingest=IngestConfig(auto_compact=False))
    queries = queries_for(rng, data, 8)
    for q in queries[:4]:
        eng.step(q)
    before = eng.backend.serve(queries[4])
    eng.ingest(rng.uniform(0, 100, size=(250, 3)))
    after = eng.backend.serve(queries[4])
    # the composed state now carries 1250 rows; the delta batch spans the
    # whole domain so the query cannot skip it
    composed = eng.backend._serving_cache
    assert composed[3] == 1250                      # total rows
    assert after == pytest.approx((before * 1000 + 250) / 1250)
    assert eng.backend.delta_log.pending
    assert eng.ingest_stats()["pending_rows"] == 250


def test_ingest_does_not_advance_query_index():
    rng = np.random.default_rng(7)
    data = rng.uniform(0, 100, size=(500, 3))
    eng = simple_engine(data, ingest=IngestConfig(auto_compact=False))
    for q in queries_for(rng, data, 5):
        eng.step(q)
    eng.ingest(rng.uniform(0, 100, size=(20, 3)))
    res = eng.result()
    assert len(res.query_costs) == 5
    assert eng.ingest_stats()["ingested_rows"] == 20


def test_always_compact_triggers_at_first_delta_query():
    rng = np.random.default_rng(8)
    data = rng.uniform(0, 100, size=(1000, 3))
    eng = simple_engine(data, ingest=IngestConfig(debt_threshold=0.0))
    queries = queries_for(rng, data, 6)
    for q in queries[:3]:
        eng.step(q)
    eng.ingest(rng.uniform(0, 100, size=(100, 3)))
    eng.step(queries[3])        # debt meter active -> trigger (threshold 0)
    stats = eng.ingest_stats()
    assert stats["compactions"] == [3]
    eng.step(queries[4])        # delta=1: the compaction swap lands here
    assert not eng.backend.delta_log.pending        # absorbed
    assert eng.backend._serving_cache[3] == 1100
    # compactions are real reorg charges in the trace
    assert 3 in eng.result().reorg_indices


def test_never_compact_accrues_debt_without_reorgs():
    rng = np.random.default_rng(9)
    # column-sorted data: narrow zone maps, so unclustered deltas hurt
    data = np.sort(rng.uniform(0, 100, size=(1000, 3)), axis=0)
    eng = simple_engine(data, ingest=IngestConfig(auto_compact=False),
                        alpha=1.5, sort_col=0)
    queries = queries_for(rng, data, 30)
    for k, q in enumerate(queries):
        if k == 5:
            eng.ingest(rng.uniform(0, 100, size=(200, 3)))
        eng.step(q)
    stats = eng.ingest_stats()
    assert stats["compactions"] == []
    assert stats["clustering_debt"] > 1.5           # way past alpha
    assert eng.backend.delta_log.pending            # never absorbed
    assert eng.result().reorg_indices == []


def test_debt_aware_compacts_once_debt_crosses_alpha():
    rng = np.random.default_rng(10)
    data = np.sort(rng.uniform(0, 100, size=(1000, 3)), axis=0)
    eng = simple_engine(data, ingest=IngestConfig(debt_threshold=1.0),
                        alpha=1.5, sort_col=0)
    queries = queries_for(rng, data, 80)
    compacted_at = None
    for k, q in enumerate(queries):
        if k == 5:
            eng.ingest(rng.uniform(0, 100, size=(400, 3)))
        eng.step(q)
        if eng.compaction_indices and compacted_at is None:
            compacted_at = k
            assert eng.ingest_stats()["total_excess"] >= 1.5
        if compacted_at is not None and k >= compacted_at + 2:
            break                       # delta=1: the swap has landed
    assert compacted_at is not None and compacted_at > 5
    assert not eng.backend.delta_log.pending    # absorbed by the rewrite
    # debt was reset by the absorb
    assert eng.ingest_stats()["clustering_debt"] == 0.0


def test_drift_reorg_absorbs_deltas_and_resets_debt():
    """A policy-driven (drift) reorganization also rewrites the grown
    table: deltas absorb through the same activation path."""
    rng = np.random.default_rng(11)
    data = rng.uniform(0, 100, size=(1000, 3))
    eng = simple_engine(data, ingest=IngestConfig(auto_compact=False))
    queries = queries_for(rng, data, 4)
    for q in queries[:2]:
        eng.step(q)
    eng.ingest(rng.uniform(0, 100, size=(50, 3)))
    assert eng.backend.delta_log.pending
    sid = eng.backend.serving_state
    eng.backend.activate(sid)                   # what a drift swap does
    assert not eng.backend.delta_log.pending
    assert eng.backend._serving_cache[3] == 1050
    eng.step(queries[2])
    assert eng.ingest_stats()["clustering_debt"] == 0.0


def test_incremental_compaction_moves_only_delta_touched_partitions():
    """An incremental compaction diffs the hybrid delta-bearing source
    against the re-materialized target: clustered partitions whose row
    set is unchanged are skipped; the charge ledger still telescopes to
    bitwise alpha."""
    rng = np.random.default_rng(12)
    n = 2000
    # sorted data + a clustered layout: routing appends touches only the
    # partitions whose value range the delta rows fall into
    data = np.sort(rng.uniform(0, 100, size=(n, 1)), axis=0)
    eng = simple_engine(data, ingest=IngestConfig(debt_threshold=0.0),
                        incremental=True, alpha=1.5, sort_col=0)
    queries = queries_for(rng, data, 10, bounded=1)
    for q in queries[:3]:
        eng.step(q)
    # deltas confined to a narrow value band -> few target partitions
    eng.ingest(rng.uniform(10.0, 12.0, size=(120, 1)))
    eng.step(queries[3])                        # trigger
    eng.step(queries[4])                        # delta=1: begin + complete
    ex = eng.reorg_executor
    assert len(ex.migrations) == 1
    mig = ex.migrations[0]
    assert mig.completed_at >= 0
    assert mig.charged == mig.alpha             # bitwise ledger close
    k = eng.backend.ingest_base_meta.num_partitions
    assert 0 < mig.moves_total < k              # untouched partitions skipped
    assert not eng.backend.delta_log.pending


def test_mid_flight_appends_stack_as_fresh_deltas():
    """Rows appended while a migration is in flight stay pending delta
    partitions (served immediately) and survive the completion absorb."""
    rng = np.random.default_rng(13)
    data = np.sort(rng.uniform(0, 100, size=(3000, 1)), axis=0)
    eng = simple_engine(data, ingest=IngestConfig(debt_threshold=0.0),
                        incremental=True, alpha=1.5, sort_col=0)
    # tiny row budget so the compaction stays in flight across steps
    eng.reorg_executor.rows_per_tick = 40
    queries = queries_for(rng, data, 30, bounded=1)
    for q in queries[:3]:
        eng.step(q)
    eng.ingest(rng.uniform(20.0, 30.0, size=(300, 1)))
    eng.step(queries[3])                        # trigger
    eng.step(queries[4])                        # begin (40 rows/tick)
    assert eng.backend.migrating
    mid = eng.ingest(rng.uniform(50.0, 60.0, size=(80, 1)))
    assert eng.backend.delta_log.pending        # the mid-flight batch
    eng.reorg_executor.rows_per_tick = None     # let it drain
    k = 5
    while eng.backend.migrating and k < 30:
        eng.step(queries[k])
        k += 1
    assert not eng.backend.migrating
    assert [b.batch_id for b in eng.backend.delta_log.batches] \
        == [mid.batch_id]
    assert eng.backend._serving_cache[3] == 3380
    ex = eng.reorg_executor
    assert ex.migrations[0].charged == ex.migrations[0].alpha


def test_run_forces_stepwise_serving_under_ingest():
    rng = np.random.default_rng(14)
    data = rng.uniform(0, 100, size=(500, 3))
    eng = simple_engine(data, ingest=IngestConfig())
    with pytest.raises(ValueError, match="batch_serve"):
        eng.run(wl.WorkloadStream(queries=queries_for(rng, data, 3),
                                  segments=[], templates=[]),
                batch_serve=True)


# ---------------------------------------------------------------------------
# S3: zero-ingest golden identity, every scenario x scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_zero_ingest_traces_bit_identical(scenario, tenant_data, bounds):
    """Ingest enabled but never used: atomic and incremental fleet
    traces — loop AND batched — are bit-identical to the pre-ingest
    goldens under every scheduler."""
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario(scenario, lo, hi, num_tenants=2,
                                 queries_per_tenant=80, seed=7)
        golden = FleetEngine({tid: oreo_engine(tenant_data[tid])
                              for tid in fs.tenant_ids}, factory()).run(fs)
        golden_inc = FleetEngine({tid: oreo_engine(tenant_data[tid],
                                                   incremental=True)
                                  for tid in fs.tenant_ids},
                                 factory()).run(fs)
        for tid in fs.tenant_ids:
            assert_same_trace(golden.per_tenant[tid],
                              golden_inc.per_tenant[tid])
        arms = {
            "atomic-loop": lambda f: FleetEngine(
                {tid: oreo_engine(tenant_data[tid], ingest=IngestConfig())
                 for tid in fs.tenant_ids}, f()).run(fs),
            "atomic-batched": lambda f: FleetEngine(
                {tid: oreo_engine(tenant_data[tid], ingest=IngestConfig())
                 for tid in fs.tenant_ids}, f()).run_batched(fs),
            "incremental-loop": lambda f: FleetEngine(
                {tid: oreo_engine(tenant_data[tid], incremental=True,
                                  ingest=IngestConfig())
                 for tid in fs.tenant_ids}, f()).run(fs),
        }
        for label, arm in arms.items():
            res = arm(factory)
            for tid in fs.tenant_ids:
                assert_same_trace(golden.per_tenant[tid],
                                  res.per_tenant[tid]), (label, tid)
            assert res.swaps_deferred == golden.swaps_deferred, label
            assert res.deferred_ticks == golden.deferred_ticks, label


# ---------------------------------------------------------------------------
# Mixed read/write fleet streams
# ---------------------------------------------------------------------------

def test_ingest_scenarios_materialize_and_preserve_order(bounds):
    lo, hi = bounds
    assert set(INGEST_SCENARIOS) == {"trickle", "append_heavy", "mixed_rw",
                                     "ingest_burst", "bulk_load"}
    for name in sorted(INGEST_SCENARIOS):
        fs = make_ingest_scenario(name, lo, hi, num_tenants=2,
                                  queries_per_tenant=60, seed=5)
        assert fs.scenario == name
        assert fs.total_appended_rows > 0
        assert len(fs.events) == sum(len(v) for v in fs.per_tenant.values())
        for tid in fs.tenant_ids:
            assert len(fs.tenant_queries(tid)) == 60
            # interleaving preserves per-tenant event order
            replayed = [e for t, e in fs.events if t == tid]
            assert all(x is y for x, y
                       in zip(replayed, fs.per_tenant[tid]))
        # determinism
        again = make_ingest_scenario(name, lo, hi, num_tenants=2,
                                     queries_per_tenant=60, seed=5)
        for (t1, e1), (t2, e2) in zip(fs.events, again.events):
            assert t1 == t2 and type(e1) is type(e2)
            if isinstance(e1, IngestBatch):
                np.testing.assert_array_equal(e1.rows, e2.rows)


@pytest.mark.parametrize("scenario", ["trickle", "mixed_rw", "bulk_load"])
def test_fleet_mixed_stream_loop_vs_batched_bit_identical(scenario,
                                                          tenant_data,
                                                          bounds):
    lo, hi = bounds
    fs = make_ingest_scenario(scenario, lo, hi, num_tenants=2,
                              queries_per_tenant=120, seed=9)

    def build():
        return FleetEngine({tid: oreo_engine(tenant_data[tid], alpha=2.0,
                                             ingest=IngestConfig())
                            for tid in fs.tenant_ids}, UnlimitedScheduler())

    loop, batched = build(), build()
    rl, rb = loop.run(fs), batched.run_batched(fs)
    for tid in fs.tenant_ids:
        assert_same_trace(rl.per_tenant[tid], rb.per_tenant[tid])
        assert (loop.tenant(tid).compaction_indices
                == batched.tenant(tid).compaction_indices)
        assert len(rl.per_tenant[tid].query_costs) == 120
    assert rl.ticks == rb.ticks == len(fs)


def test_fleet_incremental_mixed_stream_matches_atomic(tenant_data, bounds):
    """Unbounded budget: the incremental fleet's mixed-stream trace is
    bit-identical to the atomic fleet's (compactions included)."""
    lo, hi = bounds
    fs = make_ingest_scenario("trickle", lo, hi, num_tenants=2,
                              queries_per_tenant=120, seed=11)

    def build(mode):
        return FleetEngine({tid: oreo_engine(tenant_data[tid], alpha=2.0,
                                             incremental=mode,
                                             ingest=IngestConfig())
                            for tid in fs.tenant_ids}, UnlimitedScheduler())

    atomic, incr = build(False), build(True)
    ra, ri = atomic.run(fs), incr.run(fs)
    for tid in fs.tenant_ids:
        assert_same_trace(ra.per_tenant[tid], ri.per_tenant[tid])
        assert (atomic.tenant(tid).compaction_indices
                == incr.tenant(tid).compaction_indices)
        for mig in incr.tenant(tid).reorg_executor.migrations:
            assert mig.completed_at == mig.begun_at
            assert mig.charged == mig.alpha
    # compactions actually happened somewhere in the fleet
    assert any(atomic.tenant(tid).compaction_indices
               for tid in fs.tenant_ids)


def test_fleet_step_returns_none_observation_for_ingest(tenant_data):
    data = tenant_data["t0"]
    fleet = FleetEngine({"t0": oreo_engine(data, ingest=IngestConfig())},
                        UnlimitedScheduler())
    rng = np.random.default_rng(15)
    q = queries_for(rng, data, 1)[0]
    assert fleet.step("t0", q).step is not None
    out = fleet.step("t0", IngestBatch(rows=rng.uniform(
        0, 100, size=(10, data.shape[1]))))
    assert out.step is None and out.tick == 2


# ---------------------------------------------------------------------------
# Durable DiskBackend: WAL recovery
# ---------------------------------------------------------------------------

def disk_engine(data, root, ingest=None, alpha=2.0, durable=True,
                snapshot_every=64):
    backend = DiskBackend(data, root, background=False, durable=durable,
                          wal_snapshot_every=snapshot_every)
    return simple_engine(data, ingest=ingest, alpha=alpha,
                         backend=backend), backend


def test_disk_backend_serves_pending_deltas(tmp_path):
    rng = np.random.default_rng(16)
    data = rng.uniform(0, 100, size=(600, 3))
    eng, backend = disk_engine(data, str(tmp_path / "d"), durable=False,
                               ingest=IngestConfig(auto_compact=False))
    queries = queries_for(rng, data, 4)
    eng.step(queries[0])
    eng.ingest(rng.uniform(0, 100, size=(150, 3)))
    # physical serve fraction == metadata cost of the composed state
    composed = backend.delta_log.compose(backend.ingest_base_meta)
    for q in queries[1:]:
        got = backend.serve(q)
        want = float(layouts.eval_cost(composed, q.lo, q.hi))
        assert got == pytest.approx(want)
    backend.close()


def test_disk_backend_wal_replays_to_live_manifest(tmp_path):
    """The crash-injection gate: at every point of a mixed run, replaying
    the WAL reconstructs the serving manifest bitwise and the exact set
    of pending delta batches."""
    rng = np.random.default_rng(17)
    data = rng.uniform(0, 100, size=(600, 3))
    root = str(tmp_path / "d")
    eng, backend = disk_engine(data, root, snapshot_every=5,
                               ingest=IngestConfig(debt_threshold=0.0))
    queries = queries_for(rng, data, 30)
    for k, q in enumerate(queries):
        eng.step(q)
        if k % 6 == 4:
            eng.ingest(rng.uniform(0, 100, size=(40, 3)))
        # "crash now": an independent replay of the WAL directory must
        # reproduce the live on-disk manifest bitwise
        state = DiskBackend.recover_state(root)
        assert state["serving"] == os.path.basename(
            backend._serving_store.root)
        with open(os.path.join(backend._serving_store.root,
                               "manifest.json")) as f:
            assert state["manifest"] == json.load(f)
        live_pending = [b.batch_id for b in backend.delta_log.batches]
        assert [d["batch_id"] for d in state["deltas"]] == live_pending
        for d in state["deltas"]:
            assert os.path.exists(os.path.join(root, "deltas", d["file"]))
    assert eng.compaction_indices            # the run really compacted
    # a second replay is idempotent (bitwise)
    assert (canonical_manifest(DiskBackend.recover_state(root))
            == canonical_manifest(DiskBackend.recover_state(root)))
    backend.close()


def test_disk_backend_orphaned_delta_file_is_ignored(tmp_path):
    """Crash between delta-file write and WAL commit: the orphaned file
    is never referenced by replay (the record is the commit point)."""
    rng = np.random.default_rng(18)
    data = rng.uniform(0, 100, size=(400, 3))
    root = str(tmp_path / "d")
    eng, backend = disk_engine(data, root,
                               ingest=IngestConfig(auto_compact=False))
    eng.step(queries_for(rng, data, 1)[0])
    eng.ingest(rng.uniform(0, 100, size=(30, 3)))
    # fabricate the crash artifact: a delta file with no WAL record
    np.savez(os.path.join(root, "deltas", "delta_99999.npz"),
             rows=np.zeros((5, 3)))
    state = DiskBackend.recover_state(root)
    assert [d["batch_id"] for d in state["deltas"]] == [0]
    assert all(d["file"] != "delta_99999.npz" for d in state["deltas"])
    backend.close()


def test_disk_backend_wal_records_incremental_migration(tmp_path):
    """Drift migrations on a durable DiskBackend log begin/apply/swap;
    mid-flight crash replay shows the in-flight migration, completion
    replay shows the target manifest."""
    rng = np.random.default_rng(19)
    data = np.sort(rng.uniform(0, 100, size=(1500, 2)), axis=0)
    root = str(tmp_path / "d")
    backend = DiskBackend(data, root, background=False, durable=True)
    eng = simple_engine(data, incremental=True, alpha=1.5, backend=backend)
    eng.reorg_executor.rows_per_tick = 100
    queries = queries_for(rng, data, 60, bounded=1)
    saw_in_flight = False
    for q in queries:
        eng.step(q)
        state = DiskBackend.recover_state(root)
        if backend.migrating:
            saw_in_flight = True
            assert state["migration"] is not None
            done = state["migration"]["done"]
            assert done == sorted(set(done))
        if eng.result().reorg_indices and not backend.migrating:
            break
    final = DiskBackend.recover_state(root)
    if eng.result().reorg_indices:
        assert saw_in_flight
        assert final["migration"] is None
        with open(os.path.join(backend._serving_store.root,
                               "manifest.json")) as f:
            assert final["manifest"] == json.load(f)
    backend.close()


@pytest.mark.parametrize("scenario", sorted(INGEST_SCENARIOS))
def test_fleet_mixed_stream_pallas_fused_bit_identical(scenario,
                                                       tenant_data,
                                                       bounds):
    """Every ingest scenario under the megakernel batched backend: the
    float32 guard keeps the fused pass exact, so mixed query/append
    traces (compactions included) equal the stepwise loop bit for bit."""
    lo, hi = bounds
    fs = make_ingest_scenario(scenario, lo, hi, num_tenants=2,
                              queries_per_tenant=100, seed=9)

    def build():
        return FleetEngine({tid: simple_engine(tenant_data[tid],
                                               ingest=IngestConfig())
                            for tid in fs.tenant_ids}, UnlimitedScheduler())

    loop, batched = build(), build()
    rl = loop.run(fs)
    rb = batched.run_batched(fs, compute="pallas_fused")
    for tid in fs.tenant_ids:
        assert_same_trace(rl.per_tenant[tid], rb.per_tenant[tid])
        assert (loop.tenant(tid).compaction_indices
                == batched.tenant(tid).compaction_indices)
