"""Config-registry tests: exact assigned architecture numbers."""
import pytest

from repro.configs import SHAPES, get_arch, list_archs


EXACT = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
    "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
}


@pytest.mark.parametrize("name", sorted(EXACT))
def test_exact_config_numbers(name):
    cfg = get_arch(name)
    L, d, h, kv, ff, v = EXACT[name]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab == v


def test_family_flags():
    assert get_arch("phi3.5-moe-42b-a6.6b").moe.num_experts == 16
    assert get_arch("phi3.5-moe-42b-a6.6b").moe.top_k == 2
    assert get_arch("moonshot-v1-16b-a3b").moe.num_experts == 64
    assert get_arch("moonshot-v1-16b-a3b").moe.top_k == 6
    assert get_arch("chatglm3-6b").rope_mode == "half"
    assert get_arch("qwen3-1.7b").qk_norm
    assert get_arch("nemotron-4-340b").act == "sq_relu"
    assert get_arch("minitron-4b").act == "sq_relu"
    assert get_arch("zamba2-2.7b").ssm.d_state == 64
    assert get_arch("zamba2-2.7b").attn_every == 6
    assert get_arch("paligemma-3b").embed_input
    assert get_arch("musicgen-large").embed_input


def test_param_counts_plausible():
    """Analytic parameter counts land near the names' advertised sizes."""
    expect = {
        "nemotron-4-340b": (300e9, 380e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        # the literal assigned config (48L x 64e x d_ff 1408) is ~28B
        "moonshot-v1-16b-a3b": (25e9, 31e9),
        "chatglm3-6b": (5.5e9, 8e9),
        "qwen3-1.7b": (1.4e9, 2.3e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "rwkv6-3b": (2.2e9, 3.8e9),
        "musicgen-large": (1.5e9, 2.6e9),
        "paligemma-3b": (2.2e9, 3.6e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).num_params()
        assert lo <= n <= hi, (name, n)


def test_moe_active_params_below_total():
    for name in ("phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b"):
        cfg = get_arch(name)
        assert cfg.num_active_params() < 0.5 * cfg.num_params()


def test_shape_cells():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_smoke_configs_are_small():
    for name in list_archs():
        smoke = get_arch(name, smoke=True)
        assert smoke.num_params() < 5e6, (name, smoke.num_params())
