"""Grower-driven dynamic-state churn: plane hygiene + golden traces.

Three layers of the same contract — mid-run state growth and retirement
must be invisible to every mirror of the decision plane:

* ``StateMatrix.deregister`` leaves no stale payload in the vacated slot
  (swap-with-last wipes the tail back to identity fills), and
  ``FleetMatrix`` transposed twins track arbitrary register/deregister
  churn slot for slot.
* Fleet traces with :class:`repro.forecast.ForecastPolicy` growing and
  retiring qd-tree states mid-stream are bit-identical between the
  stepwise loop and ``run_batched`` — including the ``pallas_fused``
  megakernel backend — across every drift scenario and scheduler (the
  primed-estimate fallback must survive plane-version bumps caused by
  mid-decide registration).
"""
import numpy as np
import pytest

from repro.core import OreoConfig, build_default_layout, layouts, \
    make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import (FleetEngine, FleetMatrix, InMemoryBackend,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          StateMatrix, TokenBucketScheduler,
                          UnlimitedScheduler)
from repro.forecast import ForecastConfig, ForecastPolicy, QdTreeGrower, \
    grown_ids


def make_meta(rng, partitions, columns, rows_per=40):
    data = rng.uniform(0, 100, size=(partitions * rows_per, columns))
    assignment = np.repeat(np.arange(partitions), rows_per)
    return layouts.metadata_from_assignment(data, assignment, partitions)


# ---------------------------------------------------------------------------
# StateMatrix slot hygiene under deregistration
# ---------------------------------------------------------------------------

def test_deregister_wipes_vacated_slot():
    """After swap-with-last removal the tail slot must hold identity
    fills, not the payload of the state that used to live there — a
    later register into that slot with fewer partitions would otherwise
    inherit stale bounds rows beyond its own partition count."""
    rng = np.random.default_rng(0)
    sm = StateMatrix()
    for sid, p in [(1, 4), (2, 8), (3, 6)]:
        sm.register(sid, make_meta(rng, p, 3))
    sm.deregister(2)                      # 3 swaps into slot 1
    vac = len(sm)                         # the vacated tail slot
    assert np.all(np.isinf(sm._mins[vac]))
    assert np.all(sm._mins[vac] > 0)
    assert np.all(np.isinf(sm._maxs[vac]))
    assert np.all(sm._maxs[vac] < 0)
    assert np.all(np.isinf(sm._minsT[:, vac]))
    assert np.all(np.isinf(sm._maxsT[:, vac]))
    assert np.all(sm._rows[vac] == 0.0)
    assert np.all(sm._totals_arr[vac] == 1.0)


def test_fleet_mirror_tracks_random_register_deregister_churn():
    """Stale slot-map audit: arbitrary interleaved register/deregister
    churn across tenants keeps every FleetMatrix twin (row-major and
    transposed) equal to the local plane, slot for slot."""
    rng = np.random.default_rng(7)
    fm = FleetMatrix()
    sms = {tid: StateMatrix() for tid in ("a", "b", "c")}
    for tid, sm in sms.items():
        fm.attach(tid, sm)
    next_sid = 0
    for _ in range(200):
        tid = ("a", "b", "c")[int(rng.integers(3))]
        sm = sms[tid]
        if len(sm) and rng.uniform() < 0.4:
            sm.deregister(sm.state_ids[int(rng.integers(len(sm)))])
        else:
            sm.register(next_sid, make_meta(rng, int(rng.integers(2, 9)), 3))
            next_sid += 1
    for tid, sm in sms.items():
        assert fm.state_ids(tid) == sm.state_ids
        row = fm.tenant_row(tid)
        for sid in sm.state_ids:
            slot = sm.slot(sid)
            assert fm.slot(tid, sid) == slot
            meta = sm.metadata(sid)
            p = meta.num_partitions
            np.testing.assert_array_equal(
                fm._mins[row, slot, :p], meta.mins)
            np.testing.assert_array_equal(
                fm._maxs[row, slot, :p], meta.maxs)
            np.testing.assert_array_equal(
                fm._minsT[:, row, slot, :p], meta.mins.T)
            np.testing.assert_array_equal(
                fm._maxsT[:, row, slot, :p], meta.maxs.T)
            assert np.all(np.isinf(fm._mins[row, slot, p:]))
        # slots past the live count are identity-filled in the mirror too
        assert np.all(np.isinf(fm._mins[row, len(sm):]))


# ---------------------------------------------------------------------------
# Golden loop vs batched traces with mid-stream growth + retirement
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(100 + t).uniform(
        0, 100, size=(3_000, 6)) for t in range(3)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def forecast_engine(data, alpha=10.0, delta=5, seed=2):
    """An engine whose policy grows and retires qd-tree states eagerly:
    lax admission (alpha=0 grower, zero gain/floor, period forecasts
    eligible), one-deep grown pool and a short retirement window so
    register *and* deregister churn both land mid-stream."""
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    inner = OreoPolicy(data, build_default_layout(0, data, 8), gen, cfg)
    fc = ForecastConfig(grow=True, max_grown=1, grow_retire_after=30,
                        grow_sources=("period", "trend", "adversarial"))
    grower = QdTreeGrower(data, 8, min_queries=4, gain=0.0, cost_floor=0.0,
                          alpha=0.0, seed=seed + 101)
    policy = ForecastPolicy(inner, config=fc, grower=grower)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


SCHEDULERS = [
    ("unlimited", UnlimitedScheduler),
    ("k1", lambda: KConcurrentScheduler(1)),
    ("bucket", lambda: TokenBucketScheduler(rate=0.01, capacity=1.0,
                                            initial=0.0)),
]

ALL_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                 "flash_crowd", "template_churn"]


def _assert_identical(fs, r_loop, r_batched):
    for tid in fs.tenant_ids:
        a, b = r_loop.per_tenant[tid], r_batched.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs)
        assert a.reorg_indices == b.reorg_indices
        assert np.array_equal(a.state_seq, b.state_seq)
        assert a.info.get("grown_admitted") == b.info.get("grown_admitted")
        assert a.info.get("prepositions") == b.info.get("prepositions")
    assert r_loop.swaps_deferred == r_batched.swaps_deferred
    assert r_loop.deferred_ticks == r_batched.deferred_ticks
    assert r_loop.scheduler_stats.get("grants") \
        == r_batched.scheduler_stats.get("grants")


@pytest.mark.parametrize("compute", ["numpy", "pallas_fused"])
@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_grower_churn_batched_bit_identical_to_loop(scenario, compute,
                                                    tenant_data, bounds):
    lo, hi = bounds
    for _, factory in SCHEDULERS:
        fs = make_drift_scenario(scenario, lo, hi, num_tenants=3,
                                 queries_per_tenant=120, seed=7)
        loop = FleetEngine({tid: forecast_engine(tenant_data[tid])
                            for tid in fs.tenant_ids}, factory())
        r_loop = loop.run(fs)
        batched = FleetEngine({tid: forecast_engine(tenant_data[tid])
                               for tid in fs.tenant_ids}, factory())
        r_batched = batched.run_batched(fs, compute=compute)
        _assert_identical(fs, r_loop, r_batched)


def test_grower_churn_actually_churns(tenant_data, bounds):
    """The golden tests above are vacuous if no state ever grows or
    retires mid-run; pin that the lax config really churns the plane."""
    lo, hi = bounds
    fs = make_drift_scenario("cyclic_diurnal", lo, hi, num_tenants=3,
                             queries_per_tenant=120, seed=7)
    engines = {tid: forecast_engine(tenant_data[tid])
               for tid in fs.tenant_ids}
    fleet = FleetEngine(engines, UnlimitedScheduler())
    res = fleet.run(fs)
    admitted = sum(res.per_tenant[t].info["grown_admitted"]
                   for t in fs.tenant_ids)
    assert admitted > 0
    # at least one grown state was deregistered again mid-run (FIFO
    # eviction or idle retirement), so deregister paths were exercised
    live = sum(len(grown_ids(engines[t].policy.inner.dumts.states))
               for t in fs.tenant_ids)
    assert live < admitted
