"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DynamicUMTS, layouts
from repro.core.mts import theorem_iv1_bound
from repro.core.sampling import RTBSample, ReservoirSample, SlidingWindow


# ---------------------------------------------------------------------------
# D-UMTS invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n_states=st.integers(2, 6),
    alpha=st.floats(2.0, 50.0),
    seed=st.integers(0, 100),
    costs=st.lists(st.lists(st.floats(0.0, 1.0), min_size=6, max_size=6),
                   min_size=20, max_size=120),
)
def test_dumts_invariants(n_states, alpha, seed, costs):
    d = DynamicUMTS(alpha=alpha, initial_states=list(range(n_states)),
                    seed=seed)
    for row in costs:
        s = d.observe({i: row[i] for i in range(n_states)})
        # invariant 1: current state is always a live state
        assert s in d.states
        # invariant 2: active states have counters strictly below alpha
        assert all(d.counters[a] < alpha for a in d.active)
        # invariant 3: the active set is never empty after observe
        assert d.active
        # invariant 4: counters are monotonically nonnegative
        assert all(c >= 0.0 for c in d.counters.values())
    # invariant 5: competitive-ratio bookkeeping
    assert d.competitive_bound() >= 2.0
    assert d.max_state_space >= n_states


@settings(max_examples=25, deadline=None)
@given(
    alpha=st.floats(2.0, 20.0),
    seed=st.integers(0, 50),
    ops=st.lists(st.tuples(st.sampled_from(["add", "remove", "query"]),
                           st.integers(0, 9)), min_size=10, max_size=80),
)
def test_dumts_dynamic_state_space(alpha, seed, ops):
    """Arbitrary interleaving of add/remove/query keeps the system sound."""
    d = DynamicUMTS(alpha=alpha, initial_states=[0], seed=seed)
    rng = np.random.default_rng(seed)
    next_id = 1
    for op, _arg in ops:
        if op == "add":
            d.add_state(next_id)
            next_id += 1
        elif op == "remove" and len(d.states) > 1:
            victims = [s for s in sorted(d.states)]
            d.remove_state(victims[_arg % len(victims)])
        else:
            known = sorted(d.states | d.pending_additions)
            d.observe({s: float(rng.uniform(0, 1)) for s in known})
        assert d.current_state in d.states
        assert d.active.issubset(d.states)


# ---------------------------------------------------------------------------
# Zone-map cost model invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n_parts=st.integers(1, 20),
    n_cols=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_eval_cost_bounds_and_monotonicity(n_parts, n_cols, seed):
    rng = np.random.default_rng(seed)
    mins = rng.uniform(0, 1, (n_parts, n_cols))
    maxs = mins + rng.uniform(0, 1, (n_parts, n_cols))
    rows = rng.integers(1, 100, n_parts).astype(np.float64)
    meta = layouts.PartitionMetadata(mins=mins, maxs=maxs, rows=rows)
    lo = rng.uniform(-1, 1, n_cols)
    hi = lo + rng.uniform(0, 1, n_cols)
    c = float(layouts.eval_cost(meta, lo, hi))
    assert 0.0 <= c <= 1.0
    # widening the query can only scan more
    c_wide = float(layouts.eval_cost(meta, lo - 0.5, hi + 0.5))
    assert c_wide >= c - 1e-12
    # the full-space query scans everything
    full = float(layouts.eval_cost(meta, np.full(n_cols, -np.inf),
                                   np.full(n_cols, np.inf)))
    assert full == 1.0
    # skipped + scanned = 1
    assert float(layouts.eval_skipped(meta, lo, hi)) == 1.0 - c


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 300), size=st.integers(1, 50),
       seed=st.integers(0, 99))
def test_samplers_bounded(n, size, seed):
    sw = SlidingWindow(size)
    rs = ReservoirSample(size, seed=seed)
    tb = RTBSample(size, seed=seed)
    for i in range(n):
        sw.add(i)
        rs.add(i)
        tb.add(i)
    assert len(sw) <= size and len(rs) <= size and len(tb) <= size
    if n >= size:
        assert len(sw) == size
        # sliding window holds exactly the most recent items
        assert sw.sample() == list(range(n - size, n))
    # reservoir items are valid observations
    assert all(0 <= x < n for x in rs.sample())
    assert all(0 <= x < n for x in tb.sample())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 30))
def test_rtbs_recency_bias(seed):
    """Time-biased reservoir holds more recent items than a uniform one."""
    tb = RTBSample(50, lam=5e-2, seed=seed)
    rs = ReservoirSample(50, seed=seed)
    for i in range(3000):
        tb.add(i)
        rs.add(i)
    assert np.mean(tb.sample()) > np.mean(rs.sample())


def test_harmonic_bound_monotone():
    vals = [theorem_iv1_bound(n) for n in range(1, 30)]
    assert all(b2 >= b1 for b1, b2 in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# StateMatrix metadata plane invariants
# ---------------------------------------------------------------------------

def _tiny_meta(rng: np.random.Generator, p: int, c: int = 4):
    data = rng.uniform(0, 1, (max(4 * p, 16), c))
    assignment = rng.integers(0, p, len(data))
    return layouts.metadata_from_assignment(data, assignment, p)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999),
       ops=st.lists(st.sampled_from(["reg", "dereg", "rereg"]),
                    min_size=1, max_size=40))
def test_state_matrix_equals_from_scratch_rebuild(seed, ops):
    """After any register/deregister sequence, the incrementally-maintained
    plane is indistinguishable from one rebuilt from scratch: same metadata,
    bit-identical estimates, both equal to the reference evaluation."""
    from repro.engine import StateMatrix
    rng = np.random.default_rng(seed)
    sm = StateMatrix()
    live = {}
    next_id = 0
    for op in ops:
        if op == "reg" or not live:
            meta = _tiny_meta(rng, int(rng.integers(1, 12)))
            sm.register(next_id, meta)
            live[next_id] = meta
            next_id += 1
        elif op == "dereg":
            victim = int(rng.choice(sorted(live)))
            sm.deregister(victim)
            del live[victim]
        else:   # re-register an existing id with fresh metadata
            victim = int(rng.choice(sorted(live)))
            meta = _tiny_meta(rng, int(rng.integers(1, 12)))
            sm.register(victim, meta)
            live[victim] = meta
    assert sorted(sm.state_ids) == sorted(live)
    rebuilt = StateMatrix()
    for sid in sm.state_ids:                # same slot order as the plane
        rebuilt.register(sid, live[sid])
    for sid in sm.state_ids:
        for attr in ("mins", "maxs", "rows"):
            assert np.array_equal(getattr(sm.metadata(sid), attr),
                                  getattr(live[sid], attr))
    lo = np.full(4, -np.inf)
    hi = np.full(4, np.inf)
    col = int(rng.integers(4))
    lo[col], hi[col] = 0.2, 0.6
    metas_in_slot_order = [live[sid] for sid in sm.state_ids]
    want = layouts.eval_cost_states(metas_in_slot_order, lo, hi)
    assert np.array_equal(sm.estimate(lo, hi), want)
    assert sm.estimate_costs(sm.state_ids, lo, hi) == \
        rebuilt.estimate_costs(sm.state_ids, lo, hi)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), extra_queries=st.integers(1, 80))
def test_layout_manager_cost_vector_cache_invalidates(seed, extra_queries):
    """Cached LayoutManager cost vectors always equal a from-scratch
    computation over the *current* R-TBS sample, before and after the
    sample changes."""
    from repro.core import build_default_layout, make_generator
    from repro.core import layout_manager as lm
    from repro.core import workload as wl
    rng = np.random.default_rng(seed)
    data = rng.uniform(0, 100, size=(1500, 4))

    def query():
        lo = np.full(4, -np.inf)
        hi = np.full(4, np.inf)
        col = int(rng.integers(4))
        lo[col] = rng.uniform(0, 80)
        hi[col] = lo[col] + rng.uniform(1, 20)
        return wl.Query(lo=lo, hi=hi)

    init = build_default_layout(0, data, 4)
    mgr = lm.LayoutManager(data, make_generator("qdtree"), init,
                           lm.LayoutManagerConfig(rtbs_size=8), seed=seed)
    for i in range(1, 4):
        mgr.store[i] = build_default_layout(i, data, 4, sort_col=i % 4)
    for _ in range(5):
        mgr.rtbs.add(query())

    def fresh_vectors():
        qs = mgr.rtbs.sample()
        q_lo, q_hi = wl.stack_queries(qs)
        return {i: layouts.cost_vector(lay.meta, q_lo, q_hi)
                for i, lay in mgr.store.items()}

    first = mgr._cost_vectors(mgr.store)
    want = fresh_vectors()
    assert all(np.array_equal(first[i], want[i]) for i in mgr.store)
    assert mgr._cv_cache          # vectors of stored layouts were cached

    version_before = mgr.rtbs.version
    for _ in range(extra_queries):
        mgr.rtbs.add(query())
    second = mgr._cost_vectors(mgr.store)
    want = fresh_vectors()
    assert all(np.array_equal(second[i], want[i]) for i in mgr.store)
    if mgr.rtbs.version != version_before:
        # sample changed -> cache was rebuilt, not reused
        assert not any(second[i] is first[i] for i in mgr.store)
    else:
        # sample unchanged -> cached arrays reused verbatim
        assert all(second[i] is first[i] for i in mgr.store)


# ---------------------------------------------------------------------------
# Incremental reorganization plane invariants
# ---------------------------------------------------------------------------

def _migration_fixture(seed, rows, partitions, num_queries):
    from repro.core import build_default_layout, make_generator
    from repro.core import workload as wl
    from repro.engine.reorg.planner import plan_migration

    rng = np.random.default_rng(seed)
    data = rng.uniform(0, 100, size=(rows, 3))
    queries = []
    for _ in range(num_queries):
        lo = np.full(3, -np.inf)
        hi = np.full(3, np.inf)
        col = int(rng.integers(3))
        lo[col] = rng.uniform(0, 80)
        hi[col] = lo[col] + rng.uniform(1, 30)
        queries.append(wl.Query(lo=lo, hi=hi))
    src = build_default_layout(0, data, partitions, sort_col=0)
    tgt = make_generator("qdtree")(1, data, queries or [wl.Query(
        lo=np.full(3, -np.inf), hi=np.full(3, np.inf))], partitions)
    plan = plan_migration(data, src, tgt, queries)
    return data, src, tgt, queries, plan


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       rows=st.integers(200, 1500),
       partitions=st.integers(2, 10),
       num_queries=st.integers(0, 12))
def test_planner_moves_are_permutation_of_diff(seed, rows, partitions,
                                               num_queries):
    """(c) The planner's move order is a permutation of the layout diff:
    every non-empty target partition whose row set differs from the
    source appears exactly once, identical partitions never appear."""
    from repro.engine.reorg.planner import plan_is_permutation_of_diff

    _, _, _, _, plan = _migration_fixture(seed, rows, partitions,
                                          num_queries)
    assert plan_is_permutation_of_diff(plan)
    assert plan.total_move_rows == sum(m.rows for m in plan.moves)
    moved = [m.target_partition for m in plan.moves]
    assert len(moved) == len(set(moved))
    assert not (set(moved) & set(plan.identical))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       alpha=st.floats(0.01, 500.0),
       rows=st.integers(200, 1200),
       partitions=st.integers(2, 8),
       batches=st.integers(1, 9))
def test_cumulative_incremental_charge_equals_alpha(seed, alpha, rows,
                                                    partitions, batches):
    """(a) Summing a completed migration's charge schedule left to right
    lands bitwise on the atomic α charge, for any batch split."""
    from repro.engine.reorg.executor import MigrationRecord

    _, _, _, _, plan = _migration_fixture(seed, rows, partitions, 4)
    record = MigrationRecord(target_state=1, charged_at=0, begun_at=0,
                             alpha=alpha,
                             total_rows=plan.total_move_rows,
                             moves_total=plan.num_moves)
    moves = list(plan.moves)
    rng = np.random.default_rng(seed)
    cuts = sorted(rng.integers(0, len(moves) + 1, size=batches - 1).tolist())
    groups = [moves[a:b] for a, b in
              zip([0] + cuts, cuts + [len(moves)])]
    for k, group in enumerate(groups):
        moved = sum(m.rows for m in group)
        record.moved_rows += moved
        record.charge(index=k, rows=moved,
                      completing=(k == len(groups) - 1))
    # the consumer's left-to-right float sum is exactly alpha
    total = 0.0
    for _, _, charge in record.charges:
        total = total + charge
    assert total == alpha
    assert record.charged == alpha
    # charges are proportional to rows moved until the closing one
    assert all(rows_k >= 0 for _, rows_k, _ in record.charges)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       rows=st.integers(300, 1500),
       partitions=st.integers(2, 8),
       done_seed=st.integers(0, 1000))
def test_hybrid_serve_cost_envelope(seed, rows, partitions, done_seed):
    """(b) For every query, the hybrid serve cost is bounded by the
    per-row mixture of the pure layouts: moved rows cost exactly their
    pure-target cost, unmoved rows at most their pure-source cost (their
    residual bounds only ever shrink), so

        moved_target_cost <= hybrid <= moved_target_cost + unmoved_source_cost

    with both endpoints reached (no moves -> pure source; all moves ->
    pure target, tested bitwise).  The naive "between source and target
    totals" claim is genuinely false for zone maps — a residual partition
    can straddle a query that both pure layouts skip — which is why the
    envelope is stated per row set.
    """
    from repro.core import layouts as L
    from repro.core import workload as wl

    data, src, tgt, queries, plan = _migration_fixture(seed, rows,
                                                       partitions, 6)
    if not queries:
        queries = [wl.Query(lo=np.full(3, -np.inf),
                            hi=np.full(3, np.inf))]
    rng = np.random.default_rng(done_seed)
    done = np.zeros(plan.num_target_partitions, dtype=bool)
    for m in plan.moves:
        if rng.uniform() < 0.5:
            done[m.target_partition] = True
    hybrid = plan.hybrid_meta(done)
    src_meta = src.materialize(data)
    tgt_meta = plan.target_meta
    total = max(len(data), 1)
    moved_rows = done[plan.target_assignment]
    for q in queries:
        c_h = float(L.eval_cost(hybrid, q.lo, q.hi))
        scan_s = L.partitions_scanned(src_meta, q.lo, q.hi)
        scan_t = L.partitions_scanned(tgt_meta, q.lo, q.hi)
        per_row_s = scan_s[plan.source_assignment]
        per_row_t = scan_t[plan.target_assignment]
        lower = per_row_t[moved_rows].sum() / total
        upper = (per_row_t[moved_rows].sum()
                 + per_row_s[~moved_rows].sum()) / total
        assert lower - 1e-12 <= c_h <= upper + 1e-12
    # endpoints, bitwise
    q_lo, q_hi = wl.stack_queries(queries)
    none = plan.hybrid_meta(np.zeros_like(done))
    full = plan.hybrid_meta(np.ones_like(done))
    assert np.array_equal(L.eval_cost(none, q_lo, q_hi),
                          L.eval_cost(src_meta, q_lo, q_hi))
    assert np.array_equal(L.eval_cost(full, q_lo, q_hi),
                          L.eval_cost(tgt_meta, q_lo, q_hi))
