"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DynamicUMTS, layouts
from repro.core.mts import theorem_iv1_bound
from repro.core.sampling import RTBSample, ReservoirSample, SlidingWindow


# ---------------------------------------------------------------------------
# D-UMTS invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n_states=st.integers(2, 6),
    alpha=st.floats(2.0, 50.0),
    seed=st.integers(0, 100),
    costs=st.lists(st.lists(st.floats(0.0, 1.0), min_size=6, max_size=6),
                   min_size=20, max_size=120),
)
def test_dumts_invariants(n_states, alpha, seed, costs):
    d = DynamicUMTS(alpha=alpha, initial_states=list(range(n_states)),
                    seed=seed)
    for row in costs:
        s = d.observe({i: row[i] for i in range(n_states)})
        # invariant 1: current state is always a live state
        assert s in d.states
        # invariant 2: active states have counters strictly below alpha
        assert all(d.counters[a] < alpha for a in d.active)
        # invariant 3: the active set is never empty after observe
        assert d.active
        # invariant 4: counters are monotonically nonnegative
        assert all(c >= 0.0 for c in d.counters.values())
    # invariant 5: competitive-ratio bookkeeping
    assert d.competitive_bound() >= 2.0
    assert d.max_state_space >= n_states


@settings(max_examples=25, deadline=None)
@given(
    alpha=st.floats(2.0, 20.0),
    seed=st.integers(0, 50),
    ops=st.lists(st.tuples(st.sampled_from(["add", "remove", "query"]),
                           st.integers(0, 9)), min_size=10, max_size=80),
)
def test_dumts_dynamic_state_space(alpha, seed, ops):
    """Arbitrary interleaving of add/remove/query keeps the system sound."""
    d = DynamicUMTS(alpha=alpha, initial_states=[0], seed=seed)
    rng = np.random.default_rng(seed)
    next_id = 1
    for op, _arg in ops:
        if op == "add":
            d.add_state(next_id)
            next_id += 1
        elif op == "remove" and len(d.states) > 1:
            victims = [s for s in sorted(d.states)]
            d.remove_state(victims[_arg % len(victims)])
        else:
            known = sorted(d.states | d.pending_additions)
            d.observe({s: float(rng.uniform(0, 1)) for s in known})
        assert d.current_state in d.states
        assert d.active.issubset(d.states)


# ---------------------------------------------------------------------------
# Zone-map cost model invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n_parts=st.integers(1, 20),
    n_cols=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_eval_cost_bounds_and_monotonicity(n_parts, n_cols, seed):
    rng = np.random.default_rng(seed)
    mins = rng.uniform(0, 1, (n_parts, n_cols))
    maxs = mins + rng.uniform(0, 1, (n_parts, n_cols))
    rows = rng.integers(1, 100, n_parts).astype(np.float64)
    meta = layouts.PartitionMetadata(mins=mins, maxs=maxs, rows=rows)
    lo = rng.uniform(-1, 1, n_cols)
    hi = lo + rng.uniform(0, 1, n_cols)
    c = float(layouts.eval_cost(meta, lo, hi))
    assert 0.0 <= c <= 1.0
    # widening the query can only scan more
    c_wide = float(layouts.eval_cost(meta, lo - 0.5, hi + 0.5))
    assert c_wide >= c - 1e-12
    # the full-space query scans everything
    full = float(layouts.eval_cost(meta, np.full(n_cols, -np.inf),
                                   np.full(n_cols, np.inf)))
    assert full == 1.0
    # skipped + scanned = 1
    assert float(layouts.eval_skipped(meta, lo, hi)) == 1.0 - c


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 300), size=st.integers(1, 50),
       seed=st.integers(0, 99))
def test_samplers_bounded(n, size, seed):
    sw = SlidingWindow(size)
    rs = ReservoirSample(size, seed=seed)
    tb = RTBSample(size, seed=seed)
    for i in range(n):
        sw.add(i)
        rs.add(i)
        tb.add(i)
    assert len(sw) <= size and len(rs) <= size and len(tb) <= size
    if n >= size:
        assert len(sw) == size
        # sliding window holds exactly the most recent items
        assert sw.sample() == list(range(n - size, n))
    # reservoir items are valid observations
    assert all(0 <= x < n for x in rs.sample())
    assert all(0 <= x < n for x in tb.sample())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 30))
def test_rtbs_recency_bias(seed):
    """Time-biased reservoir holds more recent items than a uniform one."""
    tb = RTBSample(50, lam=5e-2, seed=seed)
    rs = ReservoirSample(50, seed=seed)
    for i in range(3000):
        tb.add(i)
        rs.add(i)
    assert np.mean(tb.sample()) > np.mean(rs.sample())


def test_harmonic_bound_monotone():
    vals = [theorem_iv1_bound(n) for n in range(1, 30)]
    assert all(b2 >= b1 for b1, b2 in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# StateMatrix metadata plane invariants
# ---------------------------------------------------------------------------

def _tiny_meta(rng: np.random.Generator, p: int, c: int = 4):
    data = rng.uniform(0, 1, (max(4 * p, 16), c))
    assignment = rng.integers(0, p, len(data))
    return layouts.metadata_from_assignment(data, assignment, p)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999),
       ops=st.lists(st.sampled_from(["reg", "dereg", "rereg"]),
                    min_size=1, max_size=40))
def test_state_matrix_equals_from_scratch_rebuild(seed, ops):
    """After any register/deregister sequence, the incrementally-maintained
    plane is indistinguishable from one rebuilt from scratch: same metadata,
    bit-identical estimates, both equal to the reference evaluation."""
    from repro.engine import StateMatrix
    rng = np.random.default_rng(seed)
    sm = StateMatrix()
    live = {}
    next_id = 0
    for op in ops:
        if op == "reg" or not live:
            meta = _tiny_meta(rng, int(rng.integers(1, 12)))
            sm.register(next_id, meta)
            live[next_id] = meta
            next_id += 1
        elif op == "dereg":
            victim = int(rng.choice(sorted(live)))
            sm.deregister(victim)
            del live[victim]
        else:   # re-register an existing id with fresh metadata
            victim = int(rng.choice(sorted(live)))
            meta = _tiny_meta(rng, int(rng.integers(1, 12)))
            sm.register(victim, meta)
            live[victim] = meta
    assert sorted(sm.state_ids) == sorted(live)
    rebuilt = StateMatrix()
    for sid in sm.state_ids:                # same slot order as the plane
        rebuilt.register(sid, live[sid])
    for sid in sm.state_ids:
        for attr in ("mins", "maxs", "rows"):
            assert np.array_equal(getattr(sm.metadata(sid), attr),
                                  getattr(live[sid], attr))
    lo = np.full(4, -np.inf)
    hi = np.full(4, np.inf)
    col = int(rng.integers(4))
    lo[col], hi[col] = 0.2, 0.6
    metas_in_slot_order = [live[sid] for sid in sm.state_ids]
    want = layouts.eval_cost_states(metas_in_slot_order, lo, hi)
    assert np.array_equal(sm.estimate(lo, hi), want)
    assert sm.estimate_costs(sm.state_ids, lo, hi) == \
        rebuilt.estimate_costs(sm.state_ids, lo, hi)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), extra_queries=st.integers(1, 80))
def test_layout_manager_cost_vector_cache_invalidates(seed, extra_queries):
    """Cached LayoutManager cost vectors always equal a from-scratch
    computation over the *current* R-TBS sample, before and after the
    sample changes."""
    from repro.core import build_default_layout, make_generator
    from repro.core import layout_manager as lm
    from repro.core import workload as wl
    rng = np.random.default_rng(seed)
    data = rng.uniform(0, 100, size=(1500, 4))

    def query():
        lo = np.full(4, -np.inf)
        hi = np.full(4, np.inf)
        col = int(rng.integers(4))
        lo[col] = rng.uniform(0, 80)
        hi[col] = lo[col] + rng.uniform(1, 20)
        return wl.Query(lo=lo, hi=hi)

    init = build_default_layout(0, data, 4)
    mgr = lm.LayoutManager(data, make_generator("qdtree"), init,
                           lm.LayoutManagerConfig(rtbs_size=8), seed=seed)
    for i in range(1, 4):
        mgr.store[i] = build_default_layout(i, data, 4, sort_col=i % 4)
    for _ in range(5):
        mgr.rtbs.add(query())

    def fresh_vectors():
        qs = mgr.rtbs.sample()
        q_lo, q_hi = wl.stack_queries(qs)
        return {i: layouts.cost_vector(lay.meta, q_lo, q_hi)
                for i, lay in mgr.store.items()}

    first = mgr._cost_vectors(mgr.store)
    want = fresh_vectors()
    assert all(np.array_equal(first[i], want[i]) for i in mgr.store)
    assert mgr._cv_cache          # vectors of stored layouts were cached

    version_before = mgr.rtbs.version
    for _ in range(extra_queries):
        mgr.rtbs.add(query())
    second = mgr._cost_vectors(mgr.store)
    want = fresh_vectors()
    assert all(np.array_equal(second[i], want[i]) for i in mgr.store)
    if mgr.rtbs.version != version_before:
        # sample changed -> cache was rebuilt, not reused
        assert not any(second[i] is first[i] for i in mgr.store)
    else:
        # sample unchanged -> cached arrays reused verbatim
        assert all(second[i] is first[i] for i in mgr.store)
