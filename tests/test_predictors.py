"""Unit tests for the seed transition predictors (repro.core.predictors).

The *workload* forecasters that grew out of this module live in
``repro.forecast`` and are covered by ``tests/test_forecast.py``; these
tests pin the transition-plane helpers the D-UMTS consumes directly.
"""
import pickle

import pytest

from repro.core import mts
from repro.core.predictors import (GammaBiasedTransition,
                                   gamma_biased_transition,
                                   median_initialized_counter)


# ---------------------------------------------------------------------------
# median_initialized_counter (§IV-C mid-phase admission)
# ---------------------------------------------------------------------------

def test_median_empty_is_zero():
    assert median_initialized_counter({}) == 0.0


def test_median_odd_count_is_middle_value():
    assert median_initialized_counter({1: 0.2, 2: 0.9, 3: 0.4}) == 0.4


def test_median_even_count_is_midpoint():
    assert median_initialized_counter({1: 0.2, 2: 0.8}) == pytest.approx(0.5)


def test_median_ignores_key_order():
    a = median_initialized_counter({1: 0.7, 2: 0.1, 3: 0.3, 4: 0.5})
    b = median_initialized_counter({4: 0.5, 3: 0.3, 2: 0.1, 1: 0.7})
    assert a == b == pytest.approx(0.4)


# ---------------------------------------------------------------------------
# GammaBiasedTransition
# ---------------------------------------------------------------------------

def test_gamma_zero_recovers_uniform():
    w = {1: 0.9, 2: 0.1, 3: 0.5}
    assert GammaBiasedTransition(0.0)(w) == mts.uniform_transition(w)


def test_distribution_normalizes_and_orders_by_weight():
    probs = GammaBiasedTransition(2.0)({1: 0.9, 2: 0.1, 3: 0.5})
    assert sum(probs.values()) == pytest.approx(1.0)
    assert probs[1] > probs[3] > probs[2]


def test_zero_weight_is_floored_not_excluded():
    """States with weight 0 (full scan last phase) keep a tiny positive
    probability — the floor guards the power, it does not drop states."""
    probs = GammaBiasedTransition(1.0)({1: 0.0, 2: 1.0})
    assert probs[1] > 0.0
    assert sum(probs.values()) == pytest.approx(1.0)


def test_higher_gamma_sharpens_the_bias():
    w = {1: 0.9, 2: 0.3}
    soft = GammaBiasedTransition(1.0)(w)
    sharp = GammaBiasedTransition(4.0)(w)
    assert sharp[1] > soft[1]


def test_transition_pickles():
    fn = gamma_biased_transition(1.5)
    clone = pickle.loads(pickle.dumps(fn))
    w = {1: 0.9, 2: 0.1}
    assert clone(w) == fn(w)
    assert clone.gamma == 1.5


def test_factory_returns_callable_class_instance():
    assert isinstance(gamma_biased_transition(0.7), GammaBiasedTransition)
