"""Predictive decision plane: forecasters, grower and ForecastPolicy.

Three layers of coverage:

* Forecaster units — :func:`repro.forecast.template_key`,
  :class:`repro.forecast.PeriodDetector` and
  :class:`repro.forecast.EwmaMixtureForecaster` (period + trend branches,
  mixture sampling, pickling/determinism) plus the always-wrong
  :class:`repro.forecast.AdversarialForecaster` probe.
* :class:`repro.forecast.QdTreeGrower` admission discipline — held-out
  vetting, id reuse on rejection, the α-payback bar, pickling.
* :class:`repro.forecast.ForecastPolicy` golden traces — the gated-off
  wrapper is *bitwise* the bare reactive policy, and an adversarial
  (always-wrong) forecaster stays inside the α-bounded envelope with
  every pre-position charged through the existing executor ledger
  (``MigrationRecord.charged == alpha`` bitwise, atomic ≡ incremental).
"""
import pickle

import numpy as np
import pytest

from repro.core import OreoConfig, build_default_layout, layouts, \
    make_generator
from repro.core import layout_manager as lm
from repro.core import workload as wl
from repro.core.workload import make_drift_scenario
from repro.engine import InMemoryBackend, LayoutEngine, OreoPolicy
from repro.forecast import (GROWN_ID_BASE, AdversarialForecaster,
                            EwmaMixtureForecaster, Forecast, ForecastConfig,
                            ForecastPolicy, PeriodDetector, QdTreeGrower,
                            template_key)

COLS = 6


def make_query(template_id, col, lo_v, hi_v, cols=COLS):
    lo = np.full(cols, -np.inf)
    hi = np.full(cols, np.inf)
    lo[col], hi[col] = lo_v, hi_v
    return wl.Query(lo=lo, hi=hi, template_id=template_id)


# ---------------------------------------------------------------------------
# template_key
# ---------------------------------------------------------------------------

def test_template_key_uses_ground_truth_template_id():
    assert template_key(make_query(3, 0, 1.0, 2.0)) == ("tpl", 3)


def test_template_key_falls_back_to_predicate_columns():
    assert template_key(make_query(-1, 2, 1.0, 2.0)) == ("cols", 2)
    q = make_query(-1, 1, 0.0, 5.0)
    q.lo[4] = 3.0                        # one-sided predicate still counts
    assert template_key(q) == ("cols", 1, 4)


# ---------------------------------------------------------------------------
# PeriodDetector
# ---------------------------------------------------------------------------

def test_period_detector_finds_planted_cycle():
    codes = np.tile(np.repeat([0, 1, 2], 8), 4)      # period 24, 4 cycles
    p, frac = PeriodDetector().detect(codes)
    # blocky signals correlate at off-by-one shifts too (7 of 8 positions
    # per block), so the smallest qualifying period may land just short
    # of the true one — either reads the cycle correctly
    assert p in (23, 24)
    assert frac >= 0.85


def test_period_detector_rejects_constant_history():
    assert PeriodDetector().detect(np.zeros(128, dtype=np.int64)) is None


def test_period_detector_rejects_short_history():
    codes = np.tile(np.repeat([0, 1], 4), 3)         # 24 < min_history
    assert PeriodDetector(min_history=32).detect(codes) is None


def test_period_detector_prefers_smallest_period():
    codes = np.tile([0, 1, 0, 2], 32)                # period 4 (and 8, 12…)
    p, _ = PeriodDetector().detect(codes)
    assert p == 4


# ---------------------------------------------------------------------------
# EwmaMixtureForecaster
# ---------------------------------------------------------------------------

def cyclic_stream(blocks=12, block_len=8):
    """Template t in {0,1,2} for ``block_len`` queries, cycling."""
    qs = []
    for b in range(blocks):
        t = b % 3
        for j in range(block_len):
            qs.append(make_query(t, t, 10.0 * j, 10.0 * j + 5.0))
    return qs


def test_period_forecast_reads_key_off_the_cycle():
    f = EwmaMixtureForecaster()
    for q in cyclic_stream():                        # 96 obs, period 24
        f.observe(q)
    fc = f.forecast(lead=16)
    assert fc is not None
    assert fc.source == "period"
    # dwell is the observed block length; lead clamps to half of it
    assert fc.dwell == 8.0
    assert 1 <= fc.lead <= 4
    # 4 steps past the last B-block tail the cycle is back in template 0
    assert fc.key == ("tpl", 0)
    assert all(q.template_id == 0 for q in fc.queries)


def drift_stream(n=200, seed=0):
    """Template 1's share ramps 0 -> 1 with seeded noise (aperiodic)."""
    ramp = np.linspace(0.0, 1.0, n)
    flags = np.random.default_rng(seed).uniform(size=n) < ramp
    return [make_query(1 if f else 0, 1 if f else 0, 10.0, 40.0)
            for f in flags]


def test_trend_forecast_fires_on_gradual_drift_with_mixture_sample():
    f = EwmaMixtureForecaster()
    for q in drift_stream():
        f.observe(q)
    fc = f.forecast(lead=16)
    assert fc is not None
    assert fc.source == "trend"
    assert fc.key == ("tpl", 1)
    assert fc.dwell == f.trend_dwell
    # mid-drift the sample is a *mixture*: the old template keeps the
    # mass the projected share leaves it, not zero
    tids = {q.template_id for q in fc.queries}
    assert tids == {0, 1}
    riser = sum(q.template_id == 1 for q in fc.queries)
    assert riser / len(fc.queries) >= f.trend_share


def test_single_template_stream_yields_no_forecast():
    f = EwmaMixtureForecaster()
    for j in range(128):
        f.observe(make_query(0, 0, 1.0 * j, 1.0 * j + 5.0))
    assert f.forecast() is None


def test_short_history_yields_no_forecast():
    f = EwmaMixtureForecaster()
    for q in cyclic_stream(blocks=2):                # 16 < min_history
        f.observe(q)
    assert f.forecast() is None


def test_forecaster_pickles_mid_stream_and_stays_deterministic():
    stream = cyclic_stream()
    a = EwmaMixtureForecaster()
    for q in stream[:60]:
        a.observe(q)
    b = pickle.loads(pickle.dumps(a))
    for q in stream[60:]:
        a.observe(q)
        b.observe(q)
    fa, fb = a.forecast(16), b.forecast(16)
    assert (fa.key, fa.source, fa.confidence, fa.dwell, fa.lead) \
        == (fb.key, fb.source, fb.confidence, fb.dwell, fb.lead)
    la, ha = wl.stack_queries(fa.queries)
    lb, hb = wl.stack_queries(fb.queries)
    assert np.array_equal(la, lb) and np.array_equal(ha, hb)


# ---------------------------------------------------------------------------
# AdversarialForecaster
# ---------------------------------------------------------------------------

def test_adversarial_mirrors_ranges_under_a_sentinel_key():
    f = AdversarialForecaster()
    low, high = make_query(0, 0, 10.0, 20.0), make_query(1, 0, 70.0, 80.0)
    f.observe(low)
    f.observe(high)
    fc = f.forecast()
    assert fc.source == "adversarial"
    assert fc.dwell >= 1e6
    # the sentinel key never matches any realized query's key
    assert fc.key != template_key(low) and fc.key != template_key(high)
    # mirrored within the observed domain [10, 80]: [10,20] <-> [70,80]
    assert fc.queries[0].lo[0] == 70.0 and fc.queries[0].hi[0] == 80.0
    assert fc.queries[1].lo[0] == 10.0 and fc.queries[1].hi[0] == 20.0


def test_adversarial_empty_history_yields_no_forecast():
    assert AdversarialForecaster().forecast() is None


# ---------------------------------------------------------------------------
# QdTreeGrower
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def table():
    return np.random.default_rng(5).uniform(0, 100, size=(2_000, COLS))


def narrow_forecast(dwell=200.0):
    qs = [make_query(0, 0, 5.0 * j, 5.0 * j + 4.0) for j in range(16)]
    return Forecast(key=("tpl", 0), queries=qs, source="trend",
                    confidence=0.9, dwell=dwell, lead=8)


def whole_table_meta(table):
    return layouts.metadata_from_assignment(
        table, np.zeros(len(table), dtype=np.int64), 1)


def test_grower_admits_against_empty_state_space(table):
    g = QdTreeGrower(table, 8, seed=3)
    cand = g.propose(narrow_forecast(), [])
    assert cand is not None
    assert cand.layout_id == GROWN_ID_BASE
    assert cand.meta.num_partitions <= 8
    assert g.info() == {"grown_proposed": 1, "grown_admitted": 1}
    again = g.propose(narrow_forecast(), [])
    assert again.layout_id == GROWN_ID_BASE + 1      # id consumed on admit


def test_grower_rejects_covered_regime_and_reuses_the_id(table):
    g = QdTreeGrower(table, 8, seed=3)
    cand = g.propose(narrow_forecast(), [])
    # the admitted tree itself now covers the regime -> next proposal
    # fails the floor/gain bars and its id is NOT consumed
    assert g.propose(narrow_forecast(), [cand.meta]) is None
    assert g.next_id == GROWN_ID_BASE + 1
    assert g.propose(narrow_forecast(), []).layout_id == GROWN_ID_BASE + 1


def test_grower_needs_a_minimum_forecast_sample(table):
    g = QdTreeGrower(table, 8, min_queries=8, seed=3)
    fc = narrow_forecast()
    fc.queries = fc.queries[:5]
    assert g.propose(fc, []) is None
    assert g.num_proposed == 0                       # not even counted


def test_grower_alpha_payback_bar_blocks_unprofitable_growth(table):
    """Every grown state the plane visits inserts an α-priced hop; a
    saving*dwell that cannot cover it is rejected however good the tree."""
    base = [whole_table_meta(table)]                 # best existing = 1.0
    greedy = QdTreeGrower(table, 8, alpha=0.0, seed=3)
    assert greedy.propose(narrow_forecast(), base) is not None
    frugal = QdTreeGrower(table, 8, alpha=1e9, seed=3)
    assert frugal.propose(narrow_forecast(), base) is None
    # a longer predicted dwell can tip the same candidate over the bar
    priced = QdTreeGrower(table, 8, alpha=50.0, seed=3)
    assert priced.propose(narrow_forecast(dwell=10.0), base) is None
    assert priced.propose(narrow_forecast(dwell=1e4), base) is not None


def test_grower_pickles_and_reproposes_identically(table):
    g = QdTreeGrower(table, 8, seed=3)
    g.propose(narrow_forecast(), [])
    clone = pickle.loads(pickle.dumps(g))
    a = g.propose(narrow_forecast(), [])
    b = clone.propose(narrow_forecast(), [])
    assert a.layout_id == b.layout_id == GROWN_ID_BASE + 1
    assert np.array_equal(a.meta.mins, b.meta.mins)
    assert np.array_equal(a.meta.maxs, b.meta.maxs)


# ---------------------------------------------------------------------------
# ForecastPolicy golden traces
# ---------------------------------------------------------------------------

ALPHA, DELTA, PARTS = 10.0, 5, 8


@pytest.fixture(scope="module")
def data():
    return np.random.default_rng(11).uniform(0, 100, size=(3_000, COLS))


@pytest.fixture(scope="module")
def streams(data):
    lo, hi = data.min(0), data.max(0)
    out = {}
    for name in ("cyclic_diurnal", "gradual_drift"):
        fs = make_drift_scenario(name, lo, hi, num_tenants=1,
                                 queries_per_tenant=400, seed=7)
        out[name] = fs.per_tenant[fs.tenant_ids[0]]
    return out


def make_inner(data, seed=2):
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=ALPHA, seed=seed, delta=DELTA,
                     manager=lm.LayoutManagerConfig(target_partitions=PARTS,
                                                    window_size=60,
                                                    gen_every=30))
    return OreoPolicy(data, build_default_layout(0, data, PARTS), gen, cfg)


def run_engine(policy, data, stream, **kw):
    return LayoutEngine(policy, InMemoryBackend(data),
                        delta=DELTA, **kw).run(stream)


def adversarial_policy(data, **cfg_kw):
    cfg = ForecastConfig(grow=False, margin=0.0, min_gap=4, **cfg_kw)
    return ForecastPolicy(make_inner(data),
                          forecaster=AdversarialForecaster(), config=cfg)


@pytest.mark.parametrize("scenario", ["cyclic_diurnal", "gradual_drift"])
def test_gated_off_wrapper_is_bitwise_the_bare_policy(scenario, data,
                                                      streams):
    """budget_frac=0 + grow=False must consume no randomness, issue no
    moves and register no states: the trace is bitwise reactive."""
    stream = streams[scenario]
    bare_pol = make_inner(data)
    bare = run_engine(bare_pol, data, stream)
    pol = ForecastPolicy(make_inner(data),
                         config=ForecastConfig(budget_frac=0.0, grow=False))
    gated = run_engine(pol, data, stream)
    assert np.array_equal(bare.query_costs, gated.query_costs)
    assert bare.reorg_indices == gated.reorg_indices
    assert np.array_equal(bare.state_seq, gated.state_seq)
    assert pol.prepositions == 0
    assert gated.info["grown_admitted"] == 0
    assert pol.inner.dumts.events == bare_pol.dumts.events


def test_adversarial_forecaster_stays_inside_the_alpha_envelope(data,
                                                                streams):
    """The acceptance golden: an always-wrong forecaster with a zero
    pre-position margin degrades the trace by at most 3α per wrong move
    (α pre-position charge + up to α excess query cost before the
    mispredicted counter fills + α corrective jump), and the number of
    moves it may buy is clamped to the reactive movement budget."""
    stream = streams["cyclic_diurnal"]
    bare = run_engine(make_inner(data), data, stream)
    pol = adversarial_policy(data)
    res = run_engine(pol, data, stream)
    assert pol.prepositions > 0                      # the probe really fires
    # hard clamp held at every fire: P+1 <= frac * reactive_moves, and
    # reactive_moves only grows afterwards
    assert pol.prepositions \
        <= pol.config.budget_frac * pol.reactive_moves
    # every pre-position is a deterministic "preposition" event on the
    # D-UMTS ledger; reactive jumps keep their own reasons
    events = pol.inner.dumts.events
    assert sum(e.reason == "preposition" for e in events) == pol.prepositions
    assert pol.reactive_moves \
        == sum(e.reason != "preposition" for e in events)
    # the sentinel key never comes true
    assert pol.forecast_checks > 0 and pol.forecast_hits == 0
    # worst-case envelope on the realized trace
    assert res.total_cost \
        <= bare.total_cost + pol.prepositions * 3.0 * ALPHA
    # every charged reorg (reactive or pre-positioned) costs exactly α
    assert res.total_reorg_cost == ALPHA * len(res.reorg_indices)


def test_adversarial_prepositions_ride_the_incremental_ledger(data,
                                                              streams):
    """Bitwise ledger checks riding the existing executor path: with an
    unbounded per-tick budget the incremental trace is bit-identical to
    the atomic one *with pre-positions firing*, and every migration —
    pre-positioned or reactive — charges exactly alpha, bitwise."""
    stream = streams["cyclic_diurnal"]
    atomic_pol = adversarial_policy(data)
    atomic = run_engine(atomic_pol, data, stream)
    incr_pol = adversarial_policy(data)
    eng = LayoutEngine(incr_pol, InMemoryBackend(data), delta=DELTA,
                       incremental=True)
    incr = eng.run(stream)
    assert atomic_pol.prepositions == incr_pol.prepositions > 0
    assert np.array_equal(atomic.query_costs, incr.query_costs)
    assert atomic.reorg_indices == incr.reorg_indices
    assert np.array_equal(atomic.state_seq, incr.state_seq)
    migs = eng.reorg_executor.migrations
    assert len(migs) > 0
    for mig in migs:
        assert mig.completed_at == mig.begun_at      # unbounded budget
        assert mig.charged == mig.alpha              # bitwise ledger close


def test_adversarial_bounded_migration_ledger_still_closes(data, streams):
    """Under a real row budget migrations span steps; completed ones must
    still close their charge ledger at exactly alpha, bitwise."""
    stream = streams["cyclic_diurnal"]
    pol = adversarial_policy(data)
    eng = LayoutEngine(pol, InMemoryBackend(data), delta=DELTA,
                       incremental=True, rows_per_tick=400)
    eng.run(stream)
    assert pol.prepositions > 0
    done = [m for m in eng.reorg_executor.migrations if m.completed_at >= 0]
    assert len(done) > 0
    assert any(m.completed_at > m.begun_at for m in done)   # really spans
    for mig in done:
        assert mig.charged == mig.alpha


def test_preposition_budget_clamp_binds(data, streams):
    stream = streams["cyclic_diurnal"]
    free = adversarial_policy(data)
    run_engine(free, data, stream)
    clamped = adversarial_policy(data, budget_frac=0.1)
    run_engine(clamped, data, stream)
    assert clamped.prepositions <= 0.1 * clamped.reactive_moves
    assert clamped.prepositions < free.prepositions


def test_forecast_engine_pickles_mid_run_and_continues_identically(data,
                                                                   streams):
    """Cross-process tenant migration: a whole engine with a live
    ForecastPolicy (forecaster history, grower state, cooldowns) pickles
    mid-run and the resumed trace equals the uninterrupted one."""
    queries = streams["cyclic_diurnal"].queries
    fc = ForecastConfig(min_gap=4, forecast_every=5)
    straight = LayoutEngine(ForecastPolicy(make_inner(data), config=fc),
                            InMemoryBackend(data), delta=DELTA)
    for q in queries:
        straight.step_fast(q)
    resumed = LayoutEngine(ForecastPolicy(make_inner(data), config=fc),
                           InMemoryBackend(data), delta=DELTA)
    for q in queries[:150]:
        resumed.step_fast(q)
    resumed = pickle.loads(pickle.dumps(resumed))
    for q in queries[150:]:
        resumed.step_fast(q)
    a, b = straight.result(), resumed.result()
    assert np.array_equal(a.query_costs, b.query_costs)
    assert a.reorg_indices == b.reorg_indices
    assert np.array_equal(a.state_seq, b.state_seq)
    assert a.info["prepositions"] == b.info["prepositions"]
    assert a.info["forecasts"] == b.info["forecasts"]
