"""Tests for the stepwise LayoutEngine API: golden traces vs. the legacy
batch runner, policy/backend protocol behavior, and satellite fixes."""
import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, cost_model as cm,
                        generate_workload, layouts, make_generator,
                        make_templates, mts, predictors)
from repro.core import layout_manager as lm
from repro.core.oreo import OreoRunner, RunResult
from repro.engine import (DiskBackend, GreedyPolicy, InMemoryBackend,
                          LayoutEngine, MTSOptimalPolicy, OreoPolicy,
                          RegretPolicy, StaticPolicy, StorageBackend)


@pytest.fixture(scope="module")
def bench():
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(20_000, 8))
    templates = make_templates(4, 8, rng)
    stream = generate_workload(templates, data.min(0), data.max(0),
                               total_queries=1500, seed=1,
                               segment_length=(300, 500))
    return data, stream


def legacy_oreo_run(data, initial_layout, generator, config, stream):
    """The pre-engine OreoRunner.run loop, inlined verbatim as the golden
    reference for the stepwise engine."""
    manager = lm.LayoutManager(data, generator, initial_layout,
                               config.manager, seed=config.seed)
    dumts = mts.DynamicUMTS(
        alpha=config.alpha, initial_states=[initial_layout.layout_id],
        seed=config.seed,
        transition_fn=predictors.gamma_biased_transition(config.gamma),
        stay_on_phase_start=config.stay_on_phase_start)
    model = cm.CostModel(alpha=config.alpha)
    query_costs, reorg_indices, state_seq = [], [], []
    physical = manager.store[dumts.current_state]
    physical.materialize(data)
    pending = []
    for i, q in enumerate(stream):
        added, removed = manager.on_query(q, dumts.current_state)
        for sid in added:
            dumts.add_state(sid)
        for sid in removed:
            dumts.remove_state(sid)
        costs = {}
        for sid in set(dumts.states) | set(dumts.pending_additions):
            costs[sid] = (model.query_cost(manager.store[sid], q)
                          if sid in manager.store else 1.0)
        prev = dumts.num_moves
        state = dumts.observe(costs)
        if dumts.num_moves > prev:
            reorg_indices.append(i)
            pending.append((i + config.delta, state))
        while pending and pending[0][0] <= i:
            _, sid = pending.pop(0)
            if sid in manager.store:
                physical = manager.store[sid]
                physical.materialize(data)
        query_costs.append(
            float(layouts.eval_cost(physical.serving_meta(), q.lo, q.hi)))
        state_seq.append(state)
    return (np.asarray(query_costs), reorg_indices,
            np.asarray(state_seq, dtype=np.int64))


# ---------------------------------------------------------------------------
# Golden traces: engine == legacy loop, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [0, 25])
def test_engine_matches_legacy_oreo_trace(bench, delta):
    data, stream = bench
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=40.0, seed=3, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=16))
    qc, ri, ss = legacy_oreo_run(data, build_default_layout(0, data, 16),
                                 gen, cfg, stream)
    policy = OreoPolicy(data, build_default_layout(0, data, 16), gen, cfg)
    res = LayoutEngine(policy, InMemoryBackend(data),
                       delta=cfg.delta).run(stream)
    assert np.array_equal(qc, res.query_costs)      # bit-for-bit
    assert ri == res.reorg_indices
    assert np.array_equal(ss, res.state_seq)


def test_deprecated_runner_delegates_to_engine(bench):
    data, stream = bench
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=40.0, seed=3,
                     manager=lm.LayoutManagerConfig(target_partitions=16))
    with pytest.warns(DeprecationWarning):
        shim = OreoRunner(data, build_default_layout(0, data, 16), gen, cfg)
    res = shim.run(stream)
    policy = OreoPolicy(data, build_default_layout(0, data, 16), gen, cfg)
    direct = LayoutEngine(policy, InMemoryBackend(data)).run(stream)
    assert np.array_equal(res.query_costs, direct.query_costs)
    assert res.reorg_indices == direct.reorg_indices
    assert res.info["competitive_bound"] == direct.info["competitive_bound"]


# ---------------------------------------------------------------------------
# Stepwise API
# ---------------------------------------------------------------------------

def test_step_returns_per_query_observability(bench):
    data, stream = bench
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=30.0, seed=0,
                     manager=lm.LayoutManagerConfig(target_partitions=16))
    engine = LayoutEngine(
        OreoPolicy(data, build_default_layout(0, data, 16), gen, cfg),
        InMemoryBackend(data))
    steps = [engine.step(q) for q in stream.queries[:400]]
    assert [s.index for s in steps] == list(range(400))
    assert all(0.0 <= s.query_cost <= 1.0 for s in steps)
    assert all(s.serving_state is not None for s in steps)
    charged = [s.index for s in steps if s.reorg_charged]
    res = engine.result()
    assert charged == res.reorg_indices
    assert len(res.query_costs) == 400
    # run() on the remaining queries continues the same trace
    full = engine.run(stream.queries[400:800])
    assert len(full.query_costs) == 800


def test_dumts_invariant_moves_times_alpha_is_reorg_cost(bench):
    """With no state evictions, every D-UMTS move is exactly one charged
    reorganization: num_moves * alpha == total_reorg_cost."""
    data, stream = bench
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=30.0, seed=1,
                     manager=lm.LayoutManagerConfig(target_partitions=16,
                                                    max_states=64))
    policy = OreoPolicy(data, build_default_layout(0, data, 16), gen, cfg)
    res = LayoutEngine(policy, InMemoryBackend(data)).run(stream)
    assert policy.dumts.num_moves * cfg.alpha == res.total_reorg_cost
    assert res.num_reorgs == policy.dumts.num_moves


def test_baseline_policies_share_engine_loop(bench):
    """Greedy / Regret / Static / MTS-Optimal all run through LayoutEngine
    and keep their documented orderings."""
    data, stream = bench
    gen = make_generator("qdtree")
    alpha = 40.0
    def init():
        return build_default_layout(0, data, 16)

    def run(policy):
        return LayoutEngine(policy, InMemoryBackend(data)).run(stream)

    greedy = run(GreedyPolicy(data, init(), gen, alpha))
    regret = run(RegretPolicy(data, init(), gen, alpha))
    static = run(StaticPolicy(data, stream, gen, alpha,
                              target_partitions=16))
    mtsopt = run(MTSOptimalPolicy(data, stream, gen, alpha,
                                  target_partitions=16))
    assert greedy.num_reorgs >= regret.num_reorgs
    assert static.num_reorgs == 0
    for res in (greedy, regret, static, mtsopt):
        assert len(res.query_costs) == len(stream)
        assert np.all(res.query_costs >= 0) and np.all(res.query_costs <= 1)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

def test_backend_protocol_conformance():
    data = np.random.default_rng(0).uniform(0, 1, size=(100, 3))
    assert isinstance(InMemoryBackend(data), StorageBackend)


def test_batched_cost_estimation_bit_identical(bench):
    """eval_cost_states == per-state eval_cost, bitwise, including layouts
    with differing partition counts."""
    data, stream = bench
    gen = make_generator("qdtree")
    metas = [build_default_layout(0, data, 16).meta,
             gen(1, data, stream.queries[:100], 16).meta,
             gen(2, data, stream.queries[200:300], 7).meta]
    for q in stream.queries[:50]:
        batched = layouts.eval_cost_states(metas, q.lo, q.hi)
        singles = [float(layouts.eval_cost(m, q.lo, q.hi)) for m in metas]
        assert batched.tolist() == singles


@pytest.mark.parametrize("delta", [0, 25])
def test_run_batched_matches_stepwise(bench, delta):
    """run()'s block-serve fast path is bit-identical to stepping: same
    costs, same reorg indices, same state sequence."""
    data, stream = bench
    gen = make_generator("qdtree")

    def engine():
        cfg = OreoConfig(alpha=40.0, seed=3, delta=delta,
                         manager=lm.LayoutManagerConfig(target_partitions=16))
        policy = OreoPolicy(data, build_default_layout(0, data, 16), gen, cfg)
        return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)

    fast = engine().run(stream)                       # auto-detected fast path
    slow = engine().run(stream, batch_serve=False)    # forced stepwise
    assert np.array_equal(fast.query_costs, slow.query_costs)
    assert fast.reorg_indices == slow.reorg_indices
    assert np.array_equal(fast.state_seq, slow.state_seq)


def test_serve_block_matches_serve(bench):
    data, stream = bench
    backend = InMemoryBackend(data)
    backend.register(build_default_layout(0, data, 16))
    backend.activate(0)
    qs = stream.queries[:64]
    from repro.core.workload import stack_queries
    q_lo, q_hi = stack_queries(qs)
    block = backend.serve_block(q_lo, q_hi)
    singles = np.array([backend.serve(q) for q in qs])
    assert np.array_equal(block, singles)


def test_estimate_costs_modes_bit_identical(bench):
    """StateMatrix-backed estimates == the reference re-padding path ==
    per-state eval_cost, for layouts with differing partition counts."""
    data, stream = bench
    gen = make_generator("qdtree")
    lays = [build_default_layout(0, data, 16),
            gen(1, data, stream.queries[:100], 16),
            gen(2, data, stream.queries[200:300], 7)]
    mem = InMemoryBackend(data)
    ref = InMemoryBackend(data, compute="reference")
    for b in (mem, ref):
        for lay in lays:
            b.register(lay)
    for q in stream.queries[:50]:
        got = mem.estimate_costs([0, 1, 2], q)
        assert got == ref.estimate_costs([0, 1, 2], q)
        for lay in lays:
            assert got[lay.layout_id] == float(
                layouts.eval_cost(lay.meta, q.lo, q.hi))


def test_disk_backend_matches_in_memory_decisions(bench, tmp_path):
    """The same engine + policy over DiskBackend reorganizes real partition
    files in the background and serves the same logical costs."""
    data, stream = bench
    small = data[:8_000]
    qs = stream.queries[:300]
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=15.0, seed=0, delta=10,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=80,
                                                    gen_every=40))
    disk = DiskBackend(small, str(tmp_path / "table"), background=True)
    res_disk = LayoutEngine(
        OreoPolicy(small, build_default_layout(0, small, 8), gen, cfg),
        disk, delta=cfg.delta).run(qs)
    res_mem = LayoutEngine(
        OreoPolicy(small, build_default_layout(0, small, 8), gen, cfg),
        InMemoryBackend(small), delta=cfg.delta).run(qs)
    assert np.array_equal(res_disk.state_seq, res_mem.state_seq)
    assert res_disk.reorg_indices == res_mem.reorg_indices
    # scanning real files reads exactly the rows the zone maps cannot skip
    np.testing.assert_allclose(res_disk.query_costs, res_mem.query_costs,
                               atol=1e-12)
    disk.close()
    # every charged reorg produced one background rewrite; the initial table
    # load is accounted separately
    assert len(disk.reorg_seconds) == res_disk.num_reorgs
    assert disk.initial_write_seconds > 0.0


# ---------------------------------------------------------------------------
# Satellite fixes
# ---------------------------------------------------------------------------

def test_cumulative_consistent_with_total_cost():
    res = RunResult(name="x", alpha=10.0,
                    query_costs=np.array([0.5, 0.25, 0.125, 0.0625]),
                    reorg_indices=[1, 3],
                    state_seq=np.zeros(4, dtype=np.int64))
    first = res.cumulative()
    assert first[-1] == pytest.approx(res.total_cost)
    # repeated calls are stable and alpha is charged once per reorg index
    assert np.array_equal(first, res.cumulative())
    assert first[0] == pytest.approx(0.5)
    assert first[1] == pytest.approx(0.5 + 0.25 + 10.0)


def test_maybe_evict_terminates_on_empty_sample():
    """With an empty R-TBS sample every pairwise distance is inf; eviction
    must still make progress and respect max_states."""
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(2_000, 4))
    init = build_default_layout(0, data, 4)
    cfg = lm.LayoutManagerConfig(target_partitions=4, max_states=2)
    mgr = lm.LayoutManager(data, make_generator("qdtree"), init, cfg, seed=0)
    # fill the store past the cap without feeding the R-TBS any queries
    for i in range(1, 5):
        mgr.store[i] = build_default_layout(i, data, 4)
    removed = mgr._maybe_evict(current_state=0)
    assert len(mgr.store) == cfg.max_states
    assert 0 in mgr.store                       # never evicts current
    assert removed == sorted(removed, reverse=True)  # newest evicted first


def test_layout_distance_empty_sample_is_infinite():
    assert layouts.layout_distance(np.zeros(0), np.zeros(0)) == np.inf
    assert layouts.layout_distance(np.array([0.5]), np.array([0.5])) == 0.0


def _metadata_loop_reference(data, assignment, num_partitions, row_scale=1.0):
    """The pre-vectorization per-partition loop, kept as the oracle."""
    n, c = data.shape
    mins = np.full((num_partitions, c), np.inf)
    maxs = np.full((num_partitions, c), -np.inf)
    rows = np.zeros(num_partitions, dtype=np.float64)
    order = np.argsort(assignment, kind="stable")
    sorted_assign = assignment[order]
    bounds = np.searchsorted(sorted_assign, np.arange(num_partitions + 1))
    for p in range(num_partitions):
        lo, hi = bounds[p], bounds[p + 1]
        if hi > lo:
            chunk = data[order[lo:hi]]
            mins[p] = chunk.min(axis=0)
            maxs[p] = chunk.max(axis=0)
            rows[p] = (hi - lo) * row_scale
    return layouts.PartitionMetadata(mins=mins, maxs=maxs, rows=rows)


@pytest.mark.parametrize("case", ["dense", "empty_partitions", "out_of_range",
                                  "no_rows", "scaled"])
def test_metadata_from_assignment_matches_loop_reference(case):
    """The reduceat vectorization is exactly equal to the per-partition loop,
    including empty partitions and out-of-range assignments."""
    rng = np.random.default_rng(sum(ord(ch) for ch in case))
    n, c, p = 3000, 5, 16
    data = rng.uniform(-10, 10, (n, c))
    scale = 1.0
    if case == "dense":
        assignment = rng.integers(0, p, n)
    elif case == "empty_partitions":
        assignment = rng.integers(0, 3, n) * 5      # only partitions 0, 5, 10
    elif case == "out_of_range":
        assignment = rng.integers(-2, p + 4, n)     # some rows out of range
    elif case == "no_rows":
        data, assignment = data[:0], rng.integers(0, p, 0)
    else:
        assignment, scale = rng.integers(0, p, n), 137.5
    got = layouts.metadata_from_assignment(data, assignment, p,
                                           row_scale=scale)
    want = _metadata_loop_reference(data, assignment, p, row_scale=scale)
    assert np.array_equal(got.mins, want.mins)
    assert np.array_equal(got.maxs, want.maxs)
    assert np.array_equal(got.rows, want.rows)
