"""Tests for the multi-tenant FleetEngine, reorg schedulers, and the
drift-scenario registry: golden per-tenant identity under the unlimited
scheduler, charge-invariance + Δ-delay bounds under constrained schedulers,
and DiskBackend correctness under scheduler-delayed prepare/activate."""
import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, make_generator,
                        workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import DRIFT_SCENARIOS, make_drift_scenario
from repro.engine import (Decision, DiskBackend, FleetEngine, InMemoryBackend,
                          KConcurrentScheduler, LayoutEngine, OreoPolicy,
                          ReorgScheduler, TokenBucketScheduler,
                          UnlimitedScheduler)


@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(100 + t).uniform(
        0, 100, size=(4_000, 6)) for t in range(3)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, alpha=10.0, delta=5, seed=2):
    gen = make_generator("qdtree")
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8), gen, cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


class FlipFlopPolicy:
    """Deterministic contention driver: charges a reorganization to the
    other of two prebuilt layouts every ``period`` queries."""

    name = "FlipFlop"

    def __init__(self, layouts_, period, alpha=1.0):
        assert len(layouts_) == 2
        self.layouts = list(layouts_)
        self.period = period
        self.alpha = alpha
        self.cur = 0

    def bind(self, backend):
        for lay in self.layouts:
            backend.register(lay)
        return self.layouts[0].layout_id

    def decide(self, index, query, backend):
        if (index + 1) % self.period == 0:
            self.cur = 1 - self.cur
            return Decision(state=self.layouts[self.cur].layout_id,
                            reorg=True)
        return Decision(state=self.layouts[self.cur].layout_id)

    def info(self):
        return {}


def flipflop_engine(data, backend, period=10, delta=4):
    lays = [build_default_layout(0, data, 8, sort_col=0),
            build_default_layout(1, data, 8, sort_col=1)]
    return LayoutEngine(FlipFlopPolicy(lays, period), backend, delta=delta)


def serving_transitions(steps):
    """Per-tenant (tenant_index, new_serving_state) transitions from a list
    of FleetStepResults, keyed by tenant."""
    out = {}
    last = {}
    idx = {}
    for fs in steps:
        tid = fs.tenant_id
        j = idx.get(tid, 0)
        s = fs.step.serving_state
        if tid in last and s != last[tid]:
            out.setdefault(tid, []).append((j, s))
        last[tid] = s
        idx[tid] = j + 1
    return out


# ---------------------------------------------------------------------------
# Golden identity: unlimited scheduler == standalone engines, bit for bit
# ---------------------------------------------------------------------------

def test_unlimited_fleet_bit_identical_to_standalone(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                             queries_per_tenant=300, seed=7)
    fleet = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids}, UnlimitedScheduler())
    res = fleet.run(fs)
    assert res.scheduler == "unlimited"
    assert res.swaps_deferred == 0
    assert res.ticks == len(fs)
    for tid in fs.tenant_ids:
        solo = oreo_engine(tenant_data[tid]).run(fs.per_tenant[tid])
        ft = res.per_tenant[tid]
        assert np.array_equal(solo.query_costs, ft.query_costs)
        assert solo.reorg_indices == ft.reorg_indices
        assert np.array_equal(solo.state_seq, ft.state_seq)


def test_fleet_timing_fields_aggregate_per_tenant(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("cyclic_diurnal", lo, hi, num_tenants=3,
                             queries_per_tenant=120, seed=1)
    fleet = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids})
    res = fleet.run(fs)
    per = list(res.per_tenant.values())
    assert all(r.decide_seconds > 0 for r in per)
    assert all(r.serve_seconds > 0 for r in per)
    assert res.decide_seconds == pytest.approx(
        sum(r.decide_seconds for r in per))
    assert res.wall_seconds == pytest.approx(
        res.decide_seconds + res.reorg_seconds + res.serve_seconds)
    assert all(r.wall_seconds == pytest.approx(
        r.decide_seconds + r.reorg_seconds + r.serve_seconds) for r in per)


# ---------------------------------------------------------------------------
# Constrained schedulers: charges unchanged, only swap timing shifts
# ---------------------------------------------------------------------------

def contended_fleet(tenant_data, scheduler, backend_fn=None, period=10,
                    delta=4, weights=(4, 1, 1), n_per_tenant=200):
    """Fleet of FlipFlop tenants over a skewed deterministic interleave.

    With uniform weights a k=1 release pipeline drains exactly at the due-
    step spacing and nothing ever waits; a skewed interleave (one busy
    tenant, sparse others holding the grant between their steps) produces
    genuine apply-time deferrals.
    """
    backend_fn = backend_fn or (lambda tid, d: InMemoryBackend(d))
    tenants = {tid: flipflop_engine(d, backend_fn(tid, d), period=period,
                                    delta=delta)
               for tid, d in tenant_data.items()}
    fleet = FleetEngine(tenants, scheduler)
    tids = sorted(tenant_data)
    weights = {tid: float(w) for tid, w in zip(tids, weights)}
    credits = {tid: 0.0 for tid in tids}
    counts = {tid: 0 for tid in tids}
    rng = np.random.default_rng(0)
    c = next(iter(tenant_data.values())).shape[1]
    events = []
    while len(events) < n_per_tenant * len(tids):
        live = [t for t in tids if counts[t] < n_per_tenant]
        for t in live:
            credits[t] += weights[t]
        pick = max(live, key=lambda t: credits[t])
        credits[pick] -= sum(weights[t] for t in live)
        lo = np.full(c, -np.inf)
        hi = np.full(c, np.inf)
        col = counts[pick] % c
        lo[col], hi[col] = np.sort(rng.uniform(0, 100, size=2))
        events.append((pick, wl.Query(lo=lo, hi=hi)))
        counts[pick] += 1
    steps = [fleet.step(tid, q) for tid, q in events]
    return fleet, steps


def test_k1_scheduler_preserves_charges_and_delta_bounds(tenant_data):
    period, delta = 10, 4
    fleet, steps = contended_fleet(tenant_data, KConcurrentScheduler(1),
                                   period=period, delta=delta)
    res = fleet.result()
    # contention actually happened and total charges are untouched by it;
    # swaps_deferred counts distinct swaps, so it can never exceed charges
    assert 0 < res.swaps_deferred <= res.num_reorgs
    assert res.deferred_ticks >= res.swaps_deferred
    solo_charges = [i for i in range(200) if (i + 1) % period == 0]
    for tid in fleet.tenant_ids:
        ft = res.per_tenant[tid]
        assert ft.reorg_indices == solo_charges
        # every serving transition obeys the tenant's own Delta-delay:
        # a swap charged at i can land no earlier than tenant index i+delta
        charges = list(ft.reorg_indices)
        for j, sid in serving_transitions(steps).get(tid, []):
            i = charges.pop(0)
            assert j >= i + delta
    # with k=1 at most one reorganization is ever in flight
    assert fleet.scheduler.in_flight <= 1


def test_unlimited_flipflop_swaps_land_exactly_on_due(tenant_data):
    period, delta = 10, 4
    fleet, steps = contended_fleet(tenant_data, UnlimitedScheduler(),
                                   period=period, delta=delta)
    res = fleet.result()
    assert res.swaps_deferred == 0
    for tid in fleet.tenant_ids:
        trans = serving_transitions(steps).get(tid, [])
        assert trans, "flip-flop must actually swap"
        for (j, _), i in zip(trans, res.per_tenant[tid].reorg_indices):
            assert j == i + delta          # standalone timing, exactly due


def test_k1_total_charges_match_unlimited(tenant_data):
    """Scheduler pressure shifts *when* swaps land, never what was charged."""
    f_unl, _ = contended_fleet(tenant_data, UnlimitedScheduler())
    f_k1, _ = contended_fleet(tenant_data, KConcurrentScheduler(1))
    r_unl, r_k1 = f_unl.result(), f_k1.result()
    assert r_unl.total_reorg_cost == r_k1.total_reorg_cost
    assert r_unl.num_reorgs == r_k1.num_reorgs
    for tid in f_unl.tenant_ids:
        assert (r_unl.per_tenant[tid].reorg_indices
                == r_k1.per_tenant[tid].reorg_indices)
        assert np.array_equal(r_unl.per_tenant[tid].state_seq,
                              r_k1.per_tenant[tid].state_seq)


def test_zero_budget_token_bucket_freezes_serving_layout(tenant_data):
    fleet, steps = contended_fleet(
        tenant_data, TokenBucketScheduler(rate=0.0, capacity=0.0))
    res = fleet.result()
    # every charged swap eventually waits, and each is counted exactly once
    assert 0 < res.swaps_deferred <= res.num_reorgs
    # charges still happen (alpha is charged at decision time) ...
    assert res.num_reorgs > 0
    # ... but no physical swap is ever granted: serving never changes
    for fs in steps:
        assert fs.step.serving_state == 0
    assert fleet.scheduler.grants == 0


def test_token_bucket_refill_allows_late_swaps(tenant_data):
    fleet, steps = contended_fleet(
        tenant_data, TokenBucketScheduler(rate=0.01, capacity=1.0,
                                          initial=0.0))
    res = fleet.result()
    # ~6 tokens drip in over 600 ticks: some swaps land, some wait
    transitions = serving_transitions(steps)
    assert any(transitions.get(tid) for tid in fleet.tenant_ids)
    assert res.swaps_deferred > 0
    # wait time accrues per step: a swap waits many ticks but counts once
    assert res.deferred_ticks >= res.swaps_deferred
    assert fleet.scheduler.grants > 0
    assert fleet.scheduler.denied_attempts > 0


def test_scheduler_protocol_conformance():
    for s in (UnlimitedScheduler(), KConcurrentScheduler(2),
              TokenBucketScheduler(0.5, 4.0)):
        assert isinstance(s, ReorgScheduler)
    with pytest.raises(ValueError):
        KConcurrentScheduler(0)
    with pytest.raises(ValueError):
        TokenBucketScheduler(-1.0, 1.0)


def test_fleet_rejects_started_or_governed_engines(tenant_data):
    d = tenant_data["t0"]
    e1 = flipflop_engine(d, InMemoryBackend(d))
    e1.start()
    with pytest.raises(ValueError):
        FleetEngine({"t0": e1})
    e2 = flipflop_engine(d, InMemoryBackend(d))
    FleetEngine({"t0": e2})
    with pytest.raises(ValueError):
        FleetEngine({"t0": e2})            # already governed by first fleet
    with pytest.raises(ValueError):
        FleetEngine({})


def test_engine_exposes_pending_swaps(tenant_data):
    d = tenant_data["t0"]
    engine = flipflop_engine(d, InMemoryBackend(d), period=5, delta=100)
    stream = [wl.Query(lo=np.full(6, -np.inf), hi=np.full(6, np.inf))] * 12
    for q in stream:
        engine.step(q)
    # charges at indices 4 and 9, due at 104 / 109, still pending
    assert engine.pending_swaps == ((104, 1), (109, 0))


# ---------------------------------------------------------------------------
# Starvation edge cases: greedy tenants and exact token boundaries
# ---------------------------------------------------------------------------

def test_k1_greedy_tenant_does_not_starve_others(tenant_data):
    """A tenant that charges a swap on *every* tick must not starve other
    tenants' grants under k=1: the fleet's FIFO work queue hands the single
    unit to the oldest waiting request, so every tenant's swaps keep
    landing."""
    d = tenant_data["t0"]
    tenants = {
        "greedy": flipflop_engine(d, InMemoryBackend(d), period=1, delta=2),
        "calm1": flipflop_engine(d, InMemoryBackend(d), period=10, delta=2),
        "calm2": flipflop_engine(d, InMemoryBackend(d), period=10, delta=2),
    }
    fleet = FleetEngine(tenants, KConcurrentScheduler(1))
    rng = np.random.default_rng(1)
    c = d.shape[1]
    steps = []
    for i in range(200):
        for tid in ["greedy", "calm1", "calm2"]:
            lo = np.full(c, -np.inf)
            hi = np.full(c, np.inf)
            col = i % c
            lo[col], hi[col] = np.sort(rng.uniform(0, 100, size=2))
            steps.append(fleet.step(tid, wl.Query(lo=lo, hi=hi)))
    res = fleet.result()
    trans = serving_transitions(steps)
    # the greedy tenant charged ~200 swaps; the calm tenants still landed
    # most of theirs (about one per period, minus the tail in flight)
    assert len(res.per_tenant["greedy"].reorg_indices) == 200
    for tid in ["calm1", "calm2"]:
        landed = len(trans.get(tid, []))
        charged = len(res.per_tenant[tid].reorg_indices)
        assert charged == 20
        assert landed >= charged - 3, \
            f"{tid}: only {landed}/{charged} swaps landed (starved)"
    # per-tenant FIFO: the greedy tenant's unapplied swaps pile up in *its*
    # queue, not in front of other tenants' work
    assert len(fleet.tenant("greedy").pending_swaps) > 0


def test_token_bucket_grants_exactly_at_refill_boundary():
    """rate=0.25 accrues exactly 1.0 token at the 4th tick (binary-exact
    arithmetic): the grant must happen *at* that tick, not after it, and
    the bucket must clamp at capacity."""
    s = TokenBucketScheduler(rate=0.25, capacity=2.0, initial=0.0)
    for now in range(1, 4):
        s.tick(now)
        assert not s.try_acquire("a"), f"granted early at tick {now}"
    s.tick(4)
    assert s.tokens == 1.0           # exact, no float drift
    assert s.try_acquire("a")        # boundary grant
    assert s.tokens == 0.0
    # a big tick jump refills across the gap but clamps at capacity
    s.tick(100)
    assert s.tokens == 2.0
    assert s.try_acquire("a") and s.try_acquire("a")
    assert not s.try_acquire("a")


def test_token_bucket_boundary_swap_lands_at_refill_tick(tenant_data):
    """Fleet-level boundary check: with rate=1/8 and an empty bucket, a
    single tenant's first swap (charged at its first tick, due after
    delta) lands exactly when the 8th fleet tick refills the bucket."""
    d = tenant_data["t0"]
    engine = flipflop_engine(d, InMemoryBackend(d), period=1, delta=1)
    fleet = FleetEngine({"a": engine},
                        TokenBucketScheduler(rate=0.125, capacity=1.0,
                                             initial=0.0))
    q = wl.Query(lo=np.full(6, -np.inf), hi=np.full(6, np.inf))
    steps = [fleet.step("a", q) for _ in range(12)]
    serving = [fs.step.serving_state for fs in steps]
    # charged at tick 1 (index 0), due at tenant index 1; tokens reach 1.0
    # at fleet tick 8, so the pump grants then and the swap lands at the
    # tick-8 step — serving flips to state 1 at index 7, not before.
    assert serving[:7] == [0] * 7
    assert serving[7] == 1


# ---------------------------------------------------------------------------
# DiskBackend under scheduler-deferred prepare/activate
# ---------------------------------------------------------------------------

def test_disk_backend_deferred_swaps_serve_only_complete_versions(
        tenant_data, tmp_path):
    """A k=1 fleet over DiskBackends defers prepare/activate; every query
    must still be served by a fully-materialized version, i.e. cost-identical
    to the same fleet over InMemoryBackends."""
    small = {tid: d[:2_000] for tid, d in
             list(tenant_data.items())[:2]}
    disks = {}

    def disk_backend(tid, d):
        disks[tid] = DiskBackend(d, str(tmp_path / tid), background=True)
        return disks[tid]

    f_disk, _ = contended_fleet(small, KConcurrentScheduler(1),
                                backend_fn=disk_backend, period=8, delta=3)
    f_mem, _ = contended_fleet(small, KConcurrentScheduler(1),
                               period=8, delta=3)
    r_disk, r_mem = f_disk.result(), f_mem.result()
    assert r_disk.swaps_deferred == r_mem.swaps_deferred > 0
    for tid in small:
        # identical decisions and Delta-delay accounting ...
        assert (r_disk.per_tenant[tid].reorg_indices
                == r_mem.per_tenant[tid].reorg_indices)
        # ... and identical served costs: scanning the real partition files
        # reads exactly what the (fully written) zone maps cannot skip
        np.testing.assert_allclose(r_disk.per_tenant[tid].query_costs,
                                   r_mem.per_tenant[tid].query_costs,
                                   atol=1e-12)
    for backend in disks.values():
        backend.close()


def test_disk_backend_materializing_hook(tenant_data, tmp_path):
    d = tenant_data["t0"][:1_500]
    backend = DiskBackend(d, str(tmp_path / "hook"), background=True)
    lays = [build_default_layout(0, d, 4, sort_col=0),
            build_default_layout(1, d, 4, sort_col=1)]
    for lay in lays:
        backend.register(lay)
    assert backend.pending_states == []
    assert not backend.materializing(1)
    backend.activate(0)
    backend.prepare(1)
    assert backend.pending_states == [1]
    # activate while the background write may still be in flight: must join
    # the writer, never flip to a half-written version
    backend.activate(1)
    assert backend.pending_states == []
    q = wl.Query(lo=np.full(6, -np.inf), hi=np.full(6, np.inf))
    assert backend.serve(q) == pytest.approx(1.0)
    assert not backend.materializing(1)
    backend.close()


# ---------------------------------------------------------------------------
# Drift-scenario registry
# ---------------------------------------------------------------------------

ALL_SCENARIOS = ["sudden_shift", "gradual_drift", "cyclic_diurnal",
                 "flash_crowd", "template_churn"]


def test_registry_has_all_five_scenarios():
    assert set(ALL_SCENARIOS) <= set(DRIFT_SCENARIOS)
    with pytest.raises(KeyError):
        make_drift_scenario("no_such_scenario", np.zeros(2), np.ones(2))


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_streams_are_consistent(name, bounds):
    lo, hi = bounds
    fs = make_drift_scenario(name, lo, hi, num_tenants=3,
                             queries_per_tenant=240, seed=3)
    assert fs.scenario == name
    assert len(fs.tenant_ids) == 3
    assert len(fs) == sum(len(s) for s in fs.per_tenant.values())
    # interleaving preserves each tenant's query order exactly (identity)
    for tid in fs.tenant_ids:
        from_events = [q for t, q in fs.events if t == tid]
        assert len(from_events) == len(fs.per_tenant[tid])
        assert all(a is b for a, b in
                   zip(from_events, fs.per_tenant[tid].queries))
    # deterministic: same seed, same stream
    fs2 = make_drift_scenario(name, lo, hi, num_tenants=3,
                              queries_per_tenant=240, seed=3)
    assert [(t, q.template_id) for t, q in fs.events] \
        == [(t, q.template_id) for t, q in fs2.events]
    assert all(np.array_equal(a.lo, b.lo) and np.array_equal(a.hi, b.hi)
               for (_, a), (_, b) in zip(fs.events, fs2.events))


def test_sudden_shift_has_one_staggered_switch(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=4,
                             queries_per_tenant=400, seed=5)
    shift_points = []
    for tid, s in fs.per_tenant.items():
        assert len(s.segments) == 2
        assert s.segments[0][2] != s.segments[1][2]
        shift_points.append(s.segments[0][1])
        assert 0.35 * 400 <= shift_points[-1] <= 0.65 * 400
    assert len(set(shift_points)) > 1      # staggered across tenants


def test_gradual_drift_mixture_slides(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("gradual_drift", lo, hi, num_tenants=2,
                             queries_per_tenant=1000, seed=9)
    for s in fs.per_tenant.values():
        src = s.templates[0].template_id
        tgt = s.templates[1].template_id
        head = [q.template_id for q in s.queries[:200]]
        tail = [q.template_id for q in s.queries[-200:]]
        assert head.count(tgt) / 200 < 0.25
        assert tail.count(tgt) / 200 > 0.75
        assert head.count(src) + head.count(tgt) == 200


def test_cyclic_diurnal_rotates_with_phase_offsets(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("cyclic_diurnal", lo, hi, num_tenants=3,
                             queries_per_tenant=360, seed=2, num_phases=3,
                             cycles=4)
    first_templates = {}
    for tid, s in fs.per_tenant.items():
        tids_seq = [seg[2] for seg in s.segments]
        assert len(set(tids_seq)) == 3
        # strict rotation: consecutive segments always differ, recur with
        # period num_phases
        for a, b in zip(tids_seq, tids_seq[3:]):
            assert a == b
        assert all(a != b for a, b in zip(tids_seq, tids_seq[1:]))
        first_templates[tid] = tids_seq[0]
    assert len(set(first_templates.values())) > 1    # phase-shifted tenants


def test_flash_crowd_concentrates_events_in_burst(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("flash_crowd", lo, hi, num_tenants=4,
                             queries_per_tenant=500, seed=4,
                             burst_rate=4.0, burst_frac=0.2)
    burst = fs.per_tenant["t0"]
    assert len(burst.segments) == 3
    b_start, b_end, hot = burst.segments[1]
    # fleet positions of the burst tenant's events
    pos = [k for k, (tid, _) in enumerate(fs.events) if tid == "t0"]
    gaps_burst = np.diff(pos[b_start:b_end])
    gaps_out = np.diff(pos[:b_start])
    # during the burst t0 emits ~4x denser than outside
    assert gaps_burst.mean() < gaps_out.mean() / 2
    assert all(q.template_id == hot
               for q in burst.queries[b_start:b_end])


def test_template_churn_never_reuses_templates(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("template_churn", lo, hi, num_tenants=2,
                             queries_per_tenant=600, seed=6, num_segments=6)
    for s in fs.per_tenant.values():
        seg_templates = [seg[2] for seg in s.segments]
        assert len(seg_templates) == 6
        assert len(set(seg_templates)) == 6          # all fresh, none recur
        assert seg_templates == sorted(seg_templates)


def test_interleave_uniform_weights_is_round_robin(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=3,
                             queries_per_tenant=30, seed=0)
    order = [tid for tid, _ in fs.events[:9]]
    assert order == ["t0", "t1", "t2"] * 3


# ---------------------------------------------------------------------------
# Fleet x scenario end to end (small)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_fleet_runs_every_scenario_with_budget(name, tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario(name, lo, hi, num_tenants=3,
                             queries_per_tenant=150, seed=11)
    fleet = FleetEngine({tid: oreo_engine(tenant_data[tid], alpha=5.0,
                                          delta=3)
                         for tid in fs.tenant_ids},
                        TokenBucketScheduler(rate=0.05, capacity=2.0))
    res = fleet.run(fs)
    assert res.ticks == len(fs)
    for tid in fs.tenant_ids:
        r = res.per_tenant[tid]
        assert len(r.query_costs) == len(fs.per_tenant[tid])
        assert np.all(r.query_costs >= 0) and np.all(r.query_costs <= 1)
    assert res.total_cost == pytest.approx(
        res.total_query_cost + res.total_reorg_cost)
    assert "grants" in res.scheduler_stats
