"""Per-architecture smoke tests (reduced configs) + model-level equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs, runnable_cells
from repro.models import build_model, input_specs
from repro.models import layers as L
from repro.models import rwkv6


def _batch_for(cfg, B, T, key):
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(
                    key, (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, T - cfg.prefix_len), 0,
                                             cfg.vocab),
                "targets": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.family == "audio":
        return {"embeds": jax.random.normal(key, (B, T, cfg.d_model),
                                            jnp.bfloat16),
                "targets": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
            "targets": jax.random.randint(key, (B, T), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss + grad step, shapes + no NaNs."""
    cfg = get_arch(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, T = 2, 32
    batch = _batch_for(cfg, B, T, key)
    logits = model.forward(params, batch)
    assert logits.shape == (B, T, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_decode(arch):
    cfg = get_arch(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, T = 2, 16
    batch = {k: v for k, v in _batch_for(cfg, B, T, key).items()
             if k != "targets"}
    logits, cache = model.prefill(params, batch, max_len=T + 4)
    assert logits.shape == (B, 1, cfg.vocab)
    dec = ({"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
           if cfg.family == "audio"
           else {"tokens": jnp.zeros((B, 1), jnp.int32)})
    lg2, cache2 = model.decode_step(params, dec, cache)
    assert lg2.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(lg2, np.float32)).any()
    assert int(cache2["index"]) == int(cache["index"]) + 1


def test_prefill_decode_consistency():
    """decode_step after prefill(T) == forward(T+1) last logits."""
    cfg = get_arch("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    B, T = 2, 12
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :T]}, max_len=T + 4)
    step_logits, _ = model.decode_step(params, {"tokens": toks[:, T:T + 1]},
                                       cache)
    # bf16 params/activations: ~3 significant digits on O(1) logits.
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full[:, T], np.float32), rtol=5e-2, atol=5e-2)


def test_rwkv_chunked_matches_scan():
    """RWKV-6 chunked linear attention == exact sequential scan."""
    key = jax.random.PRNGKey(3)
    B, T, H, dh = 2, 50, 3, 8
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, dh),
                                 jnp.float32) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(key, 4),
                                           (B, T, H, dh)) * 0.3 - 2.0))
    u = jax.random.normal(jax.random.fold_in(key, 5), (H, dh), jnp.float32)
    o_scan, s_scan = rwkv6._wkv_scan(r, k, v, w, u, dh)
    o_chunk, s_chunk = rwkv6._wkv_chunked(r, k, v, w, u, dh, chunk=16)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_scan),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_scan),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_decode_continues_scan():
    """Sequential decode from prefill state == full-sequence forward."""
    cfg = get_arch("rwkv6-3b", smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init_params(key)
    B, T = 1, 20
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :T]})
    lg, _ = model.decode_step(params, {"tokens": toks[:, T:T + 1]}, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, T], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_moe_routes_to_topk_experts():
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(5))
    layer0 = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    out = L.moe_apply(layer0["moe"], x, cfg)
    assert out.shape == x.shape
    assert not np.isnan(np.asarray(out, np.float32)).any()
    # capacity sweep changes nothing at tiny loads
    out_hi = L.moe_apply(layer0["moe"], x, cfg, capacity_factor=4.0)
    assert np.isfinite(np.asarray(out_hi, np.float32)).all()


def test_flash_attention_vs_naive_full():
    """Model-layer blocked attention == naive softmax attention."""
    key = jax.random.PRNGKey(7)
    B, T, H, dh = 2, 96, 4, 16
    q = jax.random.normal(key, (B, T, H, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, dh),
                          jnp.float32)
    out = L.flash_attention(q, k, v, causal=True)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / dh ** 0.5
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_cell_registry_counts():
    """40 assigned cells = 32 runnable + 8 documented long_500k skips."""
    cells = runnable_cells()
    assert len(cells) == 32
    assert len(list_archs()) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    long_ok = [a for a, s in cells if s == "long_500k"]
    assert sorted(long_ok) == ["rwkv6-3b", "zamba2-2.7b"]


@pytest.mark.parametrize("arch,shape", [("qwen3-1.7b", "train_4k"),
                                        ("rwkv6-3b", "decode_32k"),
                                        ("paligemma-3b", "prefill_32k"),
                                        ("musicgen-large", "decode_32k")])
def test_input_specs_shapes(arch, shape):
    cfg = get_arch(arch)
    shapes, specs = input_specs(cfg, SHAPES[shape])
    assert set(shapes) == set(specs)
    for k, v in shapes.items():
        assert isinstance(v, jax.ShapeDtypeStruct)
        if shape.startswith("decode") and k in ("tokens", "embeds"):
            assert v.shape[1] == 1      # one new token
