"""End-to-end behaviour tests for the OREO system (paper core)."""
import numpy as np
import pytest

from repro.core import (DynamicUMTS, OreoConfig, OreoRunner,
                        baselines, build_default_layout, build_qdtree_layout,
                        build_zorder_layout, generate_workload, layouts,
                        make_generator, make_templates, stack_queries,
                        theorem_iv1_bound)
from repro.core.layout_manager import LayoutManager, LayoutManagerConfig


@pytest.fixture(scope="module")
def bench():
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(30_000, 10))
    templates = make_templates(4, 10, rng)
    stream = generate_workload(templates, data.min(0), data.max(0),
                               total_queries=2500, seed=1,
                               segment_length=(500, 800))
    return data, stream


# ---------------------------------------------------------------------------
# Layout generation + cost model
# ---------------------------------------------------------------------------

def test_qdtree_beats_default_on_its_workload(bench):
    data, stream = bench
    qs = stream.queries[:200]
    lay = build_qdtree_layout(1, data, qs, 32)
    lay.materialize(data)
    default = build_default_layout(0, data, 32)
    q_lo, q_hi = stack_queries(qs)
    c_tree = layouts.eval_cost(lay.true_meta, q_lo, q_hi).mean()
    c_def = layouts.eval_cost(default.meta, q_lo, q_hi).mean()
    assert c_tree < 0.5 * c_def


def test_zorder_layout_partitions_balanced(bench):
    data, stream = bench
    lay = build_zorder_layout(1, data, stream.queries[:100], 32)
    meta = lay.materialize(data)
    assert meta.num_partitions == 32
    assert meta.rows.sum() == len(data)
    assert meta.rows.max() < 4 * meta.rows.mean()


def test_estimated_metadata_close_to_true(bench):
    """Sample-estimated cost vectors approximate materialized ones."""
    data, stream = bench
    qs = stream.queries[:150]
    lay = build_qdtree_layout(2, data, qs, 32)
    true_meta = lay.materialize(data)
    q_lo, q_hi = stack_queries(qs)
    est = layouts.eval_cost(lay.meta, q_lo, q_hi)
    true = layouts.eval_cost(true_meta, q_lo, q_hi)
    assert np.abs(est - true).mean() < 0.12


def test_cost_in_unit_interval(bench):
    data, stream = bench
    lay = build_default_layout(0, data, 16)
    q_lo, q_hi = stack_queries(stream.queries[:500])
    c = layouts.eval_cost(lay.meta, q_lo, q_hi)
    assert np.all(c >= 0) and np.all(c <= 1)


# ---------------------------------------------------------------------------
# D-UMTS decision maker
# ---------------------------------------------------------------------------

def test_dumts_counters_and_phases():
    d = DynamicUMTS(alpha=5.0, initial_states=[0, 1, 2], seed=0)
    for _ in range(100):
        d.observe({0: 0.5, 1: 0.5, 2: 0.5})
        assert all(v >= 0 for v in d.counters.values())
        assert d.current_state in d.states
    assert d.phase >= 1                      # phases do reset


def test_dumts_add_remove_states():
    d = DynamicUMTS(alpha=5.0, initial_states=[0], seed=0,
                    midphase_admission="defer")
    d.add_state(1)
    assert 1 in d.pending_additions and 1 not in d.states
    for _ in range(15):                      # exhaust state 0 -> new phase
        d.observe({0: 0.9, 1: 0.1})
    assert 1 in d.states                     # admitted at phase reset
    d.remove_state(0)
    assert d.current_state == 1
    with pytest.raises(ValueError):
        d.remove_state(1)                    # cannot remove last state


def test_dumts_median_admission_mid_phase():
    d = DynamicUMTS(alpha=10.0, initial_states=[0, 1], seed=0,
                    midphase_admission="median")
    for _ in range(5):
        d.observe({0: 0.5, 1: 0.7})
    d.add_state(2)
    assert 2 in d.states and 2 in d.active
    assert d.counters[2] == pytest.approx(
        np.median([d.counters[0], d.counters[1]]))


def test_dumts_stays_in_good_state():
    """A zero-cost state should never be abandoned within a phase."""
    d = DynamicUMTS(alpha=5.0, initial_states=[0, 1], seed=0)
    d.current_state = 0
    for _ in range(200):
        d.observe({0: 0.0, 1: 1.0})
    assert d.current_state == 0
    assert d.num_moves == 0


def test_competitive_bound_formula():
    assert theorem_iv1_bound(1) == pytest.approx(2.0)
    assert theorem_iv1_bound(4) == pytest.approx(2 * (1 + 0.5 + 1 / 3 + 0.25))


def test_dumts_empirical_competitive_ratio():
    """Cost(OREO MTS) <= 2H(n) * OPT + O(alpha) on adversarial-ish streams."""
    rng = np.random.default_rng(0)
    n, alpha, T = 4, 10.0, 2000
    costs_per_state = rng.uniform(0, 1, size=(T, n))
    # make one state cheap per epoch, rotating -> forces movement
    for t in range(T):
        costs_per_state[t, (t // 250) % n] *= 0.05
    d = DynamicUMTS(alpha=alpha, initial_states=list(range(n)), seed=1)
    online = 0.0
    for t in range(T):
        moves_before = d.num_moves
        s = d.observe({i: float(costs_per_state[t, i]) for i in range(n)})
        online += costs_per_state[t, s] + (d.num_moves - moves_before) * alpha
    # offline lower bound: best single state (no movement)
    opt = costs_per_state.sum(axis=0).min()
    bound = theorem_iv1_bound(n)
    assert online <= bound * opt + 4 * alpha, (online, opt, bound)


# ---------------------------------------------------------------------------
# Layout manager (Alg. 5)
# ---------------------------------------------------------------------------

def test_layout_manager_admission_and_cap(bench):
    data, stream = bench
    init = build_default_layout(0, data, 32)
    cfg = LayoutManagerConfig(target_partitions=32, max_states=4,
                              epsilon=0.05)
    mgr = LayoutManager(data, make_generator("qdtree"), init, cfg, seed=0)
    for q in stream.queries[:1500]:
        mgr.on_query(q, current_state=0)
    assert len(mgr.store) <= cfg.max_states
    assert mgr.num_generated > 0
    assert mgr.num_admitted <= mgr.num_generated


def test_layout_manager_epsilon_monotone(bench):
    """Higher epsilon admits fewer candidates."""
    data, stream = bench
    admitted = {}
    for eps in (0.02, 0.3):
        init = build_default_layout(0, data, 32)
        mgr = LayoutManager(data, make_generator("qdtree"), init,
                            LayoutManagerConfig(target_partitions=32,
                                                epsilon=eps), seed=0)
        for q in stream.queries[:1200]:
            mgr.on_query(q, current_state=0)
        admitted[eps] = mgr.num_admitted
    assert admitted[0.3] <= admitted[0.02]


# ---------------------------------------------------------------------------
# End-to-end online runs
# ---------------------------------------------------------------------------

def test_oreo_end_to_end_beats_default(bench):
    data, stream = bench
    gen = make_generator("qdtree")
    init = build_default_layout(0, data, 32)
    res = OreoRunner(data, init, gen, OreoConfig(
        alpha=80.0, manager=LayoutManagerConfig(target_partitions=32))
    ).run(stream)
    # staying in the default layout forever costs ~= len(stream) * default
    q_lo, q_hi = stack_queries(stream.queries)
    stay = layouts.eval_cost(init.meta, q_lo, q_hi).sum()
    assert res.total_cost < stay
    assert res.total_query_cost + res.total_reorg_cost == pytest.approx(
        res.total_cost)
    assert res.num_reorgs == len(res.reorg_indices)


def test_oreo_vs_baseline_ordering(bench):
    """Greedy has lowest query cost / highest reorg; Regret fewest moves."""
    data, stream = bench
    gen = make_generator("qdtree")
    greedy = baselines.run_greedy(data, stream, gen,
                                  build_default_layout(0, data, 32), 80.0)
    regret = baselines.run_regret(data, stream, gen,
                                  build_default_layout(0, data, 32), 80.0)
    assert greedy.num_reorgs >= regret.num_reorgs
    assert greedy.total_query_cost <= regret.total_query_cost * 1.5


def test_offline_optimal_is_lower_bound(bench):
    data, stream = bench
    gen = make_generator("qdtree")
    off = baselines.run_offline_optimal(data, stream, gen, 80.0)
    oreo = OreoRunner(data, build_default_layout(0, data, 32), gen,
                      OreoConfig(alpha=80.0)).run(stream)
    assert off.total_query_cost <= oreo.total_query_cost
    assert off.num_reorgs == stream.num_switches


def test_delta_delay_increases_query_cost(bench):
    data, stream = bench
    gen = make_generator("qdtree")
    r0 = OreoRunner(data, build_default_layout(0, data, 32), gen,
                    OreoConfig(alpha=80.0, delta=0, seed=3)).run(stream)
    r80 = OreoRunner(data, build_default_layout(0, data, 32), gen,
                     OreoConfig(alpha=80.0, delta=80, seed=3)).run(stream)
    # same decisions -> same reorg cost; delayed swap -> >= query cost
    assert r80.total_reorg_cost == r0.total_reorg_cost
    assert r80.total_query_cost >= r0.total_query_cost * 0.98
