"""Tests for the unified typed event surface (satellites of the serving
front end PR): the ``QueryEvent | IngestEvent`` union, the deprecated
bare-tuple shim, the ``FleetEngine.submit``/``drain`` entry point that
``run``/``run_batched`` route through, and the curated public API
(including the underscore demotions' re-export shims)."""
import warnings

import numpy as np
import pytest

from repro.core import (OreoConfig, build_default_layout, make_generator,
                        workload as wl)
from repro.core import layout_manager as lm
from repro.core.workload import (IngestBatch, IngestEvent, QueryEvent,
                                 as_event, make_drift_scenario,
                                 make_ingest_scenario)
from repro.engine import (FleetEngine, FleetStepResult, InMemoryBackend,
                          LayoutEngine, OreoPolicy, StateMatrix)


@pytest.fixture(scope="module")
def tenant_data():
    return {f"t{t}": np.random.default_rng(700 + t).uniform(
        0, 100, size=(2_000, 5)) for t in range(2)}


@pytest.fixture(scope="module")
def bounds(tenant_data):
    lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)
    return lo, hi


def oreo_engine(data, alpha=10.0, delta=5, seed=2):
    cfg = OreoConfig(alpha=alpha, seed=seed, delta=delta,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=60,
                                                    gen_every=30))
    policy = OreoPolicy(data, build_default_layout(0, data, 8),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


def some_query(c=5, seed=0):
    rng = np.random.default_rng(seed)
    lo = np.full(c, -np.inf)
    hi = np.full(c, np.inf)
    lo[0], hi[0] = np.sort(rng.uniform(0, 100, size=2))
    return wl.Query(lo=lo, hi=hi)


# ---------------------------------------------------------------------------
# The Event union and its tuple compatibility
# ---------------------------------------------------------------------------

def test_typed_events_are_tuple_compatible():
    q = some_query()
    batch = IngestBatch(rows=np.zeros((3, 5)))
    qe = QueryEvent("a", q)
    ie = IngestEvent("b", batch)
    # NamedTuples ARE the legacy pairs: unpack, index, compare
    tid, payload = qe
    assert (tid, payload) == ("a", q) and qe[1] is q
    assert isinstance(qe, tuple) and isinstance(ie, tuple)
    assert ie == ("b", batch)
    assert qe.tenant_id == "a" and qe.query is q
    assert ie.tenant_id == "b" and ie.batch is batch


def test_as_event_passes_typed_through_without_warning():
    qe = QueryEvent("a", some_query())
    ie = IngestEvent("a", IngestBatch(rows=np.zeros((2, 5))))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert as_event(qe) is qe
        assert as_event(ie) is ie


def test_as_event_tuple_shim_warns_deprecation():
    q = some_query()
    with pytest.warns(DeprecationWarning, match="QueryEvent"):
        ev = as_event(("a", q))
    assert ev == QueryEvent("a", q) and type(ev) is QueryEvent
    batch = IngestBatch(rows=np.zeros((2, 5)))
    with pytest.warns(DeprecationWarning, match="IngestEvent"):
        ev = as_event(["b", batch])
    assert ev == IngestEvent("b", batch) and type(ev) is IngestEvent


def test_as_event_rejects_non_events():
    with pytest.raises(TypeError, match="not a fleet event"):
        as_event(("a", "not-a-query"))
    with pytest.raises(TypeError, match="not a fleet event"):
        as_event(42)


def test_streams_emit_typed_events(bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=2,
                             queries_per_tenant=20, seed=3)
    assert all(type(ev) is QueryEvent for ev in fs)
    ms = make_ingest_scenario("mixed_rw", lo, hi, num_tenants=2,
                              queries_per_tenant=20, seed=3)
    kinds = {type(ev) for ev in ms}
    assert kinds == {QueryEvent, IngestEvent}


# ---------------------------------------------------------------------------
# submit / drain: the single entry point
# ---------------------------------------------------------------------------

def test_submit_drain_matches_run(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=2,
                             queries_per_tenant=60, seed=5)
    ref = FleetEngine({tid: oreo_engine(tenant_data[tid])
                       for tid in fs.tenant_ids}).run(fs)
    fleet = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids})
    for ev in fs:
        fleet.submit(ev)
    assert fleet.queue_depth == len(fs.events)
    assert fleet.drain() == len(fs.events)
    assert fleet.queue_depth == 0
    got = fleet.result()
    for tid in fs.tenant_ids:
        a, b = ref.per_tenant[tid], got.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs)
        assert a.reorg_indices == b.reorg_indices
        assert np.array_equal(a.state_seq, b.state_seq)


def test_drain_collect_returns_step_results(tenant_data):
    from repro.engine import IngestConfig
    d = tenant_data["t0"]
    fleet = FleetEngine({"a": LayoutEngine(
        OreoPolicy(d, build_default_layout(0, d, 8),
                   make_generator("qdtree"),
                   OreoConfig(alpha=10.0, seed=2, delta=5)),
        InMemoryBackend(d), delta=5, ingest=IngestConfig())})
    q = some_query()
    fleet.submit(QueryEvent("a", q))
    fleet.submit(IngestEvent("a", IngestBatch(rows=d[:4].copy())))
    out = fleet.drain(collect=True)
    assert [type(r) for r in out] == [FleetStepResult, FleetStepResult]
    assert out[0].step is not None and out[0].step.query is q
    assert out[1].step is None          # ingest events have no observation
    assert out[1].tick == 2


def test_drain_batched_rejects_collect(tenant_data):
    fleet = FleetEngine({"a": oreo_engine(tenant_data["t0"])})
    with pytest.raises(ValueError, match="collect"):
        fleet.drain(batched=True, collect=True)


def test_drain_batched_empty_still_validates_backends(tenant_data):
    # run_batched([]) semantics survive the drain refactor: the plane is
    # built (and backend eligibility checked) even with nothing queued.
    fleet = FleetEngine({"a": oreo_engine(tenant_data["t0"])})
    assert fleet.drain(batched=True) == 0
    assert fleet.fleet_matrix is not None


def test_run_accepts_legacy_tuples_with_warning(tenant_data, bounds):
    lo, hi = bounds
    fs = make_drift_scenario("sudden_shift", lo, hi, num_tenants=2,
                             queries_per_tenant=40, seed=5)
    typed = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids}).run(fs)
    legacy_events = [(tid, q) for tid, q in fs]      # bare pairs
    fleet = FleetEngine({tid: oreo_engine(tenant_data[tid])
                         for tid in fs.tenant_ids})
    with pytest.warns(DeprecationWarning, match="deprecated"):
        got = fleet.run(legacy_events)
    for tid in fs.tenant_ids:
        a, b = typed.per_tenant[tid], got.per_tenant[tid]
        assert np.array_equal(a.query_costs, b.query_costs)
        assert a.reorg_indices == b.reorg_indices


# ---------------------------------------------------------------------------
# Curated public API + demotion shims
# ---------------------------------------------------------------------------

def test_engine_exports_event_surface():
    import repro.engine as eng
    for name in ("Event", "QueryEvent", "IngestEvent", "as_event",
                 "FleetEngine", "LayoutEngine"):
        assert name in eng.__all__
        assert getattr(eng, name) is not None
    assert eng.QueryEvent is QueryEvent


def test_serve_exports_frontend_surface():
    import repro.serve as serve
    for name in ("ServeFrontend", "FrontendConfig", "AdmissionResult",
                 "TokenBucket", "CircuitBreaker", "VersionedResultCache",
                 "cache_key", "SlotBatcher"):
        assert name in serve.__all__
        assert getattr(serve, name) is not None


def test_serve_primable_demoted_with_warning_shim():
    data = np.random.default_rng(0).uniform(0, 100, size=(100, 3))
    backend = InMemoryBackend(data)
    assert backend._serve_primable is True
    with pytest.warns(DeprecationWarning, match="_serve_primable"):
        assert backend.serve_primable is True


def test_state_matrix_listeners_demoted_with_warning_shim():
    sm = StateMatrix()

    class Listener:
        def on_register(self, state_id, meta):
            pass

        def on_deregister(self, state_id):
            pass

    lst = Listener()
    with pytest.warns(DeprecationWarning, match="_add_listener"):
        sm.add_listener(lst)
    with pytest.warns(DeprecationWarning, match="_remove_listener"):
        sm.remove_listener(lst)
    sm._add_listener(lst)               # the internal names, silently
    sm._remove_listener(lst)
    assert sm._listeners == []
