"""Serving example: batched greedy generation with the slot batcher.

Loads a smoke-size model, submits a queue of requests, and serves them with
fixed-slot continuous batching: prefill once per fill, single jitted decode
step per token across all active slots.

    PYTHONPATH=src python examples/serve_model.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import Request, SlotBatcher, build_serve_fns


def main() -> None:
    cfg = get_arch("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    num_slots, prompt_len, max_len = 4, 16, 64
    prefill_fn, decode_fn = build_serve_fns(model, max_len)

    batcher = SlotBatcher(num_slots)
    rng = np.random.default_rng(0)
    for rid in range(10):
        batcher.submit(Request(rid, rng.integers(0, cfg.vocab, prompt_len),
                               max_new_tokens=12))

    t0 = time.time()
    tokens_out = 0
    cache = None
    while batcher.pending or batcher.active:
        newly = batcher.fill_slots()
        if newly or cache is None:
            # (Re)prefill the whole slot batch; empty slots carry zeros.
            prompts = np.zeros((num_slots, prompt_len), np.int32)
            for i, req in enumerate(batcher.slots):
                if req is not None:
                    prompts[i] = req.prompt
            logits, cache = prefill_fn(params, {"tokens":
                                                jnp.asarray(prompts)})
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        # decode until some slot finishes
        while batcher.active and not any(
                s is None for s in batcher.slots) or (
                batcher.active and not batcher.pending):
            logits, cache = decode_fn(params, {"tokens": tok}, cache)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            batcher.record_tokens(np.asarray(tok[:, 0]))
            tokens_out += batcher.active
            if int(cache["index"]) >= max_len - 1:
                break
        if not batcher.pending and not batcher.active:
            break
    dt = time.time() - t0
    print(f"served {len(batcher.completed)} requests, "
          f"{sum(len(r.generated) for r in batcher.completed)} tokens "
          f"in {dt:.1f}s")
    for r in batcher.completed[:3]:
        print(f"  request {r.request_id}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
