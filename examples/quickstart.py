"""Quickstart: OREO in 60 seconds.

Builds a synthetic table, streams a drifting query workload through OREO,
and compares the total (query + reorganization) cost against the static
optimized layout and the greedy/regret baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (OreoConfig, build_default_layout, generate_workload,
                        make_generator, make_templates)
from repro.core.layout_manager import LayoutManagerConfig
from repro.engine import (GreedyPolicy, InMemoryBackend, LayoutEngine,
                          OreoPolicy, RegretPolicy, StaticPolicy)


def main() -> None:
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(100_000, 24))

    # A drifting workload: 8 query-template families, switching every ~800
    # queries (the regime where a single static layout loses).
    templates = make_templates(12, data.shape[1], rng,
                               cols_per_template=(1, 2),
                               selectivity_range=(0.02, 0.10))
    stream = generate_workload(templates, data.min(0), data.max(0),
                               total_queries=9000, seed=1,
                               num_segments=9)

    gen = make_generator("qdtree")          # or "zorder"
    alpha = 80.0                            # reorg = 80x a full scan

    # Every method is a Policy plugged into the same stepwise LayoutEngine
    # loop; swap InMemoryBackend for DiskBackend to run against real files.
    def run(policy):
        return LayoutEngine(policy, InMemoryBackend(data)).run(stream)

    oreo = run(OreoPolicy(
        data, build_default_layout(0, data, 32), gen,
        OreoConfig(alpha=alpha, gamma=1.0,
                   manager=LayoutManagerConfig(target_partitions=32))))
    static = run(StaticPolicy(data, stream, gen, alpha))
    greedy = run(GreedyPolicy(data, build_default_layout(0, data, 32), gen,
                              alpha))
    regret = run(RegretPolicy(data, build_default_layout(0, data, 32), gen,
                              alpha))

    print("total cost = query cost + alpha * reorganizations\n")
    for r in (static, greedy, regret, oreo):
        print(" ", r.summary())
    imp = 100 * (static.total_cost - oreo.total_cost) / static.total_cost
    print(f"\nOREO vs Static: {imp:+.1f}%  "
          f"(worst-case bound: {oreo.info['competitive_bound']:.1f}x offline"
          f" opt, |S_max|={oreo.info['max_state_space']})")


if __name__ == "__main__":
    main()
