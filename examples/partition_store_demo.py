"""Physical-layer demo: partitioned columnar store + measured alpha.

Part 1 writes a table to disk under the default layout, runs queries against
it (reading only non-skippable partitions), reorganizes it under a workload-
aware Qd-tree (skipping partitions whose row set is unchanged), and reports
the measured speedup + the measured reorganization-to-scan ratio (the
paper's alpha, Table I).

Part 2 drives the *same on-disk store* with the online engine: OREO's
decision stack runs over a DiskBackend, so reorganizations happen as
background rewrites of real partition files while queries keep scanning the
old layout (the paper's §VI-D5 deferred-swap semantics).

Part 3 switches the engine to ``incremental=True``: the same charged
reorganizations become planned micro-move migrations executed a few hundred
rows per tick, and the store serves a *hybrid* state — moved target
partitions plus residual source partitions — while each migration is in
flight.

Part 4 opens the write path on a ``durable=True`` DiskBackend: appended
rows land as unclustered delta partitions (scanned immediately), the
clustering-debt meter triggers α-charged compactions, and every manifest
mutation is committed through a write-ahead log first — so a crash in the
middle of ingest is simulated by just abandoning the process state and
replaying the WAL, which reconstructs the serving manifest bitwise plus
the exact set of pending delta batches.

    PYTHONPATH=src python examples/partition_store_demo.py
"""
import json
import os
import tempfile

import numpy as np

from repro.core import (OreoConfig, build_default_layout, generate_workload,
                        make_generator, make_templates)
from repro.core.layout_manager import LayoutManagerConfig
from repro.data.partition_store import PartitionStore
from repro.engine import (DiskBackend, IngestConfig, LayoutEngine,
                          OreoPolicy)


def main() -> None:
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(200_000, 12))
    templates = make_templates(2, 12, rng, selectivity_range=(0.02, 0.08))
    queries = [templates[0].sample(rng, data.min(0), data.max(0))
               for _ in range(60)]

    with tempfile.TemporaryDirectory() as td:
        store = PartitionStore(td + "/table")
        store.write(data, build_default_layout(0, data, 32))

        before = [store.scan(q)[1] for q in queries[:20]]
        scan_s = store.full_scan_seconds()

        gen = make_generator("qdtree")
        layout = gen(1, data, queries, 32)
        reorg = store.reorganize(layout)

        after = [store.scan(q)[1] for q in queries[20:40]]
        pr_b = np.mean([s.partitions_read for s in before])
        pr_a = np.mean([s.partitions_read for s in after])
        t_b = np.mean([s.seconds for s in before])
        t_a = np.mean([s.seconds for s in after])
        print(f"partitions read/query: {pr_b:.1f} -> {pr_a:.1f}")
        print(f"query seconds:         {t_b * 1e3:.1f}ms -> {t_a * 1e3:.1f}ms")
        print(f"full scan: {scan_s:.2f}s; reorganization: "
              f"{reorg.seconds:.2f}s ({reorg.partitions_rewritten} "
              f"partitions rewritten, {reorg.partitions_skipped} skipped "
              f"unchanged) -> measured alpha = "
              f"{reorg.seconds / scan_s:.1f}x")

    # ------------------------------------------------------------------
    # Online OREO over the on-disk store: same engine as the simulations,
    # different StorageBackend.
    print("\nonline OREO over DiskBackend (background reorganization):")
    small = data[:60_000]
    stream = generate_workload(templates, small.min(0), small.max(0),
                               total_queries=600, seed=2,
                               segment_length=(150, 250))
    cfg = OreoConfig(alpha=20.0, delta=20,
                     manager=LayoutManagerConfig(target_partitions=16,
                                                 window_size=100,
                                                 gen_every=50))
    with tempfile.TemporaryDirectory() as td:
        backend = DiskBackend(small, td + "/engine_table", background=True)
        engine = LayoutEngine(
            OreoPolicy(small, build_default_layout(0, small, 16),
                       make_generator("qdtree"), cfg),
            backend, delta=cfg.delta)
        result = engine.run(stream)
        print(f"  {result.summary()}")
        backend.close()
        print(f"  initial load: {backend.initial_write_seconds:.2f}s; "
              f"background rewrites: {len(backend.reorg_seconds)} "
              f"({sum(backend.reorg_seconds):.2f}s total, overlapped with "
              f"serving)")

    # ------------------------------------------------------------------
    # Incremental migration over the same on-disk store: the engine plans
    # micro-moves, a few thousand rows migrate per tick, and queries are
    # served from the hybrid (moved + unmoved) state in flight.
    print("\nincremental OREO over DiskBackend (micro-move migrations):")
    with tempfile.TemporaryDirectory() as td:
        backend = DiskBackend(small, td + "/engine_table", background=False)
        engine = LayoutEngine(
            OreoPolicy(small, build_default_layout(0, small, 16),
                       make_generator("qdtree"), cfg),
            backend, delta=cfg.delta, incremental=True, rows_per_tick=4_000)
        snapshots = 0
        for query in stream:
            engine.step(query)
            active = engine.reorg_executor.active
            if active is not None and snapshots < 4 \
                    and active.moves_done > 0:
                done = engine.reorg_executor.done_mask
                print(f"  in flight @q{engine._index}: "
                      f"{active.moves_done}/{active.moves_total} moves, "
                      f"{active.moved_rows}/{active.total_rows} rows, "
                      f"{int(done.sum())} target partitions serving, "
                      f"charged {active.charged:.2f}/{active.alpha:g}")
                snapshots += 1
        result = engine.result()
        print(f"  {result.summary()}")
        for k, mig in enumerate(engine.reorg_executor.migrations):
            span = (mig.completed_at - mig.begun_at
                    if mig.completed_at >= 0 else -1)
            print(f"  migration {k}: {mig.moves_done} moves / "
                  f"{mig.moved_rows} rows over {span} ticks, "
                  f"ledger {len(mig.charges)} charges summing to "
                  f"{mig.charged:g} (alpha={mig.alpha:g})")
        backend.close()

    # ------------------------------------------------------------------
    # Streaming ingest over a durable store: delta partitions, debt-
    # triggered compaction, and WAL recovery after a simulated crash.
    print("\nstreaming ingest over a durable DiskBackend (manifest WAL):")
    # column-sorted base + sort-key layout: narrow zone maps, so the
    # unclustered delta partitions carry real clustering debt
    tiny = np.sort(data[:20_000], axis=0)
    stream = generate_workload(templates, tiny.min(0), tiny.max(0),
                               total_queries=90, seed=3,
                               segment_length=(150, 250))
    cfg4 = OreoConfig(alpha=20.0, delta=5,
                      manager=LayoutManagerConfig(target_partitions=16,
                                                  window_size=100,
                                                  gen_every=50))
    with tempfile.TemporaryDirectory() as td:
        root = td + "/engine_table"
        backend = DiskBackend(tiny, root, background=False, durable=True,
                              wal_snapshot_every=8)
        engine = LayoutEngine(
            OreoPolicy(tiny, build_default_layout(0, tiny, 16, sort_col=0),
                       make_generator("qdtree"), cfg4),
            backend, delta=cfg4.delta,
            ingest=IngestConfig(debt_threshold=0.1))
        for k, query in enumerate(stream):
            engine.step(query)
            if k % 7 == 3:          # writes interleaved with reads
                u = rng.uniform(0, 100, size=(500, 1))
                engine.ingest(np.clip(u + rng.uniform(
                    -2, 2, size=(500, 12)), 0, 100))
        stats = engine.ingest_stats()
        print(f"  appended {stats['ingested_rows']} rows in delta batches; "
              f"{stats['compactions']} debt-triggered compactions; "
              f"{stats['pending_rows']} rows still unclustered "
              f"(debt {stats['clustering_debt']:.2f})")

        # the "crash": walk away mid-ingest — no close(), no flush — and
        # recover by replaying the WAL directory alone
        live = json.load(open(os.path.join(backend._serving_store.root,
                                           "manifest.json")))
        state = DiskBackend.recover_state(root)
        assert state["manifest"] == live, "WAL replay diverged from disk"
        assert state["serving"] == os.path.basename(
            backend._serving_store.root)
        present = all(
            os.path.exists(os.path.join(root, "deltas", d["file"]))
            for d in state["deltas"])
        print(f"  crash + replay: serving store '{state['serving']}' "
              f"reconstructed bitwise from the WAL "
              f"({len(state['deltas'])} pending delta batches, "
              f"{sum(d['rows'] for d in state['deltas'])} rows, all delta "
              f"files present: {present})")
        backend.close()


if __name__ == "__main__":
    main()
