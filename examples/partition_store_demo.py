"""Physical-layer demo: partitioned columnar store + measured alpha.

Writes a table to disk under the default layout, runs queries against it
(reading only non-skippable partitions), reorganizes it under a workload-
aware Qd-tree, and reports the measured speedup + the measured
reorganization-to-scan ratio (the paper's alpha, Table I).

    PYTHONPATH=src python examples/partition_store_demo.py
"""
import tempfile

import numpy as np

from repro.core import build_default_layout, make_generator, make_templates
from repro.data.partition_store import PartitionStore


def main() -> None:
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 100, size=(200_000, 12))
    templates = make_templates(2, 12, rng, selectivity_range=(0.02, 0.08))
    queries = [templates[0].sample(rng, data.min(0), data.max(0))
               for _ in range(60)]

    with tempfile.TemporaryDirectory() as td:
        store = PartitionStore(td + "/table")
        store.write(data, build_default_layout(0, data, 32))

        before = [store.scan(q)[1] for q in queries[:20]]
        scan_s = store.full_scan_seconds()

        gen = make_generator("qdtree")
        layout = gen(1, data, queries, 32)
        reorg_s = store.reorganize(layout)

        after = [store.scan(q)[1] for q in queries[20:40]]
        pr_b = np.mean([s.partitions_read for s in before])
        pr_a = np.mean([s.partitions_read for s in after])
        t_b = np.mean([s.seconds for s in before])
        t_a = np.mean([s.seconds for s in after])
        print(f"partitions read/query: {pr_b:.1f} -> {pr_a:.1f}")
        print(f"query seconds:         {t_b * 1e3:.1f}ms -> {t_a * 1e3:.1f}ms")
        print(f"full scan: {scan_s:.2f}s; reorganization: {reorg_s:.2f}s "
              f"-> measured alpha = {reorg_s / scan_s:.1f}x")


if __name__ == "__main__":
    main()
