"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the OREO-managed data pipeline (drifting data-selection queries trigger
online corpus reorganization), fault-tolerant checkpointing included.

    PYTHONPATH=src python examples/train_with_oreo_pipeline.py \
        [--steps 300] [--arch qwen3-1.7b]

This drives repro.launch.train with a ~100M-param resize of the chosen
architecture (d_model=512, 12 layers, 32k vocab by default).  NOTE: at that
size a CPU-only container takes ~1 min/step; pass e.g.
``--d-model 256 --n-layers 8 --vocab 8000`` for a fast smoke run (36 s for
30 steps on one core).
"""
import subprocess
import sys


def main() -> None:
    args = sys.argv[1:]
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b", "--smoke",
           "--d-model", "512", "--n-layers", "12", "--vocab", "32000",
           "--steps", "300", "--batch", "8", "--seq", "128",
           "--ckpt-dir", "/tmp/repro_e2e_ckpt"]
    # user overrides win
    cmd += args
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
