"""Multi-tenant fleet demo: shared reorg budget over drifting workloads.

Three tenants — each its own table, OREO policy, and α — share one
interleaved query stream and one physical-reorganization budget.  The demo
runs the same drift scenario under three schedulers and shows the paper's
cost split (query vs. reorg) plus the fleet-level effect of deferring swaps:
charges never change, only when the physical swap lands.  The unlimited-
scheduler pass also runs through ``FleetEngine.run_batched`` — the packed
FleetMatrix plane — and checks the batched trace lands the same total cost
as the stepwise loop.

Run:  PYTHONPATH=src python examples/fleet_demo.py
"""
import numpy as np

from repro.core import OreoConfig, build_default_layout, make_generator
from repro.core import layout_manager as lm
from repro.core.workload import make_drift_scenario
from repro.engine import (FleetEngine, InMemoryBackend, KConcurrentScheduler,
                          LayoutEngine, OreoPolicy, TokenBucketScheduler,
                          UnlimitedScheduler)


def tenant_engine(data: np.ndarray, alpha: float) -> LayoutEngine:
    cfg = OreoConfig(alpha=alpha, seed=0, delta=10,
                     manager=lm.LayoutManagerConfig(target_partitions=8,
                                                    window_size=80,
                                                    gen_every=40))
    policy = OreoPolicy(data, build_default_layout(0, data, 8),
                        make_generator("qdtree"), cfg)
    return LayoutEngine(policy, InMemoryBackend(data), delta=cfg.delta)


def main() -> None:
    tenant_data = {f"t{t}": np.random.default_rng(100 + t).uniform(
        0, 100, size=(8_000, 6)) for t in range(3)}
    alphas = {"t0": 4.0, "t1": 8.0, "t2": 16.0}    # per-tenant reorg cost
    col_lo = np.min([d.min(0) for d in tenant_data.values()], axis=0)
    col_hi = np.max([d.max(0) for d in tenant_data.values()], axis=0)

    scenario = "flash_crowd"
    fs = make_drift_scenario(scenario, col_lo, col_hi, num_tenants=3,
                             queries_per_tenant=600, seed=3)
    print(f"scenario={scenario}: {len(fs)} interleaved events, "
          f"tenants={fs.tenant_ids}\n")

    schedulers = [
        UnlimitedScheduler(),
        KConcurrentScheduler(1),
        TokenBucketScheduler(rate=0.005, capacity=1.0, initial=0.0),
    ]
    for scheduler in schedulers:
        fleet = FleetEngine(
            {tid: tenant_engine(tenant_data[tid], alphas[tid])
             for tid in fs.tenant_ids},
            scheduler)
        res = fleet.run(fs)
        print(res.summary())
        for tid in fs.tenant_ids:
            r = res.per_tenant[tid]
            print(f"  {tid}: {r.summary()}")
        print(f"  wall breakdown: decide={res.decide_seconds:.2f}s "
              f"reorg={res.reorg_seconds:.2f}s "
              f"serve={res.serve_seconds:.2f}s\n")

    # Same fleet, batched: one fused FleetMatrix pass scores every
    # tenant's candidate states per round of events.  Decisions, charges
    # and swap timing are bit-identical to the stepwise loop.
    batched = FleetEngine(
        {tid: tenant_engine(tenant_data[tid], alphas[tid])
         for tid in fs.tenant_ids},
        UnlimitedScheduler())
    bres = batched.run_batched(fs)
    baseline = FleetEngine(
        {tid: tenant_engine(tenant_data[tid], alphas[tid])
         for tid in fs.tenant_ids},
        UnlimitedScheduler()).run(fs)
    assert bres.total_cost == baseline.total_cost
    print(f"run_batched over the packed FleetMatrix plane "
          f"(T={len(fs.tenant_ids)} tenants in one fused pass per round): "
          f"total={bres.total_cost:.1f} — identical to the stepwise loop")


if __name__ == "__main__":
    main()
