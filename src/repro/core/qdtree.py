"""Greedy Qd-tree layout generation (Yang et al., SIGMOD'20; paper §VI-A1).

The tree is built on a small data *sample* (0.1%-1% of rows, as in the paper)
using candidate cuts drawn from workload query predicates.  No advanced
(record-induced) cuts -- matching the paper's stated implementation.  Each
split greedily maximizes the expected number of sample rows skipped across the
window's queries.  The resulting binary tree routes any row to a leaf
(= partition id); partition metadata is then computed on the full table.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import layouts, workload as wl


@dataclasses.dataclass
class _Node:
    lo: np.ndarray              # node bounding box (C,)
    hi: np.ndarray
    row_idx: np.ndarray         # sample rows in this node
    col: int = -1               # split column (-1 = leaf)
    threshold: float = 0.0
    left: int = -1              # child node indices
    right: int = -1
    leaf_id: int = -1


def _best_cut(sample: np.ndarray, node: _Node, q_lo: np.ndarray,
              q_hi: np.ndarray, min_leaf_rows: int,
              max_cuts_per_col: int = 64) -> Tuple[float, int, float]:
    """Best (gain, col, value) cut for a node, vectorized per column.

    Candidate cuts are query predicate bounds inside the node box (Qd-tree's
    workload cuts).  For a cut (col, v): the left child box gets hi[col]=v and
    is skipped by queries with lo[col] > v; right child symmetric.  Only
    queries overlapping the node box contribute (others skip both children
    regardless).  gain = skipped_queries_left * rows_left +
    skipped_queries_right * rows_right.
    """
    overlap = ((q_lo <= node.hi[None, :]) &
               (q_hi >= node.lo[None, :])).all(axis=1)          # (Q,)
    if not overlap.any():
        return -1.0, -1, 0.0
    nrows = len(node.row_idx)
    best_gain, best_col, best_v = -1.0, -1, 0.0
    for col in range(sample.shape[1]):
        lo_b = q_lo[overlap, col]
        hi_b = q_hi[overlap, col]
        vs = np.concatenate([lo_b, hi_b])
        vs = np.unique(vs[(vs > node.lo[col]) & (vs < node.hi[col])
                          & np.isfinite(vs)])
        if vs.size == 0:
            continue
        if vs.size > max_cuts_per_col:
            vs = vs[np.linspace(0, vs.size - 1, max_cuts_per_col).astype(int)]
        vals = np.sort(sample[node.row_idx, col])
        n_l = np.searchsorted(vals, vs, side="right")
        n_r = nrows - n_l
        lo_sorted = np.sort(lo_b)
        hi_sorted = np.sort(hi_b)
        skip_l = lo_b.size - np.searchsorted(lo_sorted, vs, side="right")
        skip_r = np.searchsorted(hi_sorted, vs, side="left")
        gains = skip_l * n_l + skip_r * n_r
        valid = (n_l >= min_leaf_rows) & (n_r >= min_leaf_rows)
        gains = np.where(valid, gains, -1.0)
        j = int(np.argmax(gains))
        if gains[j] > best_gain:
            best_gain, best_col, best_v = float(gains[j]), col, float(vs[j])
    return best_gain, best_col, best_v


class _TreeRouter:
    """Vectorized tree routing over the packed node arrays.

    A class (not a closure) so layouts — and the engines holding them —
    stay picklable for cross-process tenant migration.
    """

    def __init__(self, cols, thresholds, lefts, rights, leaf_ids):
        self.cols = cols
        self.thresholds = thresholds
        self.lefts = lefts
        self.rights = rights
        self.leaf_ids = leaf_ids

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        idx = np.zeros(len(rows), dtype=np.int64)
        active = self.cols[idx] >= 0
        while active.any():
            cur = idx[active]
            go_left = rows[active, self.cols[cur]] <= self.thresholds[cur]
            idx[active] = np.where(go_left, self.lefts[cur],
                                   self.rights[cur])
            active = self.cols[idx] >= 0
        return self.leaf_ids[idx]


class _DefaultRouter:
    """Arrival-order (or sort-column quantile) routing; picklable."""

    def __init__(self, k: int, sort_col, boundaries):
        self.k = k
        self.sort_col = sort_col
        self.boundaries = boundaries

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        if self.sort_col is None:
            n2 = len(rows)
            return np.minimum((np.arange(n2) * self.k) // n2, self.k - 1)
        return np.searchsorted(self.boundaries, rows[:, self.sort_col],
                               side="right")


def build_qdtree_layout(layout_id: int,
                        data: np.ndarray,
                        queries: Sequence[wl.Query],
                        k: int,
                        sample_frac: float = 0.01,
                        min_sample_rows: int = 2048,
                        min_leaf_rows: int = 8,
                        seed: int = 0,
                        name: Optional[str] = None) -> layouts.Layout:
    """Greedy Qd-tree with <= k leaves; returns a routable Layout.

    Built entirely on a data sample (paper §VI-A1: 0.1%-1% of rows); the
    returned metadata is the sample *estimate* (rows scaled up).  Exact
    metadata is produced only when the layout is materialized
    (``Layout.materialize``), mirroring the real system where candidate
    exploration never rewrites the table.
    """
    rng = np.random.default_rng(seed)
    n, c = data.shape
    m = min(max(int(n * sample_frac), min(n, min_sample_rows)), n)
    sample_idx = rng.choice(n, size=m, replace=False)
    sample = data[sample_idx]

    q_lo, q_hi = wl.stack_queries(list(queries))

    root = _Node(lo=sample.min(axis=0) - 1e-9, hi=sample.max(axis=0) + 1e-9,
                 row_idx=np.arange(len(sample)))
    nodes: List[_Node] = [root]
    # Max-heap of splittable leaves by row count (split the biggest first).
    heap: List[Tuple[int, int, int]] = [(-len(root.row_idx), 0, 0)]
    tiebreak = 1
    num_leaves = 1
    while num_leaves < k and heap:
        _, _, ni = heapq.heappop(heap)
        node = nodes[ni]
        if len(node.row_idx) < 2 * min_leaf_rows:
            continue
        best = _best_cut(sample, node, q_lo, q_hi, min_leaf_rows)
        if best[1] < 0:
            # No workload cut helps: median-cut the widest queried column to
            # keep sizes bounded (keeps partitions within size targets).
            hist = wl.queried_column_histogram(queries, c)
            col = int(np.argmax(hist)) if hist.sum() else int(
                np.argmax(node.hi - node.lo))
            v = float(np.median(sample[node.row_idx, col]))
            if not (node.lo[col] < v < node.hi[col]):
                continue
            vals = sample[node.row_idx, col]
            if ((vals <= v).sum() == 0
                    or (vals <= v).sum() == len(node.row_idx)):
                continue
            best = (0.0, col, v)
        _, col, v = best
        mask = sample[node.row_idx, col] <= v
        lo_l, hi_l = node.lo.copy(), node.hi.copy()
        hi_l[col] = v
        lo_r, hi_r = node.lo.copy(), node.hi.copy()
        lo_r[col] = v
        left = _Node(lo=lo_l, hi=hi_l, row_idx=node.row_idx[mask])
        right = _Node(lo=lo_r, hi=hi_r, row_idx=node.row_idx[~mask])
        node.col, node.threshold = col, v
        node.left, node.right = len(nodes), len(nodes) + 1
        nodes.append(left)
        nodes.append(right)
        for child_i in (node.left, node.right):
            heapq.heappush(heap, (-len(nodes[child_i].row_idx), tiebreak,
                                  child_i))
            tiebreak += 1
        num_leaves += 1

    # Assign leaf ids.
    leaf_count = 0
    for nd in nodes:
        if nd.col < 0:
            nd.leaf_id = leaf_count
            leaf_count += 1

    cols = np.array([nd.col for nd in nodes], dtype=np.int64)
    thresholds = np.array([nd.threshold for nd in nodes])
    lefts = np.array([nd.left for nd in nodes], dtype=np.int64)
    rights = np.array([nd.right for nd in nodes], dtype=np.int64)
    leaf_ids = np.array([nd.leaf_id for nd in nodes], dtype=np.int64)

    route = _TreeRouter(cols, thresholds, lefts, rights, leaf_ids)
    sample_assignment = route(sample)
    meta = layouts.metadata_from_assignment(sample, sample_assignment,
                                            leaf_count, row_scale=n / m)
    return layouts.Layout(
        layout_id=layout_id,
        name=name or f"qdtree#{layout_id}",
        technique="qdtree",
        meta=meta,
        route=route,
        info={"num_nodes": len(nodes), "num_leaves": leaf_count,
              "sample_rows": m},
    )


def build_default_layout(layout_id: int, data: np.ndarray, k: int,
                         sort_col: Optional[int] = None) -> layouts.Layout:
    """Default layout: partition by arrival order (or a predefined sort col),
    the paper's starting state (e.g. partition-by-time)."""
    n = len(data)
    if sort_col is None:
        order = np.arange(n)
    else:
        order = np.argsort(data[:, sort_col], kind="stable")
    assignment = np.empty(n, dtype=np.int64)
    assignment[order] = np.minimum((np.arange(n) * k) // n, k - 1)
    meta = layouts.metadata_from_assignment(data, assignment, k)

    # Arrival-order layout: contiguous chunks in row order (matches the
    # metadata built above); with a sort col, route by value against the
    # learned quantile boundaries.
    if sort_col is None:
        boundaries = None
    else:
        vals = data[order, sort_col]
        boundaries = vals[np.minimum((np.arange(1, k) * n) // k, n - 1)]
    route = _DefaultRouter(k, sort_col, boundaries)
    return layouts.Layout(layout_id=layout_id, name=f"default#{layout_id}",
                          technique="default", meta=meta, route=route)
