"""Query-stream sampling: sliding window, reservoir, and R-TBS.

The LAYOUT MANAGER generates candidates from a *sliding window* (paper default)
and measures layout similarity on an *R-TBS* (reservoir-based time-biased
sample, Hentschel et al., TODS'19) of the stream (§V-B).  Plain reservoir
sampling is kept for the Table II ablation.
"""
from __future__ import annotations

from typing import Generic, List, TypeVar

import numpy as np

T = TypeVar("T")


class SlidingWindow(Generic[T]):
    """Fixed-size window of the most recent items."""

    def __init__(self, size: int):
        self.size = size
        self.items: List[T] = []

    def add(self, item: T) -> None:
        self.items.append(item)
        if len(self.items) > self.size:
            self.items.pop(0)

    def sample(self) -> List[T]:
        return list(self.items)

    def __len__(self) -> int:
        return len(self.items)


class ReservoirSample(Generic[T]):
    """Classic Vitter reservoir: uniform over the whole history."""

    def __init__(self, size: int, seed: int = 0):
        self.size = size
        self.rng = np.random.default_rng(seed)
        self.items: List[T] = []
        self.seen = 0

    def add(self, item: T) -> None:
        self.seen += 1
        if len(self.items) < self.size:
            self.items.append(item)
        else:
            j = int(self.rng.integers(self.seen))
            if j < self.size:
                self.items[j] = item

    def sample(self) -> List[T]:
        return list(self.items)

    def __len__(self) -> int:
        return len(self.items)


class RTBSample(Generic[T]):
    """Reservoir-based Time-Biased Sampling (R-TBS).

    Items are retained with probability proportional to an exponential decay
    of their age: an item of age a has relative weight exp(-lam * a).  We use
    the simple "replace-with-probability" variant: each arrival is accepted
    into a full reservoir with probability p_accept driven by the weight ratio
    between the newest item (weight 1) and the current average retained
    weight; the evictee is chosen inverse-proportionally to weight.  This
    matches the qualitative property OREO needs -- recency bias with a tail of
    history -- and is exact for lam=0 (uniform reservoir).
    """

    def __init__(self, size: int, lam: float = 1e-3, seed: int = 0):
        self.size = size
        self.lam = lam
        self.rng = np.random.default_rng(seed)
        self.items: List[T] = []
        self.arrival: List[int] = []
        self.t = 0
        #: Bumped whenever the retained sample changes.  Consumers (e.g. the
        #: LayoutManager's cost-vector cache) key derived data on this counter
        #: so rejected arrivals don't invalidate anything.
        self.version = 0

    def _weights(self) -> np.ndarray:
        ages = self.t - np.asarray(self.arrival, dtype=np.float64)
        return np.exp(-self.lam * ages)

    def add(self, item: T) -> None:
        self.t += 1
        if len(self.items) < self.size:
            self.items.append(item)
            self.arrival.append(self.t)
            self.version += 1
            return
        w = self._weights()
        # Accept the (weight-1) newcomer vs. the reservoir's mean weight.
        p_accept = 1.0 / (1.0 + w.mean() * (self.size - 1) / self.size)
        p_accept = min(max(p_accept * 2.0, 1.0 / self.size), 1.0)
        if self.rng.random() < p_accept:
            inv = 1.0 / np.maximum(w, 1e-12)
            evict = int(self.rng.choice(self.size, p=inv / inv.sum()))
            self.items[evict] = item
            self.arrival[evict] = self.t
            self.version += 1

    def sample(self) -> List[T]:
        return list(self.items)

    def __len__(self) -> int:
        return len(self.items)
