"""Extensions sketched in the paper's §VIII / technical-report appendices.

* :class:`MultiCopyDUMTS` -- Appendix-D direction: with storage budget for
  ``kappa`` simultaneous copies of the dataset, the system *holds* a set of
  kappa layouts, services each query with the cheapest held layout, and pays
  the movement cost only to replace one copy.  Algorithm-4 counters/phases
  are kept per state; a held state is ejected when its counter fills.
* :func:`two_state_asymmetric` -- Appendix-C special case: two states with
  asymmetric switch costs (cf. Bruno-Chaudhuri online physical tuning).  The
  classic work-function rule (switch when accumulated extra cost since last
  switch exceeds the switch cost) is 3-competitive.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


class MultiCopyDUMTS:
    """D-UMTS with kappa simultaneously-held layouts (storage-for-query)."""

    def __init__(self, alpha: float, initial_states: Sequence[int],
                 kappa: int = 2, seed: int = 0):
        if kappa < 1:
            raise ValueError("kappa >= 1")
        self.alpha = float(alpha)
        self.kappa = kappa
        self.rng = np.random.default_rng(seed)
        self.states: set = set(initial_states)
        self.counters: Dict[int, float] = {s: 0.0 for s in initial_states}
        self.active: set = set(initial_states)
        init = list(initial_states)[:kappa]
        self.held: List[int] = list(init)
        self.moves = 0
        self.phase = 0

    def add_state(self, state_id: int) -> None:
        if state_id in self.states:
            return
        self.states.add(state_id)
        self.counters[state_id] = 0.0
        self.active.add(state_id)

    def observe(self, costs: Dict[int, float]) -> Tuple[int, float]:
        """Returns (serving_state, cost) -- cost = min over held copies."""
        serving = min(self.held, key=lambda s: costs[s])
        c = costs[serving]
        # Counters accumulate the cost each state would incur as the *sole*
        # layout (the Alg. 3 semantics, unchanged).
        for s in list(self.active):
            self.counters[s] += costs[s]
        self.active = {s for s in self.active
                       if self.counters[s] < self.alpha}
        if not self.active:
            self.counters = {s: 0.0 for s in self.states}
            self.active = set(self.states)
            self.phase += 1
        # Replace any held copy whose counter filled.
        for i, s in enumerate(self.held):
            if s not in self.active:
                candidates = [a for a in self.active if a not in self.held]
                if not candidates:
                    continue
                self.held[i] = int(self.rng.choice(sorted(candidates)))
                self.moves += 1
        return serving, c

    @property
    def total_reorg_cost(self) -> float:
        return self.moves * self.alpha


def two_state_asymmetric(costs_a: Sequence[float], costs_b: Sequence[float],
                         alpha_ab: float, alpha_ba: float
                         ) -> Tuple[float, List[int]]:
    """Work-function online algorithm for 2 states with asymmetric switch
    costs.  Switch away from the current state when the accumulated excess
    cost since the last switch exceeds the cost of switching *back and
    forth* is not required -- the one-way switch cost suffices for the
    3-competitive bound in this special case.

    Returns (total cost, per-query state sequence).
    """
    assert len(costs_a) == len(costs_b)
    state = 0
    regret = 0.0
    total = 0.0
    seq: List[int] = []
    for ca, cb in zip(costs_a, costs_b):
        here, there = (ca, cb) if state == 0 else (cb, ca)
        switch_cost = alpha_ab if state == 0 else alpha_ba
        regret = max(0.0, regret + (here - there))
        if regret > switch_cost:
            total += switch_cost
            state = 1 - state
            regret = 0.0
            here = ca if state == 0 else cb
        total += here
        seq.append(state)
    return total, seq


def offline_two_state(costs_a: Sequence[float], costs_b: Sequence[float],
                      alpha_ab: float, alpha_ba: float) -> float:
    """Optimal offline two-state cost via dynamic programming."""
    best = [0.0, alpha_ab]     # start in state 0 by convention
    for ca, cb in zip(costs_a, costs_b):
        best = [
            min(best[0], best[1] + alpha_ba) + ca,
            min(best[1], best[0] + alpha_ab) + cb,
        ]
    return min(best)
