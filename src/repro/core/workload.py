"""Query workload generation.

Reproduces the paper's workload generator: a state machine that samples range
queries from one query *template* for an arbitrary amount of time before
switching to another random template (§VI-A2).  Templates focus on a small set
of columns with a target selectivity, mimicking TPC-H/TPC-DS template families
and the Telemetry workload (time-range + collector filters).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Query:
    """Conjunctive range query: per-column [lo, hi] bounds ((C,) arrays)."""

    lo: np.ndarray
    hi: np.ndarray
    template_id: int = -1

    @property
    def num_columns(self) -> int:
        return int(self.lo.shape[0])


def stack_queries(queries: Sequence[Query]) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorize a list of queries into (Q, C) lo/hi arrays."""
    if not queries:
        raise ValueError("empty query list")
    lo = np.stack([q.lo for q in queries])
    hi = np.stack([q.hi for q in queries])
    return lo, hi


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    """A template: a set of predicate columns + target per-column selectivity."""

    template_id: int
    columns: Tuple[int, ...]
    selectivities: Tuple[float, ...]

    def sample(self, rng: np.random.Generator, col_lo: np.ndarray,
               col_hi: np.ndarray) -> Query:
        c = col_lo.shape[0]
        lo = np.full(c, -np.inf)
        hi = np.full(c, np.inf)
        for col, sel in zip(self.columns, self.selectivities):
            span = col_hi[col] - col_lo[col]
            width = span * sel
            start = col_lo[col] + rng.uniform(0.0, max(span - width, 1e-12))
            lo[col] = start
            hi[col] = start + width
        return Query(lo=lo, hi=hi, template_id=self.template_id)


def make_templates(num_templates: int, num_columns: int,
                   rng: np.random.Generator,
                   cols_per_template: Tuple[int, int] = (1, 3),
                   selectivity_range: Tuple[float, float] = (0.01, 0.15),
                   ) -> List[QueryTemplate]:
    """Random template set: each focuses on 1-3 columns (paper's generator)."""
    templates = []
    for t in range(num_templates):
        k = int(rng.integers(cols_per_template[0], cols_per_template[1] + 1))
        cols = tuple(int(c) for c in rng.choice(num_columns, size=k,
                                                replace=False))
        sels = tuple(float(rng.uniform(*selectivity_range)) for _ in range(k))
        templates.append(QueryTemplate(t, cols, sels))
    return templates


@dataclasses.dataclass
class WorkloadStream:
    """Materialized workload: queries + ground-truth template segmentation."""

    queries: List[Query]
    segments: List[Tuple[int, int, int]]   # (start_idx, end_idx_excl, template_id)
    templates: List[QueryTemplate]

    def __len__(self) -> int:
        return len(self.queries)

    def __iter__(self) -> Iterator[Query]:
        return iter(self.queries)

    @property
    def num_switches(self) -> int:
        return max(len(self.segments) - 1, 0)


def generate_workload(templates: Sequence[QueryTemplate],
                      col_lo: np.ndarray, col_hi: np.ndarray,
                      total_queries: int,
                      seed: int = 0,
                      segment_length: Tuple[int, int] = (800, 2200),
                      num_segments: Optional[int] = None) -> WorkloadStream:
    """State-machine workload: stay in one template for a random stretch,
    then jump to another random template (never the same one twice in a row).
    """
    rng = np.random.default_rng(seed)
    queries: List[Query] = []
    segments: List[Tuple[int, int, int]] = []
    current = int(rng.integers(len(templates)))
    if num_segments is not None:
        # Divide the stream into exactly num_segments segments.
        cuts = np.linspace(0, total_queries, num_segments + 1).astype(int)
        lengths = np.diff(cuts)
    else:
        lengths = []
        remaining = total_queries
        while remaining > 0:
            ln = int(rng.integers(*segment_length))
            ln = min(ln, remaining)
            lengths.append(ln)
            remaining -= ln
    start = 0
    for ln in lengths:
        for _ in range(ln):
            queries.append(templates[current].sample(rng, col_lo, col_hi))
        segments.append((start, start + ln, current))
        start += ln
        # Switch template.
        if len(templates) > 1:
            nxt = int(rng.integers(len(templates)))
            while nxt == current:
                nxt = int(rng.integers(len(templates)))
            current = nxt
    return WorkloadStream(queries=queries, segments=segments,
                          templates=list(templates))


def queried_column_histogram(queries: Sequence[Query],
                             num_columns: int) -> np.ndarray:
    """How often each column appears with a finite predicate -- used by the
    workload-aware Z-order generator (top-k most-queried columns)."""
    hist = np.zeros(num_columns, dtype=np.int64)
    for q in queries:
        finite = np.isfinite(q.lo) | np.isfinite(q.hi)
        hist += finite.astype(np.int64)
    return hist
