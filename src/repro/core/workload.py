"""Query workload generation.

Reproduces the paper's workload generator: a state machine that samples range
queries from one query *template* for an arbitrary amount of time before
switching to another random template (§VI-A2).  Templates focus on a small set
of columns with a target selectivity, mimicking TPC-H/TPC-DS template families
and the Telemetry workload (time-range + collector filters).

Beyond the single-stream generator, this module hosts the **drift-scenario
registry** (:data:`DRIFT_SCENARIOS`): named generators of interleaved
multi-tenant :class:`FleetStream`\\ s — sudden template shift, gradual
interpolated drift, cyclic/diurnal rotation, flash-crowd burst, and template
churn — the workload conditions a multi-tenant fleet
(:class:`repro.engine.FleetEngine`) is exercised under.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import (Callable, Dict, Iterator, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import numpy as np


@dataclasses.dataclass(frozen=True)
class Query:
    """Conjunctive range query: per-column [lo, hi] bounds ((C,) arrays)."""

    lo: np.ndarray
    hi: np.ndarray
    template_id: int = -1

    @property
    def num_columns(self) -> int:
        return int(self.lo.shape[0])


def stack_queries(queries: Sequence[Query]) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorize a list of queries into (Q, C) lo/hi arrays."""
    if not queries:
        raise ValueError("empty query list")
    lo = np.stack([q.lo for q in queries])
    hi = np.stack([q.hi for q in queries])
    return lo, hi


# ---------------------------------------------------------------------------
# The typed event envelope (the fleet-level request API)
# ---------------------------------------------------------------------------

class QueryEvent(NamedTuple):
    """One tenant's range query, addressed to the fleet.

    A ``NamedTuple`` on purpose: it *is* the legacy ``(tenant_id, query)``
    pair, so streams of typed events unpack, index and compare exactly like
    the tuples they replace — only construction gained a type.
    """

    tenant_id: str
    query: Query


class IngestEvent(NamedTuple):
    """One tenant's append batch, addressed to the fleet.

    Tuple-compatible with the legacy ``(tenant_id, IngestBatch)`` pair,
    like :class:`QueryEvent`.
    """

    tenant_id: str
    batch: "IngestBatch"


#: The fleet's one request envelope: every entry point
#: (:meth:`repro.engine.FleetEngine.submit`, ``run``, ``run_batched``,
#: :class:`repro.serve.ServeFrontend`) consumes this union.
Event = Union[QueryEvent, IngestEvent]


def as_event(obj) -> Event:
    """Coerce a request into the typed :data:`Event` union.

    Typed events pass through untouched.  Legacy bare ``(tenant_id,
    Query)`` / ``(tenant_id, IngestBatch)`` pairs still work but raise a
    :class:`DeprecationWarning` — construct :class:`QueryEvent` /
    :class:`IngestEvent` instead.
    """
    if isinstance(obj, (QueryEvent, IngestEvent)):
        return obj
    if isinstance(obj, (tuple, list)) and len(obj) == 2:
        tid, payload = obj
        if isinstance(payload, Query):
            warnings.warn(
                "bare (tenant_id, Query) event tuples are deprecated; "
                "pass repro.core.workload.QueryEvent(tenant_id, query)",
                DeprecationWarning, stacklevel=3)
            return QueryEvent(str(tid), payload)
        if isinstance(payload, IngestBatch):
            warnings.warn(
                "bare (tenant_id, IngestBatch) event tuples are deprecated; "
                "pass repro.core.workload.IngestEvent(tenant_id, batch)",
                DeprecationWarning, stacklevel=3)
            return IngestEvent(str(tid), payload)
    raise TypeError(
        f"not a fleet event: {obj!r} (expected QueryEvent, IngestEvent, or "
        f"a legacy (tenant_id, Query|IngestBatch) pair)")


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    """A template: a set of predicate columns + target per-column selectivity."""

    template_id: int
    columns: Tuple[int, ...]
    selectivities: Tuple[float, ...]

    def sample(self, rng: np.random.Generator, col_lo: np.ndarray,
               col_hi: np.ndarray) -> Query:
        c = col_lo.shape[0]
        lo = np.full(c, -np.inf)
        hi = np.full(c, np.inf)
        for col, sel in zip(self.columns, self.selectivities):
            span = col_hi[col] - col_lo[col]
            width = span * sel
            start = col_lo[col] + rng.uniform(0.0, max(span - width, 1e-12))
            lo[col] = start
            hi[col] = start + width
        return Query(lo=lo, hi=hi, template_id=self.template_id)


def make_templates(num_templates: int, num_columns: int,
                   rng: np.random.Generator,
                   cols_per_template: Tuple[int, int] = (1, 3),
                   selectivity_range: Tuple[float, float] = (0.01, 0.15),
                   ) -> List[QueryTemplate]:
    """Random template set: each focuses on 1-3 columns (paper's generator)."""
    templates = []
    for t in range(num_templates):
        k = int(rng.integers(cols_per_template[0], cols_per_template[1] + 1))
        cols = tuple(int(c) for c in rng.choice(num_columns, size=k,
                                                replace=False))
        sels = tuple(float(rng.uniform(*selectivity_range)) for _ in range(k))
        templates.append(QueryTemplate(t, cols, sels))
    return templates


@dataclasses.dataclass
class WorkloadStream:
    """Materialized workload: queries + ground-truth template segmentation."""

    queries: List[Query]
    segments: List[Tuple[int, int, int]]   # (start_idx, end_idx_excl, template_id)
    templates: List[QueryTemplate]

    def __len__(self) -> int:
        return len(self.queries)

    def __iter__(self) -> Iterator[Query]:
        return iter(self.queries)

    @property
    def num_switches(self) -> int:
        return max(len(self.segments) - 1, 0)


def generate_workload(templates: Sequence[QueryTemplate],
                      col_lo: np.ndarray, col_hi: np.ndarray,
                      total_queries: int,
                      seed: int = 0,
                      segment_length: Tuple[int, int] = (800, 2200),
                      num_segments: Optional[int] = None) -> WorkloadStream:
    """State-machine workload: stay in one template for a random stretch,
    then jump to another random template (never the same one twice in a row).
    """
    rng = np.random.default_rng(seed)
    queries: List[Query] = []
    segments: List[Tuple[int, int, int]] = []
    current = int(rng.integers(len(templates)))
    if num_segments is not None:
        # Divide the stream into exactly num_segments segments.
        cuts = np.linspace(0, total_queries, num_segments + 1).astype(int)
        lengths = np.diff(cuts)
    else:
        lengths = []
        remaining = total_queries
        while remaining > 0:
            ln = int(rng.integers(*segment_length))
            ln = min(ln, remaining)
            lengths.append(ln)
            remaining -= ln
    start = 0
    for ln in lengths:
        for _ in range(ln):
            queries.append(templates[current].sample(rng, col_lo, col_hi))
        segments.append((start, start + ln, current))
        start += ln
        # Switch template.
        if len(templates) > 1:
            nxt = int(rng.integers(len(templates)))
            while nxt == current:
                nxt = int(rng.integers(len(templates)))
            current = nxt
    return WorkloadStream(queries=queries, segments=segments,
                          templates=list(templates))


# ---------------------------------------------------------------------------
# Multi-tenant drift scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetStream:
    """An interleaved multi-tenant workload with per-tenant ground truth.

    ``events`` is the fleet-level stream of :class:`QueryEvent`\\ s in
    arrival order (tuple-compatible with the legacy ``(tenant_id, query)``
    pairs); ``per_tenant`` holds each tenant's queries *in the same
    relative order* as an ordinary :class:`WorkloadStream` (with its own
    segmentation), so a tenant's standalone run over ``per_tenant[tid]`` is
    the golden reference for its fleet trace.
    """

    scenario: str
    events: List[QueryEvent]
    per_tenant: Dict[str, WorkloadStream]

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[QueryEvent]:
        return iter(self.events)

    @property
    def tenant_ids(self) -> List[str]:
        return list(self.per_tenant)


#: name -> scenario generator; populated by :func:`drift_scenario` below.
DRIFT_SCENARIOS: Dict[str, Callable[..., FleetStream]] = {}


@dataclasses.dataclass(frozen=True)
class ScenarioInfo:
    """Ground-truth drift parameters of a registered scenario.

    Declared at registration next to the generator, so benchmarks can
    report forecast accuracy against what the scenario *actually does*
    (period, drift rate, shift window) instead of re-deriving it from the
    stream.  All tick-valued quantities are expressed as fractions of a
    tenant's stream (scenarios scale with ``queries_per_tenant``); use
    :meth:`period_ticks` for the absolute cycle length.

    ``forecastable`` marks scenarios whose structure a workload
    forecaster can exploit in principle (recurring or smoothly drifting
    mixtures).  A one-shot jump is *detectable* after the fact but not
    predictable before it, so ``sudden_shift`` and friends are False.
    """

    name: str
    family: str                     # "drift" | "ingest"
    forecastable: bool = False
    #: Cyclic scenarios: templates per cycle / cycles per stream.
    num_phases: Optional[int] = None
    cycles: Optional[int] = None
    #: One-shot shifts: the (lo, hi) fraction window the shift tick is
    #: drawn from per tenant.
    shift_window: Optional[Tuple[float, float]] = None
    #: Gradual drift: fraction of the stream the mixture slides over.
    drift_span: Optional[float] = None
    #: Flash crowd: burst start fraction and burst length fraction.
    burst_start: Optional[float] = None
    burst_fraction: Optional[float] = None
    #: Template churn: fresh-template segments per stream.
    num_segments: Optional[int] = None

    def period_ticks(self, queries_per_tenant: int) -> Optional[int]:
        """Per-tenant cycle length in queries, if the scenario cycles."""
        if self.num_phases is None or self.cycles is None:
            return None
        block = max(queries_per_tenant // (self.num_phases * self.cycles), 1)
        return self.num_phases * block

    def drift_rate(self, queries_per_tenant: int) -> Optional[float]:
        """Mixture-share change per query, if the scenario drifts."""
        if self.drift_span is None:
            return None
        span = self.drift_span * max(queries_per_tenant - 1, 1)
        return 1.0 / span


#: name -> ScenarioInfo for every registered scenario (drift and ingest).
SCENARIO_INFO: Dict[str, ScenarioInfo] = {}


def forecastable_scenarios() -> List[str]:
    """Names of registered scenarios a forecaster can exploit."""
    return sorted(n for n, i in SCENARIO_INFO.items() if i.forecastable)


def drift_scenario(name: str, forecastable: bool = False, **meta):
    """Register a named multi-tenant drift-scenario generator.

    Keyword metadata lands in :data:`SCENARIO_INFO` as a
    :class:`ScenarioInfo` — the ground truth benchmark reports compare
    forecasts against.
    """
    def deco(fn):
        DRIFT_SCENARIOS[name] = fn
        SCENARIO_INFO[name] = ScenarioInfo(name=name, family="drift",
                                           forecastable=forecastable, **meta)
        fn.scenario_name = name
        return fn
    return deco


def make_drift_scenario(name: str, col_lo: np.ndarray, col_hi: np.ndarray,
                        num_tenants: int = 4, queries_per_tenant: int = 2000,
                        seed: int = 0, **kwargs) -> FleetStream:
    """Instantiate a registered drift scenario by name."""
    if name not in DRIFT_SCENARIOS:
        raise KeyError(f"unknown drift scenario {name!r}; "
                       f"known: {sorted(DRIFT_SCENARIOS)}")
    return DRIFT_SCENARIOS[name](
        col_lo=col_lo, col_hi=col_hi, num_tenants=num_tenants,
        queries_per_tenant=queries_per_tenant, seed=seed, **kwargs)


def _tenant_ids(num_tenants: int) -> List[str]:
    return [f"t{t}" for t in range(num_tenants)]


def _stream_from_plan(plan: Sequence[Tuple[QueryTemplate, int]],
                      templates: Sequence[QueryTemplate],
                      col_lo: np.ndarray, col_hi: np.ndarray,
                      rng: np.random.Generator) -> WorkloadStream:
    """Materialize a (template, segment_length) plan into a WorkloadStream."""
    queries: List[Query] = []
    segments: List[Tuple[int, int, int]] = []
    start = 0
    for tmpl, length in plan:
        for _ in range(length):
            queries.append(tmpl.sample(rng, col_lo, col_hi))
        if length > 0:
            segments.append((start, start + length, tmpl.template_id))
        start += length
    return WorkloadStream(queries=queries, segments=segments,
                          templates=list(templates))


def interleave_streams(per_tenant: Dict[str, WorkloadStream],
                       weight_fn: Optional[Callable[[str, int], float]] = None,
                       ) -> List[QueryEvent]:
    """Deterministic weighted-fair interleave of per-tenant streams.

    Smooth weighted round-robin: each pick adds every live tenant's current
    weight to its credit, emits the highest-credit tenant's next query, and
    debits that tenant by the total live weight.  ``weight_fn(tenant_id,
    next_index)`` may vary over a tenant's progress (e.g. a flash-crowd
    burst); the default is uniform round-robin.  Per-tenant query order is
    always preserved.
    """
    tids = sorted(per_tenant)
    cursors = {tid: 0 for tid in tids}
    credits = {tid: 0.0 for tid in tids}
    events: List[QueryEvent] = []
    total = sum(len(s) for s in per_tenant.values())
    for _ in range(total):
        live = [t for t in tids if cursors[t] < len(per_tenant[t].queries)]
        weights = {t: (weight_fn(t, cursors[t]) if weight_fn else 1.0)
                   for t in live}
        for t in live:
            credits[t] += weights[t]
        pick = max(live, key=lambda t: credits[t])
        credits[pick] -= sum(weights.values())
        events.append(QueryEvent(pick, per_tenant[pick].queries[cursors[pick]]))
        cursors[pick] += 1
    return events


def _scenario_rngs(seed: int, num_tenants: int) -> List[np.random.Generator]:
    """One independent generator per tenant (tenants are separate tables)."""
    root = np.random.SeedSequence(seed)
    return [np.random.default_rng(s) for s in root.spawn(num_tenants)]


@drift_scenario("sudden_shift", shift_window=(0.35, 0.65))
def sudden_shift(col_lo: np.ndarray, col_hi: np.ndarray, num_tenants: int = 4,
                 queries_per_tenant: int = 2000, seed: int = 0,
                 ) -> FleetStream:
    """Each tenant abruptly switches template once, at a staggered point.

    The motivating condition of the paper: a hard workload change that a
    static layout cannot follow.  Shift points are spread across tenants so
    the fleet sees a rolling wave of reorganization pressure.
    """
    per_tenant: Dict[str, WorkloadStream] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(2, col_lo.shape[0], rng)
        shift = int(queries_per_tenant * rng.uniform(0.35, 0.65))
        plan = [(tmpls[0], shift),
                (tmpls[1], queries_per_tenant - shift)]
        per_tenant[f"t{t}"] = _stream_from_plan(plan, tmpls, col_lo, col_hi,
                                                rng)
    return FleetStream("sudden_shift", interleave_streams(per_tenant),
                       per_tenant)


@drift_scenario("gradual_drift", forecastable=True, drift_span=1.0)
def gradual_drift(col_lo: np.ndarray, col_hi: np.ndarray,
                  num_tenants: int = 4, queries_per_tenant: int = 2000,
                  seed: int = 0) -> FleetStream:
    """Smoothly interpolated drift from one template family to another.

    Query ``j`` of a tenant samples from the target template with
    probability ``j / (T - 1)``, so the mixture slides from 100% source to
    100% target with no hard boundary — the regime where switch-point
    detectors (and static layouts) degrade gracefully or not at all.
    """
    per_tenant: Dict[str, WorkloadStream] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(2, col_lo.shape[0], rng)
        total = queries_per_tenant
        queries: List[Query] = []
        for j in range(total):
            frac = j / max(total - 1, 1)
            tmpl = tmpls[1] if rng.uniform() < frac else tmpls[0]
            queries.append(tmpl.sample(rng, col_lo, col_hi))
        # Ground-truth segmentation is approximate by construction: label
        # the source-dominant and target-dominant halves.
        segments = [(0, total // 2, tmpls[0].template_id),
                    (total // 2, total, tmpls[1].template_id)]
        per_tenant[f"t{t}"] = WorkloadStream(queries=queries,
                                             segments=segments,
                                             templates=list(tmpls))
    return FleetStream("gradual_drift", interleave_streams(per_tenant),
                       per_tenant)


@drift_scenario("cyclic_diurnal", forecastable=True, num_phases=3,
                cycles=4)
def cyclic_diurnal(col_lo: np.ndarray, col_hi: np.ndarray,
                   num_tenants: int = 4, queries_per_tenant: int = 2000,
                   seed: int = 0, num_phases: int = 3, cycles: int = 4,
                   ) -> FleetStream:
    """Diurnal rotation: templates recur in a fixed cycle, phase-shifted
    per tenant (tenants "peak" at different times of day).

    Recurring templates reward keeping previously-generated layouts in the
    state space instead of regenerating them every period.
    """
    per_tenant: Dict[str, WorkloadStream] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(num_phases, col_lo.shape[0], rng)
        block = max(queries_per_tenant // (num_phases * cycles), 1)
        phase0 = t % num_phases                     # per-tenant phase shift
        plan: List[Tuple[QueryTemplate, int]] = []
        emitted = 0
        k = 0
        while emitted < queries_per_tenant:
            tmpl = tmpls[(phase0 + k) % num_phases]
            length = min(block, queries_per_tenant - emitted)
            plan.append((tmpl, length))
            emitted += length
            k += 1
        per_tenant[f"t{t}"] = _stream_from_plan(plan, tmpls, col_lo, col_hi,
                                                rng)
    return FleetStream("cyclic_diurnal", interleave_streams(per_tenant),
                       per_tenant)


@drift_scenario("flash_crowd", burst_start=0.4, burst_fraction=0.15)
def flash_crowd(col_lo: np.ndarray, col_hi: np.ndarray, num_tenants: int = 4,
                queries_per_tenant: int = 2000, seed: int = 0,
                burst_tenant: int = 0, burst_frac: float = 0.15,
                burst_rate: float = 4.0) -> FleetStream:
    """One tenant's traffic spikes: a hot template takes over *and* its
    event rate multiplies for the burst window.

    During the burst the victim tenant emits ``burst_rate`` events for every
    one of each other tenant's, concentrating both serving load and
    reorganization pressure at the same fleet ticks — the worst case for a
    shared reorg budget.
    """
    burst_tid = f"t{burst_tenant % num_tenants}"
    per_tenant: Dict[str, WorkloadStream] = {}
    burst_range: Tuple[int, int] = (0, 0)
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tid = f"t{t}"
        tmpls = make_templates(2, col_lo.shape[0], rng)
        if tid == burst_tid:
            burst_len = int(queries_per_tenant * burst_frac)
            start = int(queries_per_tenant * 0.4)
            plan = [(tmpls[0], start),
                    (tmpls[1], burst_len),            # the flash crowd
                    (tmpls[0], queries_per_tenant - start - burst_len)]
            burst_range = (start, start + burst_len)
        else:
            plan = [(tmpls[0], queries_per_tenant)]
        per_tenant[tid] = _stream_from_plan(plan, tmpls, col_lo, col_hi, rng)

    def weight(tid: str, next_index: int) -> float:
        if tid == burst_tid and burst_range[0] <= next_index < burst_range[1]:
            return burst_rate
        return 1.0

    return FleetStream("flash_crowd",
                       interleave_streams(per_tenant, weight_fn=weight),
                       per_tenant)


@drift_scenario("template_churn", num_segments=6)
def template_churn(col_lo: np.ndarray, col_hi: np.ndarray,
                   num_tenants: int = 4, queries_per_tenant: int = 2000,
                   seed: int = 0, num_segments: int = 6) -> FleetStream:
    """Templates enter and leave: every segment brings a never-seen-before
    template and retires the previous one.

    No template recurs, so cached layouts go stale continuously — the
    stress test for candidate generation and ε-admission (state churn), as
    opposed to switching among a stable set.
    """
    per_tenant: Dict[str, WorkloadStream] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        c = col_lo.shape[0]
        segs = max(num_segments, 1)
        cuts = np.linspace(0, queries_per_tenant, segs + 1).astype(int)
        tmpls: List[QueryTemplate] = []
        plan: List[Tuple[QueryTemplate, int]] = []
        for s in range(segs):
            fresh = make_templates(1, c, rng)[0]
            fresh = dataclasses.replace(fresh, template_id=s)
            tmpls.append(fresh)
            plan.append((fresh, int(cuts[s + 1] - cuts[s])))
        per_tenant[f"t{t}"] = _stream_from_plan(plan, tmpls, col_lo, col_hi,
                                                rng)
    return FleetStream("template_churn", interleave_streams(per_tenant),
                       per_tenant)


# ---------------------------------------------------------------------------
# Streaming ingest scenarios (mixed read/write event streams)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IngestBatch:
    """One append event: rows to land as an unclustered delta partition."""

    rows: np.ndarray            # (N, C)
    batch_id: int = -1

    @property
    def num_rows(self) -> int:
        return int(len(self.rows))


@dataclasses.dataclass
class IngestStream:
    """An interleaved multi-tenant stream mixing queries and appends.

    ``events`` is the fleet-level arrival order of typed :data:`Event`
    envelopes (:class:`QueryEvent` / :class:`IngestEvent`, each
    tuple-compatible with the legacy ``(tenant_id, payload)`` pairs);
    ``per_tenant`` preserves each tenant's own event order (the golden
    reference for a standalone replay of that tenant).
    """

    scenario: str
    events: List[Event]
    per_tenant: Dict[str, List[object]]

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events)

    @property
    def tenant_ids(self) -> List[str]:
        return list(self.per_tenant)

    def tenant_queries(self, tenant_id: str) -> List[Query]:
        return [e for e in self.per_tenant[tenant_id]
                if isinstance(e, Query)]

    def tenant_batches(self, tenant_id: str) -> List[IngestBatch]:
        return [e for e in self.per_tenant[tenant_id]
                if isinstance(e, IngestBatch)]

    @property
    def total_appended_rows(self) -> int:
        return sum(e[1].num_rows for e in self.events
                   if isinstance(e[1], IngestBatch))


#: name -> scenario generator; populated by :func:`ingest_scenario` below.
INGEST_SCENARIOS: Dict[str, Callable[..., IngestStream]] = {}


def ingest_scenario(name: str, forecastable: bool = False, **meta):
    """Register a named mixed read/write scenario generator (metadata
    lands in :data:`SCENARIO_INFO`, exactly like :func:`drift_scenario`)."""
    def deco(fn):
        INGEST_SCENARIOS[name] = fn
        SCENARIO_INFO[name] = ScenarioInfo(name=name, family="ingest",
                                           forecastable=forecastable, **meta)
        fn.scenario_name = name
        return fn
    return deco


def make_ingest_scenario(name: str, col_lo: np.ndarray, col_hi: np.ndarray,
                         num_tenants: int = 3,
                         queries_per_tenant: int = 1500,
                         seed: int = 0, **kwargs) -> IngestStream:
    """Instantiate a registered ingest scenario by name."""
    if name not in INGEST_SCENARIOS:
        raise KeyError(f"unknown ingest scenario {name!r}; "
                       f"known: {sorted(INGEST_SCENARIOS)}")
    return INGEST_SCENARIOS[name](
        col_lo=col_lo, col_hi=col_hi, num_tenants=num_tenants,
        queries_per_tenant=queries_per_tenant, seed=seed, **kwargs)


def interleave_event_streams(per_tenant: Dict[str, List[object]],
                             weight_fn: Optional[Callable[[str, int],
                                                          float]] = None,
                             ) -> List[Event]:
    """Smooth-WRR interleave of per-tenant *mixed* event lists.

    Identical discipline to :func:`interleave_streams` (same credits, same
    tie-breaking), generalized from query lists to lists that may also
    hold :class:`IngestBatch` events.  Per-tenant event order is always
    preserved.
    """
    tids = sorted(per_tenant)
    cursors = {tid: 0 for tid in tids}
    credits = {tid: 0.0 for tid in tids}
    events: List[Event] = []
    total = sum(len(s) for s in per_tenant.values())
    for _ in range(total):
        live = [t for t in tids if cursors[t] < len(per_tenant[t])]
        weights = {t: (weight_fn(t, cursors[t]) if weight_fn else 1.0)
                   for t in live}
        for t in live:
            credits[t] += weights[t]
        pick = max(live, key=lambda t: credits[t])
        credits[pick] -= sum(weights.values())
        payload = per_tenant[pick][cursors[pick]]
        events.append(QueryEvent(pick, payload)
                      if isinstance(payload, Query)
                      else IngestEvent(pick, payload))
        cursors[pick] += 1
    return events


def _sample_batch(rng: np.random.Generator, col_lo: np.ndarray,
                  col_hi: np.ndarray, rows: int) -> IngestBatch:
    """Uniform rows over the full domain: maximally unclustered appends
    (a delta partition's bounds then span whatever arrived, so queries
    can rarely skip it — the worst case the debt meter prices)."""
    return IngestBatch(rows=rng.uniform(col_lo, col_hi,
                                        size=(rows, col_lo.shape[0])))


def _weave(queries: Sequence[Query],
           batch_after: Dict[int, List[IngestBatch]]) -> List[object]:
    """Per-tenant event list: each query, with any batches scheduled
    after it inserted in order (index -1 batches lead the stream)."""
    events: List[object] = list(batch_after.get(-1, []))
    for k, q in enumerate(queries):
        events.append(q)
        events.extend(batch_after.get(k, []))
    return events


@ingest_scenario("trickle")
def trickle_ingest(col_lo: np.ndarray, col_hi: np.ndarray,
                   num_tenants: int = 3, queries_per_tenant: int = 1500,
                   seed: int = 0, every: int = 10, batch_rows: int = 40,
                   ) -> IngestStream:
    """Steady trickle: a small append every ``every`` queries, one stable
    query template — the base case for debt-metered compaction."""
    per_tenant: Dict[str, List[object]] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(1, col_lo.shape[0], rng)
        stream = _stream_from_plan([(tmpls[0], queries_per_tenant)], tmpls,
                                   col_lo, col_hi, rng)
        batches = {k: [_sample_batch(rng, col_lo, col_hi, batch_rows)]
                   for k in range(every - 1, queries_per_tenant, every)}
        per_tenant[f"t{t}"] = _weave(stream.queries, batches)
    return IngestStream("trickle", interleave_event_streams(per_tenant),
                        per_tenant)


@ingest_scenario("append_heavy")
def append_heavy(col_lo: np.ndarray, col_hi: np.ndarray,
                 num_tenants: int = 3, queries_per_tenant: int = 1500,
                 seed: int = 0, every: int = 4, batch_rows: int = 80,
                 ) -> IngestStream:
    """Write-dominated: frequent, larger appends keep delta partitions
    piling on faster than any single compaction clears them."""
    per_tenant: Dict[str, List[object]] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(1, col_lo.shape[0], rng)
        stream = _stream_from_plan([(tmpls[0], queries_per_tenant)], tmpls,
                                   col_lo, col_hi, rng)
        batches = {k: [_sample_batch(rng, col_lo, col_hi, batch_rows)]
                   for k in range(every - 1, queries_per_tenant, every)}
        per_tenant[f"t{t}"] = _weave(stream.queries, batches)
    return IngestStream("append_heavy", interleave_event_streams(per_tenant),
                        per_tenant)


@ingest_scenario("mixed_rw", shift_window=(0.4, 0.6))
def mixed_rw(col_lo: np.ndarray, col_hi: np.ndarray, num_tenants: int = 3,
             queries_per_tenant: int = 1500, seed: int = 0,
             every: int = 8, batch_rows: int = 50) -> IngestStream:
    """Reads drift while writes trickle: a mid-stream template shift makes
    drift reorgs and debt compactions compete for the same α budget."""
    per_tenant: Dict[str, List[object]] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(2, col_lo.shape[0], rng)
        shift = int(queries_per_tenant * rng.uniform(0.4, 0.6))
        stream = _stream_from_plan(
            [(tmpls[0], shift), (tmpls[1], queries_per_tenant - shift)],
            tmpls, col_lo, col_hi, rng)
        batches = {k: [_sample_batch(rng, col_lo, col_hi, batch_rows)]
                   for k in range(every - 1, queries_per_tenant, every)}
        per_tenant[f"t{t}"] = _weave(stream.queries, batches)
    return IngestStream("mixed_rw", interleave_event_streams(per_tenant),
                        per_tenant)


@ingest_scenario("ingest_burst")
def ingest_burst(col_lo: np.ndarray, col_hi: np.ndarray,
                 num_tenants: int = 3, queries_per_tenant: int = 1500,
                 seed: int = 0, burst_start: float = 0.3,
                 burst_end: float = 0.5, every: int = 3,
                 batch_rows: int = 100) -> IngestStream:
    """A concentrated load window then a long read-only tail: everything
    appended lands inside ``[burst_start, burst_end)`` of the stream."""
    per_tenant: Dict[str, List[object]] = {}
    lo_k = int(queries_per_tenant * burst_start)
    hi_k = int(queries_per_tenant * burst_end)
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(1, col_lo.shape[0], rng)
        stream = _stream_from_plan([(tmpls[0], queries_per_tenant)], tmpls,
                                   col_lo, col_hi, rng)
        batches = {k: [_sample_batch(rng, col_lo, col_hi, batch_rows)]
                   for k in range(lo_k, hi_k, every)}
        per_tenant[f"t{t}"] = _weave(stream.queries, batches)
    return IngestStream("ingest_burst", interleave_event_streams(per_tenant),
                        per_tenant)


@ingest_scenario("bulk_load")
def bulk_load(col_lo: np.ndarray, col_hi: np.ndarray, num_tenants: int = 3,
              queries_per_tenant: int = 1500, seed: int = 0,
              load_rows: int = 600,
              load_points: Tuple[float, ...] = (0.2, 0.5, 0.9),
              ) -> IngestStream:
    """A few large loads at fixed points — the last one near the end of
    the stream, where eagerly reclustering can never pay for itself (the
    case that separates debt-aware from always-recluster)."""
    per_tenant: Dict[str, List[object]] = {}
    for t, rng in enumerate(_scenario_rngs(seed, num_tenants)):
        tmpls = make_templates(1, col_lo.shape[0], rng)
        stream = _stream_from_plan([(tmpls[0], queries_per_tenant)], tmpls,
                                   col_lo, col_hi, rng)
        batches: Dict[int, List[IngestBatch]] = {}
        for frac in load_points:
            k = min(int(queries_per_tenant * frac), queries_per_tenant - 1)
            batches.setdefault(k, []).append(
                _sample_batch(rng, col_lo, col_hi, load_rows))
        per_tenant[f"t{t}"] = _weave(stream.queries, batches)
    return IngestStream("bulk_load", interleave_event_streams(per_tenant),
                        per_tenant)


def queried_column_histogram(queries: Sequence[Query],
                             num_columns: int) -> np.ndarray:
    """How often each column appears with a finite predicate -- used by the
    workload-aware Z-order generator (top-k most-queried columns)."""
    hist = np.zeros(num_columns, dtype=np.int64)
    for q in queries:
        finite = np.isfinite(q.lo) | np.isfinite(q.hi)
        hist += finite.astype(np.int64)
    return hist
