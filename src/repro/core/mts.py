"""D-UMTS: the dynamic uniform Metrical Task System at the heart of OREO.

Implements Algorithms 1-4 of the paper:

* Per-state counters accumulate service costs c(s, q) for every *active* state.
* A state becomes inactive ("full") once its counter reaches alpha.
* When the current state goes full, jump to a uniformly random (or
  predictor-biased, §IV-C) active state, paying movement cost alpha.
* When no active state remains, a new *phase* starts: all counters reset, and
  state additions deferred mid-phase become visible (Algorithm 4).
* Mid-phase deletion sets the deleted state's counter to alpha; deleting the
  current state forces an immediate jump.

The "stay at phase start" optimization (§IV-A, last paragraph) keeps the
current state across a phase boundary instead of re-randomizing -- the paper
notes this does not change the asymptotic competitive ratio but measurably
cuts reorganization cost.

Competitive ratio: 2*H(|S_max|) (Theorem IV.1), predictor-improved via
Theorem IV.2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

# A transition distribution builder: maps {state_id: weight in [0,1]} of the
# *active* states to a probability vector over those states (same key order).
TransitionFn = Callable[[Dict[int, float]], Dict[int, float]]


def uniform_transition(weights: Dict[int, float]) -> Dict[int, float]:
    n = len(weights)
    return {s: 1.0 / n for s in weights}


@dataclasses.dataclass
class MTSEvent:
    """One reorganization decision (state switch)."""
    query_idx: int
    from_state: int
    to_state: int
    reason: str            # "counter_full" | "state_deleted" | "phase_reset"


class DynamicUMTS:
    """Online decision maker over a dynamic state space (Algorithm 4).

    Usage: call :meth:`observe` once per query with the service-cost map of
    *all currently known* states; call :meth:`add_state` / :meth:`remove_state`
    for state-management queries at any point.  ``current_state`` is the state
    the system is in *before* servicing the next query.
    """

    def __init__(self, alpha: float, initial_states: List[int],
                 seed: int = 0,
                 transition_fn: Optional[TransitionFn] = None,
                 stay_on_phase_start: bool = True,
                 midphase_admission: str = "median"):
        """``midphase_admission``: how state additions mid-phase are handled.

        * ``"defer"``  -- Algorithm 4 verbatim: the new state only becomes
          available at the next phase.
        * ``"median"`` -- §IV-C optimization: the state joins the current
          phase immediately, its counter initialized to the median of the
          phase costs incurred so far by existing active states.
        """
        if alpha <= 1:
            raise ValueError("alpha must exceed 1 (reorg costlier than scan)")
        if not initial_states:
            raise ValueError("need at least one initial state")
        if midphase_admission not in ("defer", "median"):
            raise ValueError(f"bad midphase_admission: {midphase_admission}")
        self.alpha = float(alpha)
        self.rng = np.random.default_rng(seed)
        self.transition_fn = transition_fn or uniform_transition
        self.stay_on_phase_start = stay_on_phase_start
        self.midphase_admission = midphase_admission

        self.states: set[int] = set(initial_states)
        self.counters: Dict[int, float] = {s: 0.0 for s in initial_states}
        self.active: set[int] = set(initial_states)
        self.pending_additions: set[int] = set()
        self.current_state: int = int(self.rng.choice(sorted(self.states)))

        self.query_idx = 0
        self.phase = 0
        self.max_state_space = len(self.states)
        self.events: List[MTSEvent] = []
        self.history: List[int] = [self.current_state]
        # Per-phase bookkeeping for predictors: per-state (cost sum, #queries
        # observed while active) -> last phase's *average* cost per query,
        # whose complement is the paper's "average fraction of data skipped".
        self.last_phase_avg_costs: Dict[int, float] = {}
        self._phase_costs: Dict[int, float] = {s: 0.0 for s in initial_states}
        self._phase_counts: Dict[int, int] = {s: 0 for s in initial_states}

    # ------------------------------------------------------------------
    # State-management queries (the D in D-UMTS)
    # ------------------------------------------------------------------
    def add_state(self, state_id: int,
                  admission: Optional[str] = None) -> None:
        """Add a state (Algorithm 4, line 12).

        ``defer`` mode parks it until the next phase; ``median`` mode (§IV-C)
        admits it into the running phase with a median-initialized counter.
        ``admission`` overrides the instance-wide mode for this one state —
        predictive growers defer their speculative states to the next phase
        (a fresh state is a preferred jump target, so mid-phase admission
        would pull exploratory jumps toward a layout built for a regime
        that hasn't arrived yet) while manager-driven additions keep the
        configured behavior.
        """
        if state_id in self.states or state_id in self.pending_additions:
            return
        if (admission or self.midphase_admission) == "defer":
            self.pending_additions.add(state_id)
        else:
            active_costs = [self.counters[s] for s in self.active]
            init = float(np.median(active_costs)) if active_costs else 0.0
            self.states.add(state_id)
            self.counters[state_id] = init
            self._phase_costs[state_id] = init
            self._phase_counts.setdefault(state_id, 0)
            if init < self.alpha:
                self.active.add(state_id)
        self.max_state_space = max(
            self.max_state_space, len(self.states) + len(self.pending_additions))

    def remove_state(self, state_id: int) -> None:
        """Deletion marks the counter full; deleting the current state forces
        a jump (Algorithm 4, lines 5-11)."""
        self.pending_additions.discard(state_id)
        if state_id not in self.states:
            return
        if len(self.states) == 1:
            raise ValueError("cannot remove the last remaining state")
        self.states.discard(state_id)
        self.active.discard(state_id)
        self.counters[state_id] = self.alpha
        if not self.active:
            self._reset_phase(reason="state_deleted")
        if state_id == self.current_state:
            self._jump(reason="state_deleted")

    def force_move(self, state_id: int, reason: str = "preposition") -> None:
        """Deterministically move the decision maker to an active state.

        The hook behind predictive pre-positioning
        (:class:`repro.forecast.policy.ForecastPolicy`): the caller pays the
        usual movement cost α for the emitted event; counters, phases and
        the rng stream are untouched, so a wrapper that never calls this is
        bitwise indistinguishable from the bare D-UMTS.  Moving to the
        current state is a no-op (no event, nothing charged).
        """
        if state_id not in self.active:
            raise ValueError(f"cannot force-move to inactive state "
                             f"{state_id} (active: {sorted(self.active)})")
        if state_id == self.current_state:
            return
        self.events.append(MTSEvent(self.query_idx, self.current_state,
                                    state_id, reason))
        self.current_state = state_id

    # ------------------------------------------------------------------
    # Query processing
    # ------------------------------------------------------------------
    def observe(self, costs: Dict[int, float]) -> int:
        """Process one query given service costs for (at least) all active
        states.  Returns the state the system is in while servicing the query
        (counters update first, as in Algorithm 3 -- the returned state is the
        state *after* any forced transitions for this query)."""
        for s in list(self.active):
            c = float(costs[s])
            if not (0.0 <= c <= 1.0 + 1e-9):
                raise ValueError(f"cost out of [0,1]: state {s} -> {c}")
            self.counters[s] += c
            self._phase_costs[s] = self._phase_costs.get(s, 0.0) + c
            self._phase_counts[s] = self._phase_counts.get(s, 0) + 1
        self.active = {s for s in self.active if self.counters[s] < self.alpha}
        if self.current_state not in self.active:
            if not self.active:
                self._reset_phase(reason="phase_reset")
                if not self.stay_on_phase_start:
                    self._jump(reason="phase_reset")
            else:
                self._jump(reason="counter_full")
        self.query_idx += 1
        self.history.append(self.current_state)
        return self.current_state

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _reset_phase(self, reason: str) -> None:
        self.states |= self.pending_additions
        self.pending_additions.clear()
        self.last_phase_avg_costs = {
            s: self._phase_costs[s] / max(self._phase_counts.get(s, 0), 1)
            for s in self._phase_costs if self._phase_counts.get(s, 0) > 0
        }
        self._phase_costs = {s: 0.0 for s in self.states}
        self._phase_counts = {s: 0 for s in self.states}
        self.counters = {s: 0.0 for s in self.states}
        self.active = set(self.states)
        self.phase += 1
        self.max_state_space = max(self.max_state_space, len(self.states))

    def _jump(self, reason: str) -> None:
        # Weight = average fraction of data skipped in the last phase
        # (paper §IV-C); states unseen last phase (freshly generated from the
        # current window) get the optimistic weight 1.
        candidates = {
            s: 1.0 - min(self.last_phase_avg_costs.get(s, 0.0), 1.0)
            for s in self.active
        }
        probs = self.transition_fn(candidates)
        keys = sorted(probs)
        p = np.array([max(probs[s], 0.0) for s in keys], dtype=np.float64)
        total = p.sum()
        p = p / total if total > 0 else np.full(len(keys), 1.0 / len(keys))
        new_state = int(self.rng.choice(keys, p=p))
        self.events.append(MTSEvent(self.query_idx, self.current_state,
                                    new_state, reason))
        self.current_state = new_state

    # ------------------------------------------------------------------
    # Reporting
    # ------------------------------------------------------------------
    @property
    def num_moves(self) -> int:
        return len(self.events)

    def competitive_bound(self) -> float:
        """2*H(|S_max|) from Theorem IV.1."""
        n = max(self.max_state_space, 1)
        return 2.0 * sum(1.0 / i for i in range(1, n + 1))


def harmonic(n: int) -> float:
    return sum(1.0 / i for i in range(1, n + 1))


def theorem_iv1_bound(s_max: int) -> float:
    return 2.0 * harmonic(max(s_max, 1))


def theorem_iv2_bound(n: int, beta: float) -> float:
    """O(log_{1/(1-beta)} n): expected transitions with a beta-good predictor."""
    if not 0.0 < beta < 1.0:
        raise ValueError("beta in (0,1)")
    return math.log(max(n, 2)) / math.log(1.0 / (1.0 - beta))
