"""Methods of comparison (paper §VI-A3 and §VI-C).

Online (no workload knowledge, same candidate stream as OREO):
  * Greedy -- switches to any freshly generated layout that beats the current
    one on the sliding window, ignoring reorganization cost.
  * Regret -- switches only once the *cumulative* query-cost saving of a
    candidate over the current layout exceeds alpha (TASM-style).

Offline (workload knowledge):
  * Static -- one layout optimized for the entire workload, never switches.
  * MTS-Optimal -- fixed precomputed state space (best layout per template) +
    OREO's D-UMTS switching.
  * Offline-Optimal -- sees the whole stream; switches to each template's best
    layout exactly at template boundaries (lower bound for online methods).

Every method runs through the shared :class:`repro.engine.LayoutEngine` loop
as a pluggable policy (:mod:`repro.engine.policies`); the ``run_*`` functions
below are thin compatibility wrappers composing policy + in-memory backend.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from . import layout_manager as lm
from . import layouts, oreo, workload as wl


def _run(policy, data: np.ndarray, stream: wl.WorkloadStream,
         name: str) -> oreo.RunResult:
    from repro import engine as _engine   # deferred: engine builds on core
    return _engine.LayoutEngine(policy, _engine.InMemoryBackend(data)).run(
        stream, name=name)


# ---------------------------------------------------------------------------
# Static
# ---------------------------------------------------------------------------

def run_static(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, alpha: float,
               target_partitions: int = 32,
               name: str = "Static") -> oreo.RunResult:
    from repro import engine as _engine
    policy = _engine.StaticPolicy(data, stream, generator, alpha,
                                  target_partitions=target_partitions)
    return _run(policy, data, stream, name)


# ---------------------------------------------------------------------------
# Greedy / Regret share OREO's candidate generation cadence
# ---------------------------------------------------------------------------

def run_greedy(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, initial_layout: layouts.Layout,
               alpha: float, mgr_cfg: Optional[lm.LayoutManagerConfig] = None,
               name: str = "Greedy") -> oreo.RunResult:
    from repro import engine as _engine
    policy = _engine.GreedyPolicy(data, initial_layout, generator, alpha,
                                  mgr_cfg=mgr_cfg)
    return _run(policy, data, stream, name)


def run_regret(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, initial_layout: layouts.Layout,
               alpha: float, mgr_cfg: Optional[lm.LayoutManagerConfig] = None,
               max_candidates: int = 8,
               name: str = "Regret") -> oreo.RunResult:
    """Switch when cumulative saving vs. the current layout exceeds alpha."""
    from repro import engine as _engine
    policy = _engine.RegretPolicy(data, initial_layout, generator, alpha,
                                  mgr_cfg=mgr_cfg,
                                  max_candidates=max_candidates)
    return _run(policy, data, stream, name)


# ---------------------------------------------------------------------------
# Template-aware oracles (§VI-C)
# ---------------------------------------------------------------------------

def per_template_layouts(data: np.ndarray, stream: wl.WorkloadStream,
                         generator: lm.GeneratorFn, target_partitions: int,
                         queries_per_template: int = 200
                         ) -> Dict[int, layouts.Layout]:
    """Best layout per query template, built from that template's queries."""
    by_template: Dict[int, List[wl.Query]] = {}
    for q in stream.queries:
        by_template.setdefault(q.template_id, []).append(q)
    out: Dict[int, layouts.Layout] = {}
    for tid, qs in sorted(by_template.items()):
        out[tid] = generator(tid, data, qs[:queries_per_template],
                             target_partitions)
        out[tid].materialize(data)
    return out


def run_mts_optimal(data: np.ndarray, stream: wl.WorkloadStream,
                    generator: lm.GeneratorFn, alpha: float,
                    target_partitions: int = 32, gamma: float = 1.0,
                    seed: int = 0,
                    name: str = "MTS Optimal") -> oreo.RunResult:
    """Fixed precomputed state space + our MTS switching (no dynamic states)."""
    from repro import engine as _engine
    policy = _engine.MTSOptimalPolicy(data, stream, generator, alpha,
                                      target_partitions=target_partitions,
                                      gamma=gamma, seed=seed)
    return _run(policy, data, stream, name)


def run_offline_optimal(data: np.ndarray, stream: wl.WorkloadStream,
                        generator: lm.GeneratorFn, alpha: float,
                        target_partitions: int = 32,
                        name: str = "Offline Optimal") -> oreo.RunResult:
    """Knows the whole stream: per-template layout, switch at boundaries."""
    from repro import engine as _engine
    policy = _engine.OfflineOptimalPolicy(data, stream, generator, alpha,
                                          target_partitions=target_partitions)
    return _run(policy, data, stream, name)
