"""Methods of comparison (paper §VI-A3 and §VI-C).

Online (no workload knowledge, same candidate stream as OREO):
  * Greedy -- switches to any freshly generated layout that beats the current
    one on the sliding window, ignoring reorganization cost.
  * Regret -- switches only once the *cumulative* query-cost saving of a
    candidate over the current layout exceeds alpha (TASM-style).

Offline (workload knowledge):
  * Static -- one layout optimized for the entire workload, never switches.
  * MTS-Optimal -- fixed precomputed state space (best layout per template) +
    OREO's D-UMTS switching.
  * Offline-Optimal -- sees the whole stream; switches to each template's best
    layout exactly at template boundaries (lower bound for online methods).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from . import cost_model as cm
from . import layout_manager as lm
from . import layouts, mts, oreo, predictors, sampling, workload as wl


# ---------------------------------------------------------------------------
# Static
# ---------------------------------------------------------------------------

def run_static(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, alpha: float,
               target_partitions: int = 32,
               name: str = "Static") -> oreo.RunResult:
    layout = generator(0, data, stream.queries, target_partitions)
    meta = layout.materialize(data)
    q_lo, q_hi = wl.stack_queries(stream.queries)
    costs = layouts.eval_cost(meta, q_lo, q_hi)
    return oreo.RunResult(name=name, alpha=alpha, query_costs=costs,
                          reorg_indices=[], state_seq=np.zeros(len(stream),
                                                               dtype=np.int64))


# ---------------------------------------------------------------------------
# Greedy / Regret share OREO's candidate generation cadence
# ---------------------------------------------------------------------------

def run_greedy(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, initial_layout: layouts.Layout,
               alpha: float, mgr_cfg: Optional[lm.LayoutManagerConfig] = None,
               name: str = "Greedy") -> oreo.RunResult:
    cfg = mgr_cfg or lm.LayoutManagerConfig()
    window: sampling.SlidingWindow[wl.Query] = sampling.SlidingWindow(
        cfg.window_size)
    current = initial_layout
    current.materialize(data)
    next_id = initial_layout.layout_id + 1
    query_costs, reorg_indices, state_seq = [], [], []
    for i, q in enumerate(stream):
        window.add(q)
        if (i + 1) % cfg.gen_every == 0 and len(window) >= cfg.window_size // 2:
            qs = window.sample()
            cand = generator(next_id, data, qs, cfg.target_partitions)
            next_id += 1
            w_lo, w_hi = wl.stack_queries(qs)
            cur_cost = layouts.eval_cost(current.meta, w_lo, w_hi).mean()
            cand_cost = layouts.eval_cost(cand.meta, w_lo, w_hi).mean()
            if cand_cost < cur_cost:
                current = cand
                current.materialize(data)
                reorg_indices.append(i)
        query_costs.append(
            float(layouts.eval_cost(current.serving_meta(), q.lo, q.hi)))
        state_seq.append(current.layout_id)
    return oreo.RunResult(name=name, alpha=alpha,
                          query_costs=np.asarray(query_costs),
                          reorg_indices=reorg_indices,
                          state_seq=np.asarray(state_seq))


def run_regret(data: np.ndarray, stream: wl.WorkloadStream,
               generator: lm.GeneratorFn, initial_layout: layouts.Layout,
               alpha: float, mgr_cfg: Optional[lm.LayoutManagerConfig] = None,
               max_candidates: int = 8,
               name: str = "Regret") -> oreo.RunResult:
    """Switch when cumulative saving vs. the current layout exceeds alpha."""
    cfg = mgr_cfg or lm.LayoutManagerConfig()
    model = cm.CostModel(alpha=alpha)
    window: sampling.SlidingWindow[wl.Query] = sampling.SlidingWindow(
        cfg.window_size)
    current = initial_layout
    current.materialize(data)
    next_id = initial_layout.layout_id + 1
    candidates: Dict[int, layouts.Layout] = {}
    cum_saving: Dict[int, float] = {}
    query_costs, reorg_indices, state_seq = [], [], []
    for i, q in enumerate(stream):
        window.add(q)
        if (i + 1) % cfg.gen_every == 0 and len(window) >= cfg.window_size // 2:
            cand = generator(next_id, data, window.sample(),
                             cfg.target_partitions)
            candidates[next_id] = cand
            cum_saving[next_id] = 0.0
            next_id += 1
            if len(candidates) > max_candidates:   # bound tracked candidates
                oldest = min(candidates)
                del candidates[oldest]
                del cum_saving[oldest]
        cur_c = model.query_cost(current, q)        # estimate, for decisions
        for sid, lay in candidates.items():
            cum_saving[sid] += cur_c - model.query_cost(lay, q)
        if cum_saving:
            best = max(cum_saving, key=cum_saving.get)
            if cum_saving[best] > alpha:
                current = candidates.pop(best)
                current.materialize(data)
                cum_saving = {sid: 0.0 for sid in candidates}
                reorg_indices.append(i)
        query_costs.append(
            float(layouts.eval_cost(current.serving_meta(), q.lo, q.hi)))
        state_seq.append(current.layout_id)
    return oreo.RunResult(name=name, alpha=alpha,
                          query_costs=np.asarray(query_costs),
                          reorg_indices=reorg_indices,
                          state_seq=np.asarray(state_seq))


# ---------------------------------------------------------------------------
# Template-aware oracles (§VI-C)
# ---------------------------------------------------------------------------

def per_template_layouts(data: np.ndarray, stream: wl.WorkloadStream,
                         generator: lm.GeneratorFn, target_partitions: int,
                         queries_per_template: int = 200
                         ) -> Dict[int, layouts.Layout]:
    """Best layout per query template, built from that template's queries."""
    by_template: Dict[int, List[wl.Query]] = {}
    for q in stream.queries:
        by_template.setdefault(q.template_id, []).append(q)
    out: Dict[int, layouts.Layout] = {}
    for tid, qs in sorted(by_template.items()):
        out[tid] = generator(tid, data, qs[:queries_per_template],
                             target_partitions)
        out[tid].materialize(data)
    return out


def run_mts_optimal(data: np.ndarray, stream: wl.WorkloadStream,
                    generator: lm.GeneratorFn, alpha: float,
                    target_partitions: int = 32, gamma: float = 1.0,
                    seed: int = 0,
                    name: str = "MTS Optimal") -> oreo.RunResult:
    """Fixed precomputed state space + our MTS switching (no dynamic states)."""
    per_template = per_template_layouts(data, stream, generator,
                                        target_partitions)
    store = {lay.layout_id: lay for lay in per_template.values()}
    model = cm.CostModel(alpha=alpha)
    dumts = mts.DynamicUMTS(
        alpha=alpha, initial_states=sorted(store), seed=seed,
        transition_fn=predictors.gamma_biased_transition(gamma))
    query_costs, reorg_indices, state_seq = [], [], []
    for i, q in enumerate(stream):
        costs = {sid: model.query_cost(lay, q) for sid, lay in store.items()}
        prev = dumts.num_moves
        state = dumts.observe(costs)
        if dumts.num_moves > prev:
            reorg_indices.append(i)
        query_costs.append(
            float(layouts.eval_cost(store[state].serving_meta(), q.lo, q.hi)))
        state_seq.append(state)
    return oreo.RunResult(name=name, alpha=alpha,
                          query_costs=np.asarray(query_costs),
                          reorg_indices=reorg_indices,
                          state_seq=np.asarray(state_seq))


def run_offline_optimal(data: np.ndarray, stream: wl.WorkloadStream,
                        generator: lm.GeneratorFn, alpha: float,
                        target_partitions: int = 32,
                        name: str = "Offline Optimal") -> oreo.RunResult:
    """Knows the whole stream: per-template layout, switch at boundaries."""
    per_template = per_template_layouts(data, stream, generator,
                                        target_partitions)
    model = cm.CostModel(alpha=alpha)
    query_costs = np.zeros(len(stream))
    reorg_indices: List[int] = []
    state_seq = np.zeros(len(stream), dtype=np.int64)
    prev_tid = None
    for start, end, tid in stream.segments:
        lay = per_template[tid]
        qs = stream.queries[start:end]
        if qs:
            q_lo, q_hi = wl.stack_queries(qs)
            query_costs[start:end] = layouts.eval_cost(lay.serving_meta(),
                                                       q_lo, q_hi)
        state_seq[start:end] = lay.layout_id
        if prev_tid is not None and tid != prev_tid:
            reorg_indices.append(start)
        prev_tid = tid
    return oreo.RunResult(name=name, alpha=alpha, query_costs=query_costs,
                          reorg_indices=reorg_indices, state_seq=state_seq)
