"""Cost model: service cost c(s, q) in [0, 1] and reorganization cost alpha.

Matches the paper (§III-A): the service cost of a query is the fraction of
data records accessed under the layout (a reliable proxy for query time); the
reorganization cost is ``alpha``, the expected ratio of reorganization compute
time to a full-table-scan query.  alpha is measured empirically (Table I; our
host measurement lives in ``benchmarks/table1_alpha.py``) -- 60-100x is the
paper's band; 80 its default.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import layouts, workload as wl


@dataclasses.dataclass
class CostModel:
    alpha: float = 80.0
    full_scan_seconds: float = 1.0   # converts logical cost -> wall-clock

    def query_cost(self, layout: layouts.Layout, query: wl.Query) -> float:
        return float(layouts.eval_cost(layout.meta, query.lo, query.hi))

    def query_costs(self, layout: layouts.Layout, q_lo: np.ndarray,
                    q_hi: np.ndarray) -> np.ndarray:
        return np.atleast_1d(layouts.eval_cost(layout.meta, q_lo, q_hi))

    @property
    def reorg_cost(self) -> float:
        return self.alpha

    def to_seconds(self, logical_cost: float) -> float:
        return logical_cost * self.full_scan_seconds
