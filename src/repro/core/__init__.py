"""OREO core: online data-layout reorganization with worst-case guarantees.

Public API of the paper's contribution:

* :class:`~repro.core.mts.DynamicUMTS` -- D-UMTS decision maker (Alg. 1-4).
* :class:`~repro.core.layout_manager.LayoutManager` -- candidate generation +
  ε-admission (Alg. 5).
* :class:`~repro.engine.LayoutEngine` -- the stepwise online loop (Fig. 1),
  in :mod:`repro.engine` with pluggable policies and storage backends
  (:class:`~repro.core.oreo.OreoRunner` remains as a deprecated alias).
* Layout generators: Qd-tree, Z-order, default (arrival-order).
* Baselines: Static / Greedy / Regret / MTS-Optimal / Offline-Optimal, each
  a Policy over the shared engine loop.
"""
from repro.core import baselines, cost_model, layout_manager, layouts
from repro.core import mts, oreo, predictors, qdtree, sampling, workload, zorder
from repro.core.cost_model import CostModel
from repro.core.layout_manager import LayoutManager, LayoutManagerConfig, make_generator
from repro.core.layouts import (Layout, PartitionMetadata, cost_vector,
                                eval_cost, eval_cost_states, eval_skipped,
                                layout_distance, metadata_from_assignment,
                                partitions_scanned)
from repro.core.mts import DynamicUMTS, theorem_iv1_bound, theorem_iv2_bound
from repro.core.oreo import OreoConfig, OreoRunner, RunResult
from repro.core.qdtree import build_default_layout, build_qdtree_layout
from repro.core.workload import (DRIFT_SCENARIOS, INGEST_SCENARIOS, Event,
                                 FleetStream, IngestBatch, IngestEvent,
                                 IngestStream, Query, QueryEvent,
                                 QueryTemplate, WorkloadStream, as_event,
                                 generate_workload, interleave_streams,
                                 make_drift_scenario, make_ingest_scenario,
                                 make_templates, stack_queries)
from repro.core.zorder import build_zorder_layout

__all__ = [
    "CostModel", "DRIFT_SCENARIOS", "DynamicUMTS", "Event", "FleetStream",
    "INGEST_SCENARIOS", "IngestBatch", "IngestEvent", "IngestStream",
    "Layout", "LayoutManager",
    "LayoutManagerConfig", "OreoConfig", "OreoRunner", "PartitionMetadata",
    "Query", "QueryEvent", "QueryTemplate", "RunResult", "WorkloadStream",
    "as_event",
    "build_default_layout", "build_qdtree_layout", "build_zorder_layout",
    "cost_vector", "eval_cost", "eval_cost_states", "eval_skipped",
    "generate_workload", "interleave_streams",
    "layout_distance", "make_drift_scenario", "make_generator",
    "make_ingest_scenario", "make_templates",
    "metadata_from_assignment", "partitions_scanned", "stack_queries",
    "theorem_iv1_bound", "theorem_iv2_bound",
    "baselines", "cost_model", "layout_manager", "layouts", "mts", "oreo",
    "predictors", "qdtree", "sampling", "workload", "zorder",
]
