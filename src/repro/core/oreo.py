"""OREO orchestrator: REORGANIZER (D-UMTS) x LAYOUT MANAGER over a stream.

Implements the full online loop of Figure 1, including the paper's
Δ-delay semantics for background reorganization (§VI-D5): the reorganization
cost is charged as soon as the decision is made, but queries keep running on
the *old* layout for Δ more queries before the swap takes effect.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from . import cost_model as cm
from . import layout_manager as lm
from . import layouts, mts, predictors, workload as wl


@dataclasses.dataclass
class RunResult:
    """Per-query trace of an online (or offline) reorganization run."""

    name: str
    alpha: float
    query_costs: np.ndarray                 # (T,) fraction of data accessed
    reorg_indices: List[int]                # query idx at which reorgs charged
    state_seq: np.ndarray                   # (T,) decision state per query
    info: dict = dataclasses.field(default_factory=dict)

    @property
    def total_query_cost(self) -> float:
        return float(self.query_costs.sum())

    @property
    def total_reorg_cost(self) -> float:
        return float(len(self.reorg_indices) * self.alpha)

    @property
    def total_cost(self) -> float:
        return self.total_query_cost + self.total_reorg_cost

    @property
    def num_reorgs(self) -> int:
        return len(self.reorg_indices)

    def cumulative(self) -> np.ndarray:
        cum = np.cumsum(self.query_costs)
        for i in self.reorg_indices:
            cum[i:] += self.alpha
        return cum

    def summary(self) -> str:
        return (f"{self.name}: total={self.total_cost:.1f} "
                f"(query={self.total_query_cost:.1f}, "
                f"reorg={self.total_reorg_cost:.1f}, "
                f"moves={self.num_reorgs})")


@dataclasses.dataclass
class OreoConfig:
    alpha: float = 80.0
    gamma: float = 1.0               # transition-bias exponent (0 = uniform)
    delta: int = 0                   # background-reorg delay in queries
    seed: int = 0
    stay_on_phase_start: bool = True
    manager: lm.LayoutManagerConfig = dataclasses.field(
        default_factory=lm.LayoutManagerConfig)


class OreoRunner:
    """End-to-end online run of OREO on a (data, stream) pair."""

    def __init__(self, data: np.ndarray, initial_layout: layouts.Layout,
                 generator: lm.GeneratorFn,
                 config: Optional[OreoConfig] = None):
        self.config = config or OreoConfig()
        self.data = data
        self.manager = lm.LayoutManager(data, generator, initial_layout,
                                        self.config.manager,
                                        seed=self.config.seed)
        self.dumts = mts.DynamicUMTS(
            alpha=self.config.alpha,
            initial_states=[initial_layout.layout_id],
            seed=self.config.seed,
            transition_fn=predictors.gamma_biased_transition(self.config.gamma),
            stay_on_phase_start=self.config.stay_on_phase_start,
        )
        self.cost_model = cm.CostModel(alpha=self.config.alpha)

    def run(self, stream: wl.WorkloadStream, name: str = "OREO") -> RunResult:
        delta = self.config.delta
        query_costs: List[float] = []
        reorg_indices: List[int] = []
        state_seq: List[int] = []
        # The physically materialized layout serving queries.  Decisions use
        # sample-estimated metadata; *charged* query costs use the exact
        # metadata of the materialized table.
        physical = self.manager.store[self.dumts.current_state]
        physical.materialize(self.data)
        pending_swaps: List[tuple[int, int]] = []       # (effective_idx, state)

        for i, q in enumerate(stream):
            added, removed = self.manager.on_query(q, self.dumts.current_state)
            for sid in added:
                self.dumts.add_state(sid)
            for sid in removed:
                self.dumts.remove_state(sid)

            # Service-cost estimates for all states known to the decision
            # maker -- metadata-only (never touches rows).
            costs: Dict[int, float] = {}
            for sid in set(self.dumts.states) | set(self.dumts.pending_additions):
                if sid in self.manager.store:
                    costs[sid] = self.cost_model.query_cost(
                        self.manager.store[sid], q)
                else:
                    costs[sid] = 1.0
            prev_moves = self.dumts.num_moves
            decision_state = self.dumts.observe(costs)
            if self.dumts.num_moves > prev_moves:
                # Reorg cost charged at decision time (paper §VI-D5).
                reorg_indices.append(i)
                pending_swaps.append((i + delta, decision_state))

            # Apply any swap whose background reorganization has finished.
            while pending_swaps and pending_swaps[0][0] <= i:
                _, sid = pending_swaps.pop(0)
                if sid in self.manager.store:
                    physical = self.manager.store[sid]
                    physical.materialize(self.data)
            qc = float(layouts.eval_cost(physical.serving_meta(), q.lo, q.hi))
            query_costs.append(qc)
            state_seq.append(decision_state)

        return RunResult(
            name=name,
            alpha=self.config.alpha,
            query_costs=np.asarray(query_costs),
            reorg_indices=reorg_indices,
            state_seq=np.asarray(state_seq),
            info={
                "phases": self.dumts.phase,
                "max_state_space": self.dumts.max_state_space,
                "competitive_bound": self.dumts.competitive_bound(),
                "candidates_generated": self.manager.num_generated,
                "candidates_admitted": self.manager.num_admitted,
            },
        )
