"""OREO run configuration, result traces, and the deprecated batch runner.

The online loop of Figure 1 — including the paper's Δ-delay semantics for
background reorganization (§VI-D5) — now lives in :mod:`repro.engine`
(:class:`~repro.engine.LayoutEngine` + :class:`~repro.engine.OreoPolicy`).
This module keeps :class:`OreoConfig` and :class:`RunResult`, plus
:class:`OreoRunner` as a deprecated batch alias over the engine.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

import numpy as np

from . import layout_manager as lm
from . import layouts, mts, workload as wl


@dataclasses.dataclass
class RunResult:
    """Per-query trace of an online (or offline) reorganization run."""

    name: str
    alpha: float
    query_costs: np.ndarray                 # (T,) fraction of data accessed
    reorg_indices: List[int]                # query idx at which reorgs charged
    state_seq: np.ndarray                   # (T,) decision state per query
    info: dict = dataclasses.field(default_factory=dict)
    # Wall-clock breakdown of the run, aggregated by the engine over every
    # query stepped: decision layer / physical reorganization (prepare +
    # swap) / serving.  Zero for traces not produced by an engine.
    decide_seconds: float = 0.0
    reorg_seconds: float = 0.0
    serve_seconds: float = 0.0

    @property
    def wall_seconds(self) -> float:
        return self.decide_seconds + self.reorg_seconds + self.serve_seconds

    @property
    def total_query_cost(self) -> float:
        return float(self.query_costs.sum())

    @property
    def total_reorg_cost(self) -> float:
        return float(len(self.reorg_indices) * self.alpha)

    @property
    def total_cost(self) -> float:
        return self.total_query_cost + self.total_reorg_cost

    @property
    def num_reorgs(self) -> int:
        return len(self.reorg_indices)

    def cumulative(self) -> np.ndarray:
        """Running total (query + reorg) cost after each query.

        Each reorganization charges ``alpha`` exactly once, at its reorg
        index (duplicate indices accumulate), so ``cumulative()[-1]`` always
        equals :attr:`total_cost` and repeated calls are stable.
        """
        per_query = self.query_costs.astype(np.float64, copy=True)
        if self.reorg_indices:
            np.add.at(per_query,
                      np.asarray(self.reorg_indices, dtype=np.int64),
                      self.alpha)
        return np.cumsum(per_query)

    def summary(self) -> str:
        return (f"{self.name}: total={self.total_cost:.1f} "
                f"(query={self.total_query_cost:.1f}, "
                f"reorg={self.total_reorg_cost:.1f}, "
                f"moves={self.num_reorgs})")


@dataclasses.dataclass
class OreoConfig:
    alpha: float = 80.0
    gamma: float = 1.0               # transition-bias exponent (0 = uniform)
    delta: int = 0                   # background-reorg delay in queries
    seed: int = 0
    stay_on_phase_start: bool = True
    manager: lm.LayoutManagerConfig = dataclasses.field(
        default_factory=lm.LayoutManagerConfig)


class OreoRunner:
    """Deprecated batch alias for the stepwise engine (kept one release).

    The online loop now lives in :mod:`repro.engine`; this shim composes
    ``LayoutEngine(OreoPolicy(...), InMemoryBackend(data))`` and reproduces
    the legacy per-query cost trace bit-for-bit.  Prefer::

        from repro.engine import InMemoryBackend, LayoutEngine, OreoPolicy

        policy = OreoPolicy(data, initial_layout, generator, config)
        engine = LayoutEngine(policy, InMemoryBackend(data),
                              delta=config.delta)
        result = engine.run(stream)
    """

    def __init__(self, data: np.ndarray, initial_layout: layouts.Layout,
                 generator: lm.GeneratorFn,
                 config: Optional[OreoConfig] = None):
        warnings.warn(
            "OreoRunner is deprecated; use repro.engine.LayoutEngine with "
            "OreoPolicy + a StorageBackend instead.",
            DeprecationWarning, stacklevel=2)
        from repro import engine as _engine   # deferred: engine builds on core
        self.config = config or OreoConfig()
        self.data = data
        self.policy = _engine.OreoPolicy(data, initial_layout, generator,
                                         self.config)
        self.backend = _engine.InMemoryBackend(data)
        self.engine = _engine.LayoutEngine(self.policy, self.backend,
                                           delta=self.config.delta)

    @property
    def manager(self) -> lm.LayoutManager:
        return self.policy.manager

    @property
    def dumts(self) -> mts.DynamicUMTS:
        return self.policy.dumts

    def run(self, stream: wl.WorkloadStream, name: str = "OREO") -> RunResult:
        return self.engine.run(stream, name=name)
