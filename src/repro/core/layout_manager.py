"""LAYOUT MANAGER: on-the-fly candidate generation + ε-admission (Alg. 5).

The producer side of the dynamic state space:

* keeps a sliding window of recent queries (and, for ablations, a reservoir or
  both) from which new candidate layouts are generated every ``gen_every``
  queries;
* keeps an R-TBS time-biased reservoir of queries on which candidate layouts
  are compared: a candidate is admitted iff the normalized-L1 distance between
  its cost vector and that of *every* existing state is >= epsilon;
* caps the state space at ``max_states`` by evicting the admitted state most
  similar to the rest (never the current state), issuing a remove-state query.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from . import layouts, qdtree, sampling, workload as wl, zorder

# generate_layout(layout_id, data, window_queries, k) -> Layout
GeneratorFn = Callable[[int, np.ndarray, Sequence[wl.Query], int],
                       layouts.Layout]


class LayoutGenerator:
    """Picklable :data:`GeneratorFn` for a named technique.

    A plain class rather than a closure so policies holding a generator
    (and therefore whole engines) survive pickling — live tenant
    migration across shard processes ships the engine object.
    """

    def __init__(self, technique: str, seed: int = 0):
        if technique not in ("qdtree", "zorder"):
            raise ValueError(f"unknown technique: {technique}")
        self.technique = technique
        self.seed = seed

    def __call__(self, layout_id, data, queries, k):
        if self.technique == "qdtree":
            return qdtree.build_qdtree_layout(layout_id, data, queries, k,
                                              seed=self.seed + layout_id)
        return zorder.build_zorder_layout(layout_id, data, queries, k)


def make_generator(technique: str, seed: int = 0) -> GeneratorFn:
    return LayoutGenerator(technique, seed=seed)


@dataclasses.dataclass
class LayoutManagerConfig:
    window_size: int = 200          # paper default: most recent 200 queries
    gen_every: int = 100            # generate a candidate every N queries
    epsilon: float = 0.08           # paper default admission threshold
    max_states: int = 8             # state-space cap (|S_max| in Thm IV.1)
    rtbs_size: int = 64             # representative query sample size s
    rtbs_lambda: float = 2e-3
    target_partitions: int = 32
    candidate_source: str = "sw"    # "sw" | "rs" | "sw+rs" (Table II ablation)
    rs_size: int = 200


class LayoutManager:
    """Produces state add/remove events consumed by the REORGANIZER."""

    def __init__(self, data: np.ndarray, generator: GeneratorFn,
                 initial_layout: layouts.Layout,
                 config: Optional[LayoutManagerConfig] = None,
                 seed: int = 0):
        self.data = data
        self.generator = generator
        self.config = config or LayoutManagerConfig()
        self.rng = np.random.default_rng(seed)
        self.window: sampling.SlidingWindow[wl.Query] = sampling.SlidingWindow(
            self.config.window_size)
        self.reservoir: sampling.ReservoirSample[wl.Query] = (
            sampling.ReservoirSample(self.config.rs_size, seed=seed + 1))
        self.rtbs: sampling.RTBSample[wl.Query] = sampling.RTBSample(
            self.config.rtbs_size, lam=self.config.rtbs_lambda, seed=seed + 2)
        self.store: Dict[int, layouts.Layout] = {
            initial_layout.layout_id: initial_layout}
        self.next_id = initial_layout.layout_id + 1
        self.queries_seen = 0
        self.num_generated = 0
        self.num_admitted = 0
        # Cost vectors of stored layouts, keyed by the R-TBS sample version:
        # valid until the sample itself changes, so the eviction while-loop
        # and periodic pruning stop recomputing the full |S| x |sample|
        # matrix on every iteration.
        self._cv_cache: Dict[int, np.ndarray] = {}
        self._cv_version = -1
        self._cv_bounds: Optional[tuple] = None

    # ------------------------------------------------------------------
    def _sample_bounds(self) -> Optional[tuple]:
        """Stacked (q_lo, q_hi) of the current R-TBS sample, refreshed (and
        the cost-vector cache dropped) whenever the sample version moves."""
        if self.rtbs.version != self._cv_version:
            self._cv_cache.clear()
            self._cv_version = self.rtbs.version
            qs = self.rtbs.sample()
            self._cv_bounds = wl.stack_queries(qs) if qs else None
        return self._cv_bounds

    def _cost_vectors(self, candidates: Dict[int, layouts.Layout]
                      ) -> Dict[int, np.ndarray]:
        bounds = self._sample_bounds()
        if bounds is None:
            return {i: np.zeros(0) for i in candidates}
        q_lo, q_hi = bounds
        out: Dict[int, np.ndarray] = {}
        for i, lay in candidates.items():
            vec = self._cv_cache.get(i)
            if vec is None:
                vec = layouts.cost_vector(lay.meta, q_lo, q_hi)
                # Only layouts actually admitted to the store are cached:
                # a rejected candidate's id is reused by the next candidate.
                if self.store.get(i) is lay:
                    self._cv_cache[i] = vec
            out[i] = vec
        return out

    def _candidate_queries(self) -> List[List[wl.Query]]:
        src = self.config.candidate_source
        out: List[List[wl.Query]] = []
        if src in ("sw", "sw+rs") and len(self.window):
            out.append(self.window.sample())
        if src in ("rs", "sw+rs") and len(self.reservoir):
            out.append(self.reservoir.sample())
        return out

    # ------------------------------------------------------------------
    def on_query(self, query: wl.Query, current_state: int
                 ) -> tuple[List[int], List[int]]:
        """Observe one query; returns (added_state_ids, removed_state_ids)."""
        self.window.add(query)
        self.reservoir.add(query)
        self.rtbs.add(query)
        self.queries_seen += 1
        added: List[int] = []
        removed: List[int] = []
        if (self.queries_seen % self.config.gen_every != 0
                or len(self.window) < self.config.window_size // 2):
            return added, removed

        for qset in self._candidate_queries():
            cand = self.generator(self.next_id, self.data, qset,
                                  self.config.target_partitions)
            self.num_generated += 1
            if self._admit(cand):
                self.store[cand.layout_id] = cand
                added.append(cand.layout_id)
                self.next_id += 1
                self.num_admitted += 1
                removed.extend(self._maybe_evict(current_state))
        return added, removed

    def _admit(self, cand: layouts.Layout) -> bool:
        """Algorithm 5: admit iff >= epsilon from every existing state."""
        vecs = self._cost_vectors({**self.store, cand.layout_id: cand})
        cv = vecs.pop(cand.layout_id)
        if cv.size == 0:
            return False
        for sid, v in vecs.items():
            if layouts.layout_distance(cv, v) < self.config.epsilon:
                return False
        return True

    def _maybe_evict(self, current_state: int) -> List[int]:
        """Keep |S| <= max_states: evict the non-current state whose cost
        vector is closest to some other state (most redundant)."""
        removed = []
        while len(self.store) > self.config.max_states:
            ids = [i for i in self.store if i != current_state]
            if not ids:
                break
            vecs = self._cost_vectors(self.store)
            best, best_d = None, np.inf
            for i in ids:
                d = min(layouts.layout_distance(vecs[i], vecs[j])
                        for j in self.store if j != i)
                if d < best_d:
                    best, best_d = i, d
            if best is None:
                # Every candidate tied at a non-comparable distance (e.g. an
                # empty R-TBS sample yields degenerate cost vectors): evict
                # the newest non-current state so the loop always progresses.
                best = max(ids)
            del self.store[best]
            self._cv_cache.pop(best, None)
            removed.append(best)
        return removed

    # ------------------------------------------------------------------
    def prune_redundant(self, current_state: int) -> List[int]:
        """Optional periodic pruning (§V-B): drop states that have become
        redundant under the *current* query sample."""
        removed = []
        vecs = self._cost_vectors(self.store)
        ids = sorted(self.store)
        for i in ids:
            if i == current_state or i not in self.store:
                continue
            for j in self.store:
                if j == i:
                    continue
                if layouts.layout_distance(vecs[i], vecs[j]) < self.config.epsilon / 2:
                    del self.store[i]
                    self._cv_cache.pop(i, None)
                    removed.append(i)
                    break
        return removed
