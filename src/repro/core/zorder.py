"""Workload-aware Z-order layout generation (paper §VI-A1).

Picks the top-m most-queried columns in the recent window, quantizes each to
16-bit codes, interleaves bits (Morton order), sorts and splits into k
equal-size partitions.  The bit-interleave hot loop has a Pallas TPU kernel in
``repro.kernels.zorder``; this module is the numpy producer used by the online
simulator (and the kernel's semantics match ``interleave_bits`` here).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from . import layouts, workload as wl

ZBITS = 16  # bits per column in the Morton code


def quantize_columns(values: np.ndarray, col_lo: np.ndarray,
                     col_hi: np.ndarray) -> np.ndarray:
    """Linear-quantize selected columns to ZBITS-bit integer codes."""
    span = np.maximum(col_hi - col_lo, 1e-12)
    q = (values - col_lo) / span
    q = np.clip(q, 0.0, 1.0)
    return (q * ((1 << ZBITS) - 1)).astype(np.uint64)


def interleave_bits(codes: np.ndarray) -> np.ndarray:
    """Morton-interleave (N, m) ZBITS-bit codes into (N,) uint64 keys.

    Bit b of column j lands at position b*m + j, so high bits of all columns
    dominate jointly (standard Z-order).
    """
    n, m = codes.shape
    keys = np.zeros(n, dtype=np.uint64)
    for b in range(ZBITS):
        for j in range(m):
            bit = (codes[:, j] >> np.uint64(b)) & np.uint64(1)
            keys |= bit << np.uint64(b * m + j)
    return keys


class _ZOrderRouter:
    """Z-key quantile routing; a class (not a closure) so layouts — and
    the engines holding them — stay picklable for cross-process tenant
    migration."""

    def __init__(self, zcols, col_lo, col_hi, boundaries, k: int):
        self.zcols = zcols
        self.col_lo = col_lo
        self.col_hi = col_hi
        self.boundaries = boundaries
        self.k = k

    def __call__(self, rows: np.ndarray) -> np.ndarray:
        keys_r = interleave_bits(
            quantize_columns(rows[:, self.zcols], self.col_lo, self.col_hi))
        return np.minimum(
            np.searchsorted(self.boundaries, keys_r, side="right"),
            self.k - 1)


def build_zorder_layout(layout_id: int,
                        data: np.ndarray,
                        queries: Sequence[wl.Query],
                        k: int,
                        num_zcols: int = 3,
                        sample_frac: float = 0.02,
                        min_sample_rows: int = 4096,
                        seed: int = 0,
                        name: Optional[str] = None) -> layouts.Layout:
    """Generate a Z-order layout on the top-``num_zcols`` queried columns.

    Built from a data sample: key-quantile partition boundaries and estimated
    metadata come from the sample; exact metadata is computed only on
    materialization (actual reorganization).
    """
    rng = np.random.default_rng(seed)
    n, c = data.shape
    hist = wl.queried_column_histogram(queries, c)
    if hist.sum() == 0:
        zcols = np.arange(min(num_zcols, c))
    else:
        zcols = np.argsort(-hist, kind="stable")[:num_zcols]
    zcols = np.sort(zcols)

    m = min(max(int(n * sample_frac), min(n, min_sample_rows)), n)
    sample = data[rng.choice(n, size=m, replace=False)]
    sub = sample[:, zcols]
    col_lo = sub.min(axis=0)
    col_hi = sub.max(axis=0)
    keys = interleave_bits(quantize_columns(sub, col_lo, col_hi))
    order = np.argsort(keys, kind="stable")

    # Key-quantile boundaries let `route` assign any row consistently.
    boundaries = keys[order][np.minimum((np.arange(1, k) * m) // k, m - 1)]

    route = _ZOrderRouter(zcols, col_lo, col_hi, boundaries, k)
    meta = layouts.metadata_from_assignment(sample, route(sample), k,
                                            row_scale=n / m)
    return layouts.Layout(
        layout_id=layout_id,
        name=name or f"zorder[{','.join(map(str, zcols.tolist()))}]#{layout_id}",
        technique="zorder",
        meta=meta,
        route=route,
        info={"zcols": zcols.tolist(), "sample_rows": m},
    )
