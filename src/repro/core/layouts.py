"""Data layouts and partition-level metadata.

A *layout* is a mapping from rows of a table to partitions (the paper's BID
column).  OREO never needs the mapping itself at decision time -- only the
per-partition metadata (min/max per column, row counts), which is what
``eval_skipped`` consumes.  This mirrors the paper's design: cost estimation is
metadata-only and never touches row data.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionMetadata:
    """Per-partition zone maps: ``mins``/``maxs`` are (P, C); ``rows`` is (P,)."""

    mins: np.ndarray
    maxs: np.ndarray
    rows: np.ndarray

    def __post_init__(self):
        assert self.mins.shape == self.maxs.shape
        assert self.mins.shape[0] == self.rows.shape[0]

    @property
    def num_partitions(self) -> int:
        return int(self.mins.shape[0])

    @property
    def num_columns(self) -> int:
        return int(self.mins.shape[1])

    @property
    def total_rows(self) -> int:
        return int(self.rows.sum())


def metadata_from_assignment(data: np.ndarray, assignment: np.ndarray,
                             num_partitions: int,
                             row_scale: float = 1.0) -> PartitionMetadata:
    """Compute zone maps for ``data`` (N, C) under partition ``assignment`` (N,).

    ``row_scale`` scales row counts when ``data`` is a sample standing in for
    a larger table (the paper builds layouts and estimates metadata from
    0.1-1% samples; the full table is only touched on reorganization).

    The per-partition min/max reduction runs as one ``np.minimum.reduceat`` /
    ``np.maximum.reduceat`` pair over the sorted row order — no Python loop
    over partitions on the reorganization path.  Empty partitions keep the
    [+inf, -inf] identity bounds and zero rows; rows assigned outside
    ``[0, num_partitions)`` are ignored.
    """
    n, c = data.shape
    mins = np.full((num_partitions, c), np.inf)
    maxs = np.full((num_partitions, c), -np.inf)
    rows = np.zeros(num_partitions, dtype=np.float64)
    order = np.argsort(assignment, kind="stable")
    sorted_assign = assignment[order]
    bounds = np.searchsorted(sorted_assign, np.arange(num_partitions + 1))
    starts, ends = bounds[:-1], bounds[1:]
    nonempty = ends > starts
    if nonempty.any():
        # Rows with in-range assignments, grouped contiguously by partition.
        # reduceat segment i spans [start_i, start_{i+1}) over the non-empty
        # starts, which equals [start_i, end_i) because empty partitions have
        # zero width; the final segment ends exactly at the slice boundary.
        grouped = data[order[bounds[0]:bounds[-1]]]
        seg = starts[nonempty] - bounds[0]
        mins[nonempty] = np.minimum.reduceat(grouped, seg, axis=0)
        maxs[nonempty] = np.maximum.reduceat(grouped, seg, axis=0)
        rows[nonempty] = (ends[nonempty] - starts[nonempty]) * row_scale
    return PartitionMetadata(mins=mins, maxs=maxs, rows=rows)


@dataclasses.dataclass
class Layout:
    """A data layout: an assignment function plus its partition metadata.

    ``route`` maps a (N, C) array of rows to partition ids; it is retained so
    a *reorganization* (full rewrite of the table under this layout) can be
    materialized.  ``meta`` is the *estimated* metadata (built from the data
    sample the generator saw) used for decision making; ``true_meta`` is the
    exact metadata of the materialized table, filled in lazily the first time
    the layout is actually reorganized to (:meth:`materialize`).
    """

    layout_id: int
    name: str
    technique: str                      # "qdtree" | "zorder" | "default" | ...
    meta: PartitionMetadata
    route: Optional[Callable[[np.ndarray], np.ndarray]] = None
    info: dict = dataclasses.field(default_factory=dict)
    true_meta: Optional[PartitionMetadata] = None

    @property
    def num_partitions(self) -> int:
        return self.meta.num_partitions

    def materialize(self, data: np.ndarray) -> PartitionMetadata:
        """Reorganize the full table under this layout; exact zone maps."""
        if self.true_meta is None:
            if self.route is None:
                self.true_meta = self.meta
            else:
                assignment = self.route(data)
                self.true_meta = metadata_from_assignment(
                    data, assignment, self.num_partitions)
        return self.true_meta

    def serving_meta(self) -> PartitionMetadata:
        """Metadata of the physically materialized table (falls back to the
        estimate if never materialized -- e.g. the initial default layout)."""
        return self.true_meta if self.true_meta is not None else self.meta


# ---------------------------------------------------------------------------
# Query cost evaluation ("eval_skipped")
# ---------------------------------------------------------------------------
#
# Every cost path below reduces the scan matrix with the SAME contiguous
# einsum contraction (``scanned_dot``).  numpy's einsum uses one
# sum-of-products inner kernel for the 'p,p->', 'qp,p->q' and 'sp,sp->s'
# signatures on contiguous operands, so single-query, batched-query, and
# batched-state evaluation (including the engine's packed StateMatrix plane)
# are bit-identical by construction — unlike mixing ``@``/BLAS dots, whose
# accumulation order differs from einsum's on some shapes.


def scanned_dot(scanned: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Deterministic ``scanned · rows`` shared by all cost paths.

    ``scanned`` is bool (P,) or (Q, P); ``rows`` is float64 (P,).  Operands
    must be contiguous along P (freshly computed scan matrices always are).
    """
    if scanned.ndim == 1:
        return np.einsum("p,p->", scanned, rows)
    return np.einsum("qp,p->q", scanned, rows)


def partitions_scanned(meta: PartitionMetadata, q_lo: np.ndarray,
                       q_hi: np.ndarray) -> np.ndarray:
    """Which partitions a conjunctive range query must scan.

    ``q_lo``/``q_hi`` are (C,) or (Q, C).  A partition is scanned iff every
    column's [min, max] range overlaps the query's [lo, hi] range.
    Returns bool (P,) or (Q, P).
    """
    lo = np.atleast_2d(q_lo)[:, None, :]       # (Q, 1, C)
    hi = np.atleast_2d(q_hi)[:, None, :]
    overlap = (meta.mins[None] <= hi) & (meta.maxs[None] >= lo)  # (Q, P, C)
    scanned = overlap.all(axis=-1)
    if q_lo.ndim == 1:
        return scanned[0]
    return scanned


def eval_cost(meta: PartitionMetadata, q_lo: np.ndarray,
              q_hi: np.ndarray) -> np.ndarray:
    """Fraction of data records accessed: the paper's service cost c(s, q).

    Returns float (Q,) (or scalar for a single query), each in [0, 1].
    """
    scanned = partitions_scanned(meta, q_lo, q_hi)
    total = max(meta.total_rows, 1)
    cost = scanned_dot(scanned, self_rows(meta)) / total
    return cost


def self_rows(meta: PartitionMetadata) -> np.ndarray:
    return meta.rows.astype(np.float64)


def eval_skipped(meta: PartitionMetadata, q_lo: np.ndarray,
                 q_hi: np.ndarray) -> np.ndarray:
    """Fraction of data records *skipped* (1 - cost)."""
    return 1.0 - eval_cost(meta, q_lo, q_hi)


def cost_vector(meta: PartitionMetadata, q_lo: np.ndarray,
                q_hi: np.ndarray) -> np.ndarray:
    """Cost vector of a layout over a query sample -- used for ε-admission."""
    return np.atleast_1d(eval_cost(meta, q_lo, q_hi))


def layout_distance(cv_a: np.ndarray, cv_b: np.ndarray) -> float:
    """Normalized L1 distance between two cost vectors (paper §V-B).

    Zero-length vectors (an empty query sample) carry no evidence that two
    layouts are similar, so the distance is *infinite*: admission treats the
    pair as distinct-but-unverifiable (callers reject separately) and
    eviction/pruning never merges states on the basis of an empty sample.
    """
    if len(cv_a) == 0 or len(cv_b) == 0:
        return float("inf")
    return float(np.abs(cv_a - cv_b).mean())


def eval_cost_states(metas: Sequence[PartitionMetadata], q_lo: np.ndarray,
                     q_hi: np.ndarray) -> np.ndarray:
    """Service cost of a *single* query under many candidate layouts at once.

    The partition-overlap test — the O(S * P * C) bulk of the work — runs as
    one vectorized comparison over all states (padded to the widest partition
    count; padding rows use [+inf, -inf] bounds and zero rows so they are
    never scanned).  The final per-state dot products intentionally reuse each
    state's exact (P,) arrays so the result is bit-identical to calling
    :func:`eval_cost` on every state individually — the online decision loop
    relies on this when comparing the engine against the legacy runner.

    Returns float (S,), one cost in [0, 1] per state.
    """
    if not metas:
        return np.zeros(0)
    counts = [m.num_partitions for m in metas]
    p_max = max(counts)
    s, c = len(metas), metas[0].num_columns
    mins = np.full((s, p_max, c), np.inf)
    maxs = np.full((s, p_max, c), -np.inf)
    for i, m in enumerate(metas):
        mins[i, :counts[i]] = m.mins
        maxs[i, :counts[i]] = m.maxs
    scanned = ((mins <= q_hi) & (maxs >= q_lo)).all(axis=-1)     # (S, P_max)
    out = np.empty(s)
    for i, m in enumerate(metas):
        total = max(m.total_rows, 1)
        out[i] = scanned_dot(scanned[i, :counts[i]], self_rows(m)) / total
    return out
