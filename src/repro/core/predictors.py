"""Transition-distribution predictors (paper §IV-C, Theorem IV.2).

The default predictor weights each active state by the fraction of data it
skipped in the *previous phase* and biases the jump distribution as
P(s) ∝ w_s^gamma.  gamma=0 recovers the uniform BLS transition; gamma>0
favors recently-good states, which empirically cuts reorganization cost by
~17-28% (Table II) without hurting query cost.

These are *transition* predictors — they bias where D-UMTS jumps once a
counter fills.  The *workload* predictors that forecast what the next
horizon of queries will look like (and pre-position moves ahead of the
drift) grew into their own subsystem: :mod:`repro.forecast`.
"""
from __future__ import annotations

from typing import Dict

from . import mts


class GammaBiasedTransition:
    """P(s) ∝ w_s^gamma over the active states; picklable callable.

    The DynamicUMTS passes ``weights[s] = 1 - last_phase_cost(s)/alpha``
    (average fraction skipped proxy); states unseen last phase get weight 1
    (optimistic -- new states are worth exploring, matching the paper's
    median/replay initialization spirit).  A class rather than a closure
    so policies holding it — and whole engines — survive pickling for
    cross-process tenant migration.
    """

    def __init__(self, gamma: float):
        self.gamma = gamma

    def __call__(self, weights: Dict[int, float]) -> Dict[int, float]:
        if self.gamma == 0.0 or not weights:
            return mts.uniform_transition(weights)
        powered = {s: max(w, 1e-6) ** self.gamma
                   for s, w in weights.items()}
        total = sum(powered.values())
        return {s: v / total for s, v in powered.items()}


def gamma_biased_transition(gamma: float) -> mts.TransitionFn:
    return GammaBiasedTransition(gamma)


def median_initialized_counter(existing_phase_costs: Dict[int, float]) -> float:
    """Paper §IV-C: a state added mid-phase can have its counter initialized
    to the median of query costs incurred so far by existing states."""
    if not existing_phase_costs:
        return 0.0
    vals = sorted(existing_phase_costs.values())
    mid = len(vals) // 2
    if len(vals) % 2:
        return vals[mid]
    return 0.5 * (vals[mid - 1] + vals[mid])
