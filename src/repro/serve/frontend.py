"""The serving front end: admission, load leveling, shedding, caching.

:class:`ServeFrontend` is the traffic-facing tier in front of any
:class:`repro.engine.EventSink` — a single
:class:`repro.engine.FleetEngine` or a sharded
:class:`repro.engine.FleetRouter` (at one shard the two are
trace-bitwise interchangeable under the frontend):

* **Bounded ingress queue** (queue-based load leveling): submitted
  events wait in a bounded deque and are dispatched in order by
  :meth:`ServeFrontend.pump`; the ``overflow_policy`` decides whether a
  full queue back-pressures the caller ("block": pump to make room) or
  refuses at ingress ("reject").  An *admitted* event is never dropped.
* **Per-tenant token-bucket admission**: each tenant earns tokens per
  submit attempt and spends one per admitted event, so a flash-crowd
  tenant throttles at ingress instead of starving the fleet.
* **Circuit breaker that sheds reorg work, never serve work**: under
  overload (queue depth past the open threshold) a scheduler proxy
  refuses *new* reorganization grants and row budgets, so migrations
  and compactions defer through the fleet's existing waiting/pump
  machinery while every query keeps being served.  α-charges are
  recorded at decision time *before* the scheduler is consulted
  (paper §VI-D5), so shedding cannot change a tenant's charge ledger
  by a single bit.
* **Versioned read-through serve-cost cache**: hits prime the backend's
  identity-keyed serve memo under a plane-version key
  (:mod:`repro.serve.cache`), so hybrid-layout and delta-bearing
  tenants stay bit-exact.

All control decisions are clocked by event counters, not wall time, so
overload behaviour is deterministic and replayable; wall time is only
*measured* (per-event latency stamps for the benchmark's p50/p99).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from repro.core import workload as wl
from repro.engine import EventSink
from repro.engine.fleet import FleetResult

from .admission import CircuitBreaker, TokenBucket
from .cache import VersionedResultCache, cache_key


class _ShedState:
    """Overload state shared by every shard's shedding proxy.

    One frontend, one breaker, one shed decision — a router's shards
    each get their own :class:`_SheddingScheduler` (schedulers are
    per-shard) but all of them consult and count into this one object,
    so opening the breaker sheds reorg work fleet-wide and the counters
    aggregate naturally.
    """

    __slots__ = ("shedding", "shed_count", "shed_attempts", "shed_tids")

    def __init__(self) -> None:
        self.shedding = False
        #: Distinct (tenant, overload window) reorg grants refused.
        self.shed_count = 0
        #: Raw refused acquire attempts (the fleet re-polls waiting work
        #: every event, so this scales with time spent shedding).
        self.shed_attempts = 0
        self.shed_tids: set = set()


class _SheddingScheduler:
    """Proxy over one shard's scheduler; refuses grants while shedding.

    With ``shedding`` False the proxy is a pure delegate (same grant
    decisions, same stats, same name), so wrapping a fleet's scheduler
    changes nothing observable.  While shedding, ``try_acquire`` is
    refused (new reorg/compaction work queues in the fleet's waiting
    deque) and ``grant_rows`` returns 0 (in-flight incremental
    migrations pause); ``release`` always passes through so completing
    work frees its unit.
    """

    def __init__(self, inner, state: Optional[_ShedState] = None):
        self.inner = inner
        self.state = state if state is not None else _ShedState()

    @property
    def shedding(self) -> bool:
        return self.state.shedding

    @shedding.setter
    def shedding(self, value: bool) -> None:
        self.state.shedding = value

    @property
    def shed_count(self) -> int:
        return self.state.shed_count

    @property
    def shed_attempts(self) -> int:
        return self.state.shed_attempts

    @property
    def _shed_tids(self) -> set:
        return self.state.shed_tids

    @property
    def name(self) -> str:
        return self.inner.name

    def tick(self, now: int) -> None:
        self.inner.tick(now)

    def try_acquire(self, tenant_id: str) -> bool:
        state = self.state
        if state.shedding:
            state.shed_attempts += 1
            if tenant_id not in state.shed_tids:
                state.shed_tids.add(tenant_id)
                state.shed_count += 1
            return False
        return self.inner.try_acquire(tenant_id)

    def release(self, tenant_id: str) -> None:
        self.inner.release(tenant_id)

    def grant_rows(self, tenant_id: str, want: int) -> int:
        if self.state.shedding:
            self.state.shed_attempts += 1
            return 0
        grant = getattr(self.inner, "grant_rows", None)
        if grant is None:
            return want
        return grant(tenant_id, want)

    def stats(self) -> dict:
        stats = getattr(self.inner, "stats", None)
        return stats() if callable(stats) else {}


@dataclasses.dataclass(frozen=True)
class AdmissionResult:
    """Outcome of one :meth:`ServeFrontend.submit` attempt."""

    admitted: bool
    reason: str = "ok"        # "ok" | "throttled" | "queue_full"


@dataclasses.dataclass
class FrontendConfig:
    """Tuning knobs for :class:`ServeFrontend`.

    The defaults are permissive: unlimited admission, a deep queue, a
    breaker that only trips under a real backlog.  A frontend with
    defaults produces traces bit-identical to driving the fleet
    directly.
    """

    #: Ingress queue bound (queue-based load leveling).
    queue_capacity: int = 1024
    #: "block": a full queue pumps synchronously to make room (back
    #: pressure); "reject": refuse at ingress with reason "queue_full".
    overflow_policy: str = "block"
    #: Per-tenant admitted events per submit attempt; None = unlimited.
    admission_rate: Optional[float] = None
    #: Token-bucket burst size per tenant.
    admission_capacity: float = 8.0
    #: Starting tokens (None = full bucket).
    admission_initial: Optional[float] = None
    #: Trip the breaker (start shedding reorg work) when the queue is
    #: deeper than this fraction of capacity; disable with None.
    breaker_open_frac: Optional[float] = 0.75
    #: Re-close when the queue drains below this fraction ...
    breaker_close_frac: float = 0.25
    #: ... and at least this many events were processed while open.
    breaker_min_open_events: int = 32
    #: Versioned serve-cost cache entries; 0 disables the cache.
    cache_entries: int = 4096
    #: Events dispatched per :meth:`ServeFrontend.pump` call.
    pump_chunk: int = 32
    #: Stamp wall-clock latency per event (admission → completion).
    record_latency: bool = True
    #: Route pumps through the fused FleetMatrix pass (run_batched
    #: semantics; the versioned cache is bypassed — the fused pass does
    #: its own serve-score priming).
    batched: bool = False
    compute: str = "numpy"
    frames_per_pass: Optional[int] = None

    def __post_init__(self) -> None:
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if self.overflow_policy not in ("block", "reject"):
            raise ValueError(f"unknown overflow_policy "
                             f"{self.overflow_policy!r}")
        if self.admission_rate is not None and self.admission_rate <= 0:
            raise ValueError("admission_rate must be > 0 (None disables "
                             "admission control)")
        if self.pump_chunk < 1:
            raise ValueError("pump_chunk must be >= 1")
        if self.cache_entries < 0:
            raise ValueError("cache_entries must be >= 0")
        if self.breaker_open_frac is not None:
            if not 0.0 < self.breaker_open_frac <= 1.0:
                raise ValueError("breaker_open_frac must be in (0, 1]")
            if not 0.0 <= self.breaker_close_frac <= self.breaker_open_frac:
                raise ValueError("breaker_close_frac must be in "
                                 "[0, breaker_open_frac]")


class ServeFrontend:
    """Admission-controlled, load-leveled serving tier over a fleet.

    Typical use::

        frontend = ServeFrontend(fleet, FrontendConfig(...))
        for event in stream:
            frontend.submit_blocking(event)   # or submit() + own retry
        frontend.flush()
        result = frontend.result()

    The frontend owns the fleet's scheduler wrapping (shedding proxy)
    from construction on; everything else about the fleet is untouched,
    and :meth:`result` returns the ordinary :class:`FleetResult`.
    """

    def __init__(self, fleet: EventSink,
                 config: Optional[FrontendConfig] = None):
        self.fleet = fleet
        self.config = config or FrontendConfig()
        cfg = self.config
        # One shedding proxy per shard fleet (a plain FleetEngine is its
        # own single shard), all sharing one _ShedState so the breaker's
        # decision and the shed counters are frontend-wide.  A shard
        # already wrapped (stacked frontends) contributes its existing
        # state instead of being double-wrapped.
        shards = fleet.shard_fleets()
        state = next((s.scheduler.state for s in shards
                      if isinstance(s.scheduler, _SheddingScheduler)), None)
        self._shed_state = state if state is not None else _ShedState()
        self._shedders: List[_SheddingScheduler] = []
        for shard in shards:
            if isinstance(shard.scheduler, _SheddingScheduler):
                self._shedders.append(shard.scheduler)
            else:
                proxy = _SheddingScheduler(shard.scheduler,
                                           self._shed_state)
                shard.scheduler = proxy
                self._shedders.append(proxy)
        self._shedder = self._shedders[0]
        if cfg.breaker_open_frac is None:
            self._breaker: Optional[CircuitBreaker] = None
        else:
            cap = cfg.queue_capacity
            self._breaker = CircuitBreaker(
                open_above=max(1, int(cfg.breaker_open_frac * cap)),
                close_below=int(cfg.breaker_close_frac * cap),
                min_open_events=cfg.breaker_min_open_events)
        self._cache = (VersionedResultCache(cfg.cache_entries)
                       if cfg.cache_entries > 0 and not cfg.batched
                       else None)
        self._queue: Deque[Tuple[wl.Event, Optional[float]]] = \
            collections.deque()
        self._buckets: Dict[str, TokenBucket] = {}
        # (backend, state_matrix) per cache-eligible tenant; None marks a
        # tenant whose backend the versioned cache must not touch.
        self._cacheable: Dict[str, Optional[tuple]] = {}
        self._attempts = 0      # admission clock (all submit attempts)
        self.admitted = 0
        self.throttled = 0
        self.rejected = 0
        self.processed = 0
        #: Wall-clock seconds, admission → completion, per processed
        #: event (only when ``record_latency``); percentile fodder.
        self.latencies: List[float] = []

    # ------------------------------------------------------------------
    # Ingress
    # ------------------------------------------------------------------
    def submit(self, event) -> AdmissionResult:
        """Offer one event; admission-check, then enqueue (never runs it).

        Returns whether the event was admitted.  A throttled or rejected
        event was *not* enqueued — the caller owns the retry (or use
        :meth:`submit_blocking`).
        """
        ev = wl.as_event(event)
        self._attempts += 1
        cfg = self.config
        if cfg.admission_rate is not None:
            bucket = self._buckets.get(ev.tenant_id)
            if bucket is None:
                bucket = TokenBucket(cfg.admission_rate,
                                     cfg.admission_capacity,
                                     cfg.admission_initial)
                self._buckets[ev.tenant_id] = bucket
            if not bucket.try_take(self._attempts):
                self.throttled += 1
                return AdmissionResult(False, "throttled")
        if len(self._queue) >= cfg.queue_capacity:
            if cfg.overflow_policy == "reject":
                self.rejected += 1
                return AdmissionResult(False, "queue_full")
            while len(self._queue) >= cfg.queue_capacity:
                self.pump()
        t0 = time.perf_counter() if cfg.record_latency else None
        self._queue.append((ev, t0))
        self.admitted += 1
        self._update_breaker()
        return AdmissionResult(True, "ok")

    def submit_blocking(self, event) -> AdmissionResult:
        """Submit, retrying until admitted.

        A throttled attempt advances the admission clock (buckets refill
        per attempt, and the config requires ``admission_rate > 0``), so
        the retry loop always terminates; a full queue is pumped.
        """
        while True:
            res = self.submit(event)
            if res.admitted:
                return res
            if res.reason == "queue_full":
                self.pump()

    # ------------------------------------------------------------------
    # Dispatch
    # ------------------------------------------------------------------
    def pump(self, max_events: Optional[int] = None) -> int:
        """Dispatch up to ``max_events`` queued events; returns the count."""
        limit = max_events if max_events is not None else \
            self.config.pump_chunk
        if self.config.batched:
            return self._pump_batched(limit)
        n = 0
        while self._queue and n < limit:
            ev, t0 = self._queue.popleft()
            self._dispatch_one(ev, t0)
            self.processed += 1
            n += 1
            self._update_breaker()
        return n

    def flush(self) -> int:
        """Pump until the ingress queue is empty; returns events run."""
        total = 0
        while self._queue:
            total += self.pump()
        return total

    def run(self, events: Iterable[wl.Event],
            name: Optional[str] = None) -> FleetResult:
        """Submit (blocking) every event, flush, and return the trace."""
        for event in events:
            self.submit_blocking(event)
        self.flush()
        return self.result(name)

    def result(self, name: Optional[str] = None) -> FleetResult:
        return self.fleet.result(name)

    def _dispatch_one(self, ev: wl.Event, t0: Optional[float]) -> None:
        cache = self._cache
        fill = None
        if cache is not None and isinstance(ev, wl.QueryEvent):
            pair = self._cache_pair(ev.tenant_id)
            if pair is not None:
                backend, matrix = pair
                cost = cache.get(cache_key(ev.tenant_id, matrix.version,
                                           ev.query))
                if cost is not None:
                    # Read-through hit: prime the identity-keyed serve
                    # memo.  A swap landing mid-step clears it before it
                    # could be served stale (see repro.serve.cache).
                    backend._serve_memo = (ev.query, cost)
                else:
                    fill = matrix
        self.fleet.submit(ev)
        results = self.fleet.drain(collect=True)
        r = results[0] if results else None
        if fill is not None and r is not None and r.step is not None:
            # Nothing bumps the plane after serve within a step, so the
            # post-step version is the serve-time version — the only
            # version this realized cost may be keyed under.
            cache.put(cache_key(ev.tenant_id, fill.version, ev.query),
                      r.step.query_cost)
        if t0 is not None:
            self.latencies.append(time.perf_counter() - t0)

    def _pump_batched(self, limit: int) -> int:
        cfg = self.config
        n = 0
        t0s: List[Optional[float]] = []
        while self._queue and n < limit:
            ev, t0 = self._queue.popleft()
            self.fleet.submit(ev)
            t0s.append(t0)
            n += 1
        if n:
            self.fleet.drain(batched=True, compute=cfg.compute,
                             frames_per_pass=cfg.frames_per_pass)
            self.processed += n
            if cfg.record_latency:
                done = time.perf_counter()
                self.latencies.extend(done - t0 for t0 in t0s
                                      if t0 is not None)
        self._update_breaker()
        return n

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _cache_pair(self, tenant_id: str) -> Optional[tuple]:
        pair = self._cacheable.get(tenant_id, ())
        if pair == ():
            backend = self.fleet.tenant(tenant_id).backend
            matrix = getattr(backend, "state_matrix", None)
            primable = bool(getattr(backend, "_serve_primable", False))
            pair = (backend, matrix) if (matrix is not None
                                         and primable) else None
            self._cacheable[tenant_id] = pair
        return pair

    def _update_breaker(self) -> None:
        if self._breaker is None:
            return
        open_now = self._breaker.observe(len(self._queue), self.processed)
        if open_now and not self._shedder.shedding:
            self._shedder.shedding = True
        elif not open_now and self._shedder.shedding:
            self._shedder.shedding = False
            self._shedder._shed_tids.clear()

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def shed_count(self) -> int:
        return self._shedder.shed_count

    def stats(self) -> dict:
        """Counters for dashboards and tests (plain dict, all scalars)."""
        breaker = self._breaker
        return {
            "queue_depth": len(self._queue),
            "queue_capacity": self.config.queue_capacity,
            "admitted": self.admitted,
            "throttled": self.throttled,
            "rejected": self.rejected,
            "processed": self.processed,
            "shed_count": self._shedder.shed_count,
            "shed_attempts": self._shedder.shed_attempts,
            "breaker": None if breaker is None else {
                "is_open": breaker.is_open,
                "opens": breaker.stats.opens,
                "closes": breaker.stats.closes,
                "open_events": breaker.stats.open_events,
            },
            "cache": None if self._cache is None else self._cache.stats(),
            # One shard: the scheduler's own stats dict, exactly as when
            # fronting a plain fleet; sharded: nested per shard.
            "scheduler": (self._shedder.stats()
                          if len(self._shedders) == 1 else
                          {"shards": [s.stats() for s in self._shedders]}),
        }
