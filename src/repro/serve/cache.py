"""Versioned read-through serve-cost cache for the serving front end.

Exactness argument (why a cache cannot change a single trace bit): every
serving-layout change in a matrix-backed backend — activating a swap,
advancing or completing an incremental migration, composing an ingest
delta — goes through ``_install_serving_meta``, which re-registers the
serving shadow row in the tenant's :class:`~repro.engine.StateMatrix`
and therefore **bumps the plane version**.  Keying entries on
``(tenant, plane_version, query_bounds)`` means a hit is only possible
while the serving zone maps are bit-identical to when the entry was
filled, so the cached cost equals what ``serve()`` would recompute.
Candidate prepare/evict churn also bumps the version; that only causes
conservative misses, never a stale hit.

The frontend consumes hits by priming the backend's single-slot serve
memo (identity-keyed on the query object), so a swap landing *mid-step*
still clears the primed value before it could be served stale.
"""
from __future__ import annotations

import collections
from typing import Optional, Tuple

from repro.core import workload as wl

#: (tenant_id, plane_version, lo_bytes, hi_bytes)
CacheKey = Tuple[str, int, bytes, bytes]


def cache_key(tenant_id: str, version: int, query: wl.Query) -> CacheKey:
    """Key a query's serve cost on the tenant's serving-plane version."""
    return (tenant_id, int(version), query.lo.tobytes(), query.hi.tobytes())


class VersionedResultCache:
    """Bounded LRU mapping :func:`cache_key` → realized serve cost."""

    def __init__(self, max_entries: int = 4096):
        if max_entries <= 0:
            raise ValueError(f"max_entries must be > 0, got {max_entries!r}")
        self.max_entries = int(max_entries)
        self._data: "collections.OrderedDict[CacheKey, float]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: CacheKey) -> Optional[float]:
        """Look up a serve cost; None (and a miss) when absent."""
        cost = self._data.get(key)
        if cost is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return cost

    def put(self, key: CacheKey, cost: float) -> None:
        """Fill one entry, evicting the least-recently-used past capacity."""
        self._data[key] = float(cost)
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict:
        return {"entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions}
