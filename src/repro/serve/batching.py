"""Request batcher: fixed-slot continuous batching for the decode loop.

Requests occupy slots of a (B, S) ring; finished slots are refilled from the
queue between decode steps.  The decode step itself is a single jitted
program over the full slot batch (per-slot valid lengths handled by the KV
valid-length mask), so serving stays one compiled executable regardless of
request churn.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (T,)
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class SlotBatcher:
    """Assigns requests to fixed batch slots; tracks per-slot progress."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.slots: List[Optional[Request]] = [None] * num_slots
        # A deque, not a list: fill_slots pops from the front every decode
        # step, and list.pop(0) is O(queue) per request.
        self.queue: Deque[Request] = collections.deque()
        self.completed: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def fill_slots(self) -> List[int]:
        """Move queued requests into free slots; returns newly filled idxs."""
        filled = []
        for i in range(self.num_slots):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                filled.append(i)
        return filled

    def record_tokens(self, tokens: np.ndarray) -> None:
        """tokens: (num_slots,) next token per slot."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(tokens[i]))
            if req.done:
                self.completed.append(req)
                self.slots[i] = None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)
