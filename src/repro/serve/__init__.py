"""Serving plane: the traffic-facing tier in front of the fleet.

Public surface:

* :class:`ServeFrontend` — admission-controlled, load-leveled frontend
  over a :class:`repro.engine.FleetEngine`; sheds reorg work (never
  serve work) under overload and serves through a plane-versioned
  read-through cache.
* :class:`FrontendConfig` / :class:`AdmissionResult` — its knobs and
  per-submit outcome.
* :class:`TokenBucket` / :class:`CircuitBreaker` — deterministic
  admission primitives (event-counter clocked).
* :class:`VersionedResultCache` / :func:`cache_key` — the serve-cost
  cache keyed on StateMatrix plane versions.
* :class:`Request` / :class:`SlotBatcher`, :func:`build_serve_fns` /
  :func:`greedy_generate` — the LLM-decode substrate (fixed-slot
  continuous batching; unrelated to the fleet frontend).
"""
from repro.serve import admission, batching, cache, frontend, serve_loop
from repro.serve.admission import CircuitBreaker, TokenBucket
from repro.serve.batching import Request, SlotBatcher
from repro.serve.cache import VersionedResultCache, cache_key
from repro.serve.frontend import (AdmissionResult, FrontendConfig,
                                  ServeFrontend)
from repro.serve.serve_loop import build_serve_fns, greedy_generate

__all__ = [
    "AdmissionResult", "CircuitBreaker", "FrontendConfig", "Request",
    "ServeFrontend", "SlotBatcher", "TokenBucket", "VersionedResultCache",
    "build_serve_fns", "cache_key", "greedy_generate",
    "admission", "batching", "cache", "frontend", "serve_loop",
]
