"""Serving substrate: prefill/decode steps, greedy generation, batching."""
from repro.serve import batching, serve_loop
from repro.serve.batching import Request, SlotBatcher
from repro.serve.serve_loop import build_serve_fns, greedy_generate

__all__ = ["Request", "SlotBatcher", "build_serve_fns", "greedy_generate",
           "batching", "serve_loop"]
