"""Serving: jitted prefill + decode steps and a greedy generation loop."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.factory import ModelBundle


def build_serve_fns(model: ModelBundle, max_len: int):
    """Returns (prefill_fn, decode_fn); decode donates its cache."""
    prefill_fn = jax.jit(
        functools.partial(_prefill, model, max_len))
    decode_fn = jax.jit(functools.partial(_decode, model),
                        donate_argnums=2)
    return prefill_fn, decode_fn


def _prefill(model, max_len, params, batch):
    return model.prefill(params, batch, max_len=max_len)


def _decode(model, params, batch, cache):
    return model.decode_step(params, batch, cache)


def greedy_generate(model: ModelBundle, params, prompt: jax.Array,
                    steps: int, max_len: Optional[int] = None
                    ) -> jax.Array:
    """Greedy decoding: prompt (B, T) -> generated (B, steps)."""
    B, T = prompt.shape
    max_len = max_len or (T + steps)
    prefill_fn, decode_fn = build_serve_fns(model, max_len)
    logits, cache = prefill_fn(params, {"tokens": prompt})
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(steps):
        out.append(tok)
        logits, cache = decode_fn(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return jnp.concatenate(out, axis=1)
