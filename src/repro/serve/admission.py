"""Admission control primitives for the serving front end.

Two deterministic building blocks, both clocked by *event counters*
rather than wall time so overload behaviour is replayable in tests:

* :class:`TokenBucket` — per-tenant rate limiting at ingress.  A tenant
  earns ``rate`` tokens per submit attempt (fleet-wide), holds at most
  ``capacity``, and each admitted event spends one.
* :class:`CircuitBreaker` — queue-depth hysteresis that decides *when*
  the frontend sheds reorganization work.  It trips open when the
  ingress queue crosses ``open_above`` and re-closes only after the
  queue has drained below ``close_below`` **and** at least
  ``min_open_events`` events have been processed since it opened (the
  overload window), so it cannot flap on a single burst boundary.

Neither class touches the engine; :class:`repro.serve.ServeFrontend`
composes them with the shedding scheduler proxy.
"""
from __future__ import annotations

import dataclasses


class TokenBucket:
    """Deterministic token bucket clocked by an external counter.

    ``now`` is any monotonically non-decreasing integer/float clock —
    the frontend passes its submit-attempt counter, so two runs over the
    same event sequence make identical admission decisions.
    """

    __slots__ = ("rate", "capacity", "tokens", "_last")

    def __init__(self, rate: float, capacity: float,
                 initial: float = None):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate!r}")
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity!r}")
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.tokens = float(capacity if initial is None else initial)
        self._last = 0.0

    def try_take(self, now: float) -> bool:
        """Spend one token if available at clock ``now``; True on success."""
        elapsed = max(0.0, float(now) - self._last)
        self._last = float(now)
        self.tokens = min(self.capacity, self.tokens + elapsed * self.rate)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclasses.dataclass
class BreakerStats:
    """Observable breaker history: trips, re-closes, time spent open."""

    opens: int = 0
    closes: int = 0
    open_events: int = 0   # events processed while the breaker was open


class CircuitBreaker:
    """Queue-depth circuit breaker with a minimum-open overload window."""

    def __init__(self, open_above: int, close_below: int,
                 min_open_events: int = 0):
        if close_below > open_above:
            raise ValueError(
                f"close_below ({close_below}) must not exceed "
                f"open_above ({open_above})")
        self.open_above = int(open_above)
        self.close_below = int(close_below)
        self.min_open_events = int(min_open_events)
        self.is_open = False
        self._opened_at = 0
        self.stats = BreakerStats()

    def observe(self, queue_depth: int, processed: int) -> bool:
        """Update breaker state; returns True while open (shedding)."""
        if self.is_open:
            self.stats.open_events += 1
            if (queue_depth <= self.close_below
                    and processed - self._opened_at >= self.min_open_events):
                self.is_open = False
                self.stats.closes += 1
        elif queue_depth > self.open_above:
            self.is_open = True
            self._opened_at = processed
            self.stats.opens += 1
        return self.is_open
