"""Loss functions.

``chunked_lm_loss`` never materializes the full (B, T, V) logits tensor --
the vocab matmul + cross entropy run per sequence chunk under remat, which is
what makes 256k-vocab training shapes fit (the full tensor would be TBs for
nemotron-4-340b at train_4k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Next-token cross entropy.  ``targets`` aligned with ``logits`` positions;
    positions with target < 0 are ignored (e.g. VLM image prefix)."""
    logits = logits.astype(jnp.float32)
    valid = (targets >= 0).astype(jnp.float32)
    tclip = jnp.maximum(targets, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # One-hot reduction instead of take_along_axis: partitions cleanly when
    # the vocab dim is model-sharded (XLA fuses the one-hot into the reduce).
    onehot = (jnp.arange(logits.shape[-1])[None, None, :]
              == tclip[..., None])
    tgt = jnp.sum(logits * onehot, axis=-1)
    nll = (lse - tgt) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1.0)


def chunked_lm_loss(hidden: jax.Array, head: jax.Array, targets: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """CE over sequence chunks: logits (B, chunk, V) are transient.

    hidden: (B, T, d) final normalized hidden states; head: (d, V).
    """
    B, T, d = hidden.shape
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n = (T + pad) // c
    hs = hidden.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, t = xs
        logits = (h @ head).astype(jnp.float32)
        valid = (t >= 0).astype(jnp.float32)
        tclip = jnp.maximum(t, 0)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = (jnp.arange(logits.shape[-1])[None, None, :]
                  == tclip[..., None])
        tgt = jnp.sum(logits * onehot, axis=-1)
        nll_sum, n_valid = carry
        return (nll_sum + ((lse - tgt) * valid).sum(),
                n_valid + valid.sum()), None

    (nll_sum, n_valid), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ts))
    return nll_sum / jnp.maximum(n_valid, 1.0)
