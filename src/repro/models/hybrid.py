"""Zamba2-style hybrid (arXiv:2411.15242): Mamba-2 backbone + one *shared*
attention block applied every ``attn_every`` layers (weight reuse).

Forward structure (G = n_layers / attn_every groups):
    for g in range(G):            # lax.scan over groups
        x = shared_attn_block(x)  # same weights every application
        for i in range(attn_every):   # inner lax.scan
            x = mamba2_layer(x)

The shared block keeps a *per-application* KV cache (G caches) even though
weights are shared.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba2, sharding
from repro.models import transformer as tf


def n_groups(cfg) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def param_specs(cfg) -> Dict:
    stacked_m = jax.tree.map(lambda s: P(None, *s), mamba2.layer_specs(cfg),
                             is_leaf=lambda s: isinstance(s, P))
    return {
        "embed": P(None, "model"),
        "mamba": stacked_m,
        "shared_attn": tf.layer_specs(cfg),
        "final_norm": P(None),
        "head": P("fsdp", "model"),
    }


def init_params(key, cfg) -> Tuple[Dict, Dict]:
    ke, km, ka, kh = jax.random.split(key, 4)
    layer_keys = jax.random.split(km, cfg.n_layers)
    mamba_params = jax.vmap(lambda k: mamba2.init_layer(k, cfg)[0])(layer_keys)
    shared_params, _ = tf.init_layer(ka, cfg)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(L.DEFAULT_DTYPE),
        "mamba": mamba_params,
        "shared_attn": shared_params,
        "final_norm": L.init_rms_norm(cfg.d_model)[0],
        "head": L.dense_init(kh, cfg.d_model, cfg.vocab),
    }
    return params, param_specs(cfg)


def _grouped(tree, G: int, per: int):
    """Reshape stacked (L, ...) leaves to (G, per, ...)."""
    return jax.tree.map(lambda x: x.reshape((G, per) + x.shape[1:]), tree)


def hidden(params: Dict, cfg, batch: Dict, remat: bool = True) -> jax.Array:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)
    T = x.shape[1]
    positions = jnp.arange(T)
    G = n_groups(cfg)
    grouped = _grouped(params["mamba"], G, cfg.attn_every)

    def group_body(x, group_params):
        h, _ = tf._layer_apply(params["shared_attn"], x, cfg, positions,
                               prefix_len=0)

        def mamba_body(x, lp):
            out, _ = mamba2.layer_apply(lp, x, cfg)
            return out, None

        out, _ = jax.lax.scan(mamba_body, h, group_params)
        return out, None

    if remat:
        group_body = jax.checkpoint(group_body, policy=L.remat_policy())
    x, _ = jax.lax.scan(group_body, x, grouped)
    return L.rms_norm(x, params["final_norm"])


def forward(params: Dict, cfg, batch: Dict, remat: bool = True) -> jax.Array:
    x = hidden(params, cfg, batch, remat)
    logits = x @ params["head"]
    return sharding.constrain(logits, "batch", None, "model")


def prefill(params: Dict, cfg, batch: Dict,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)
    B, T = x.shape[0], x.shape[1]
    S = max_len or T
    positions = jnp.arange(T)
    G = n_groups(cfg)
    grouped = _grouped(params["mamba"], G, cfg.attn_every)

    def group_body(x, group_params):
        h, kv = tf._layer_apply(params["shared_attn"], x, cfg, positions,
                                prefix_len=0)

        def mamba_body(x, lp):
            out, st = mamba2.layer_apply(lp, x, cfg)
            return out, st

        out, states = jax.lax.scan(mamba_body, h, group_params)
        return out, (kv["k"], kv["v"], states)

    x, (ks, vs, mstates) = jax.lax.scan(group_body, x, grouped)
    if S > T:
        pad = ((0, 0), (0, 0), (0, S - T), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    # mstates leaves are (G, per, B, ...) -> flatten back to (L, B, ...)
    mstates = jax.tree.map(
        lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), mstates)
    x = L.rms_norm(x, params["final_norm"])
    logits = x[:, -1:] @ params["head"]
    cache = {"k": ks, "v": vs, "mamba": mstates,
             "index": jnp.asarray(T, jnp.int32)}
    return sharding.constrain(logits, "batch", None, "model"), cache


def decode_step(params: Dict, cfg, batch: Dict, cache: Dict
                ) -> Tuple[jax.Array, Dict]:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)
    idx = cache["index"]
    positions = idx[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    G = n_groups(cfg)
    grouped = _grouped(params["mamba"], G, cfg.attn_every)
    grouped_m = jax.tree.map(
        lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]),
        cache["mamba"])

    def group_body(x, xs):
        group_params, k_c, v_c, mstate = xs
        h, new_kv = tf._layer_apply(
            params["shared_attn"], x, cfg, positions, prefix_len=0,
            cache={"k": k_c, "v": v_c, "index": idx})

        def mamba_body(x, inp):
            lp, st = inp
            out, new_st = mamba2.layer_apply(lp, x, cfg, state=st)
            return out, new_st

        out, new_mstates = jax.lax.scan(mamba_body, h, (group_params, mstate))
        return out, (new_kv["k"], new_kv["v"], new_mstates)

    x, (ks, vs, mstates) = jax.lax.scan(
        group_body, x, (grouped, cache["k"], cache["v"], grouped_m))
    mstates = jax.tree.map(
        lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), mstates)
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["head"]
    new_cache = {"k": ks, "v": vs, "mamba": mstates, "index": idx + 1}
    return sharding.constrain(logits, "batch", None, "model"), new_cache


def cache_spec(cfg, batch: int, max_len: int, seq_axes=("model",)):
    G = n_groups(cfg)
    kv_shape = (G, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    arr = jax.ShapeDtypeStruct(kv_shape, L.DEFAULT_DTYPE)
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    kv_spec = P(None, "batch", seq, None, None)
    m_shapes, m_specs = mamba2.state_spec(cfg, batch)
    m_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        m_shapes)
    m_specs = jax.tree.map(lambda s: P(None, *s), m_specs,
                           is_leaf=lambda s: isinstance(s, P))
    shapes = {"k": arr, "v": arr, "mamba": m_shapes,
              "index": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = {"k": kv_spec, "v": kv_spec, "mamba": m_specs, "index": P()}
    return shapes, specs
