"""Model factory: one uniform interface over all architecture families.

``build_model(cfg)`` returns a :class:`ModelBundle` whose functions are pure
(params/caches are explicit pytrees), so ``train_step``/``serve_step`` can be
jitted/lowered uniformly for every (arch x shape) dry-run cell.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import hybrid, losses, rwkv6, transformer
from repro.models import layers as L


@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init_params: Callable                  # (key) -> params
    param_specs: Callable                  # () -> logical P tree
    forward: Callable                      # (params, batch) -> logits
    loss_fn: Callable                      # (params, batch) -> scalar loss
    prefill: Callable                      # (params, batch) -> (logits, cache)
    decode_step: Callable                  # (params, batch, cache) -> (logits, cache)
    cache_spec: Callable                   # (batch, max_len, seq_axes) -> (shapes, specs)


def _module_for(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return transformer
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return hybrid
    raise ValueError(f"unknown family {cfg.family}")


def build_model(cfg: ArchConfig) -> ModelBundle:
    mod = _module_for(cfg)

    def loss_fn(params, batch):
        # Chunked CE: the (B, T, V) logits tensor is never materialized.
        h = mod.hidden(params, cfg, batch)
        return losses.chunked_lm_loss(h, params["head"], batch["targets"])

    return ModelBundle(
        cfg=cfg,
        init_params=lambda key: mod.init_params(key, cfg)[0],
        param_specs=lambda: mod.param_specs(cfg),
        forward=lambda params, batch: mod.forward(params, cfg, batch),
        loss_fn=loss_fn,
        prefill=lambda params, batch, **kw: mod.prefill(params, cfg, batch,
                                                        **kw),
        decode_step=lambda params, batch, cache: mod.decode_step(
            params, cfg, batch, cache),
        cache_spec=lambda batch, max_len, seq_axes=("model",): mod.cache_spec(
            cfg, batch, max_len, seq_axes),
    )


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input of a cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig
                ) -> Tuple[Dict, Dict]:
    """(batch_shapes, batch_logical_specs) for a dry-run cell.

    * train/prefill: full-sequence inputs (+ targets for train).
    * decode: one new token with a KV cache of ``seq_len`` (cache specs are
      produced separately via ``ModelBundle.cache_spec``).
    * vlm: stub patch embeddings for the prefix + text tokens.
    * audio: stub EnCodec frame embeddings for the full sequence.
    """
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = L.DEFAULT_DTYPE

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if shape.kind == "decode":
        if cfg.family == "audio":
            shapes = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                                     bf16)}
            specs = {"embeds": P("batch", None, None)}
        else:
            shapes = {"tokens": tok((B, 1))}
            specs = {"tokens": P("batch", None)}
        return shapes, specs

    shapes: Dict = {}
    specs: Dict = {}
    if cfg.family == "vlm":
        prefix = cfg.prefix_len
        shapes["embeds"] = jax.ShapeDtypeStruct((B, prefix, cfg.d_model), bf16)
        shapes["tokens"] = tok((B, T - prefix))
        specs["embeds"] = P("batch", None, None)
        specs["tokens"] = P("batch", None)
    elif cfg.family == "audio":
        shapes["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), bf16)
        specs["embeds"] = P("batch", None, None)
    else:
        shapes["tokens"] = tok((B, T))
        specs["tokens"] = P("batch", None)
    if shape.kind == "train":
        shapes["targets"] = tok((B, T))
        specs["targets"] = P("batch", None)
    return shapes, specs
