"""Mesh context + activation sharding constraints for model code.

Model code annotates activations with *logical* kinds ("batch", "model",
None); the launcher installs a mesh context mapping batch-like dims to the
data axes ("data", or ("pod","data") multi-pod) and the tensor dim to
"model".  Without a context (CPU smoke tests) the constraints are no-ops.

Parameters use 2-D (fsdp x tensor) sharding: the tensor-parallel dim of every
weight is sharded on "model"; the other large dim is sharded on "data"
(ZeRO-3/FSDP style -- XLA all-gathers it just before use and the gradient
reduce-scatters back).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class MeshContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"     # param FSDP axis (None = off)
    seq_parallel: bool = False            # Megatron-style sequence parallelism


_CTX = MeshContext()


def set_mesh(mesh: Optional[Mesh], batch_axes: Sequence[str] = ("data",),
             model_axis: str = "model",
             fsdp_axis: Optional[str] = "data",
             seq_parallel: bool = False) -> None:
    global _CTX
    _CTX = MeshContext(mesh, tuple(batch_axes), model_axis, fsdp_axis,
                       seq_parallel)


def get_ctx() -> MeshContext:
    return _CTX


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], batch_axes: Sequence[str] = ("data",),
                 model_axis: str = "model",
                 fsdp_axis: Optional[str] = "data"):
    global _CTX
    prev = _CTX
    set_mesh(mesh, batch_axes, model_axis, fsdp_axis)
    try:
        yield
    finally:
        _CTX = prev


def _resolve(kind) -> object:
    if kind is None:
        return None
    if kind == "batch":
        axes = _CTX.batch_axes
        return axes if len(axes) > 1 else axes[0]
    if kind == "model":
        return _CTX.model_axis
    if kind == "fsdp":
        return _CTX.fsdp_axis
    raise ValueError(f"unknown sharding kind {kind!r}")


def spec(*kinds) -> P:
    """Build a PartitionSpec from logical kinds ('batch'|'model'|'fsdp'|None)."""
    return P(*[_resolve(k) for k in kinds])


def constrain(x: jax.Array, *kinds) -> jax.Array:
    """with_sharding_constraint by logical kinds; no-op without a mesh."""
    if _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec(*kinds)))


def constrain_residual(x: jax.Array) -> jax.Array:
    """Residual-stream (B, T, d) constraint.  With sequence parallelism the
    T dim is sharded on the tensor axis (Megatron SP); the surrounding
    attention/MoE constraints make XLA insert the all-gather/reduce-scatter
    pair exactly around the token-mixing ops."""
    if _CTX.mesh is None:
        return x
    t_axis = _CTX.model_axis if _CTX.seq_parallel else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec("batch", t_axis and "model", None)))


def named(spec_: P) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, spec_)


def sharded_embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Embedding gather with a (vocab, d/|model|)-sharded table via shard_map.

    Every device gathers full-vocab rows for its own d-slice: zero
    collectives, and it sidesteps an XLA SPMD bug where resharding a
    partitioned gather output emits an invalid dynamic-slice.  Output is
    (B, T, d) sharded (batch, None, model).
    """
    if _CTX.mesh is None:
        return jnp.take(table, tokens, axis=0)
    n_batch = 1
    for ax in _CTX.batch_axes:
        n_batch *= _CTX.mesh.shape[ax]
    if tokens.shape[0] % n_batch == 0:
        batch = (_CTX.batch_axes if len(_CTX.batch_axes) > 1
                 else _CTX.batch_axes[0])
    else:
        batch = None        # tiny batches (e.g. long-context B=1): replicate
    f = jax.shard_map(
        lambda tbl, tok: jnp.take(tbl, tok, axis=0),
        mesh=_CTX.mesh,
        in_specs=(P(None, _CTX.model_axis), P(batch, None)),
        out_specs=P(batch, None, _CTX.model_axis),
        check_vma=False,
    )
    return f(table, tokens)
