"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Time-mix: data-dependent token-shift lerps (ddlerp LoRA), per-channel decay
``w = exp(-exp(w0 + lora(x)))``, per-head matrix state
``S_t = diag(w_t) S_{t-1} + k_t v_t^T``, output ``o_t = r_t (S_{t-1} +
diag(u) k_t v_t^T)``.  Channel-mix: squared-ReLU gated FFN.

Two sequence-mix execution modes:
  * ``scan``    -- exact sequential ``lax.scan`` over time (default; O(1)
    state, numerically exact, the decode path uses the same step).
  * ``chunked`` -- MXU-friendly chunked linear attention (intra-chunk matmul
    with per-channel decay factorized in fp32 + inter-chunk scan).  This is
    the TPU-native production mode (see EXPERIMENTS.md §Perf); within-chunk
    decay products are bounded by chunk length, so fp32 is safe for the
    decay ranges RWKV-6 trains into (|log w| <~ 1) at chunk 64.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import sharding

DDLERP_DIM = 32
DECAY_LORA_DIM = 64
# Default "chunked": backward through a per-step scan would store O(T) state
# snapshots (43GB/layer at train_4k); the chunked form stores O(T/chunk).
SEQ_MODE = {"mode": "chunked", "chunk": 64}


def set_seq_mode(mode: str, chunk: int = 64) -> None:
    SEQ_MODE["mode"] = mode
    SEQ_MODE["chunk"] = chunk


def layer_specs(cfg) -> Dict:
    return {
        "ln1": P(None), "ln2": P(None), "mu_x": P(None), "mu": P(None, None),
        "ddlerp_a": P(None, None), "ddlerp_b": P(None, None, None),
        "w0": P(None), "w_lora_a": P(None, None), "w_lora_b": P(None, None),
        "u": P(None),
        "wr": P("fsdp", "model"), "wk": P("fsdp", "model"),
        "wv": P("fsdp", "model"), "wg": P("fsdp", "model"),
        "wo": P("model", "fsdp"), "gn": P(None),
        "cm_mu_k": P(None), "cm_mu_r": P(None),
        "cm_wk": P("fsdp", "model"), "cm_wv": P("model", "fsdp"),
        "cm_wr": P("fsdp", "model"),
    }


def param_specs(cfg) -> Dict:
    stacked = jax.tree.map(lambda s: P(None, *s), layer_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
    return {"embed": P(None, "model"), "layers": stacked,
            "final_norm": P(None), "head": P("fsdp", "model")}


def init_layer(key, cfg) -> Tuple[Dict, Dict]:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    small = lambda k, *shape: (jax.random.normal(k, shape) * 0.02).astype(
        jnp.float32)
    params = {
        "ln1": L.init_rms_norm(d)[0],
        "ln2": L.init_rms_norm(d)[0],
        "mu_x": small(ks[0], d),
        "mu": small(ks[1], 5, d),
        "ddlerp_a": small(ks[2], d, DDLERP_DIM),
        "ddlerp_b": small(ks[3], 5, DDLERP_DIM, d),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_lora_a": small(ks[4], d, DECAY_LORA_DIM),
        "w_lora_b": small(ks[5], DECAY_LORA_DIM, d),
        "u": small(ks[6], d),
        "wr": L.dense_init(ks[7], d, d),
        "wk": L.dense_init(ks[8], d, d),
        "wv": L.dense_init(ks[9], d, d),
        "wg": L.dense_init(ks[10], d, d),
        "wo": L.dense_init(ks[11], d, d),
        "gn": L.init_rms_norm(d)[0],
        "cm_mu_k": small(ks[0], d),
        "cm_mu_r": small(ks[1], d),
        "cm_wk": L.dense_init(ks[2], d, ff),
        "cm_wv": L.dense_init(ks[3], ff, d),
        "cm_wr": L.dense_init(ks[4], d, d),
    }
    return params, layer_specs(cfg)


def _shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros -- or ``prev`` -- at t=0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _ddlerp(params: Dict, x: jax.Array, xx: jax.Array) -> Tuple[jax.Array, ...]:
    """Data-dependent lerps for [w, k, v, r, g] (RWKV-6 ddlerp)."""
    dx = xx - x
    base = x + dx * params["mu_x"]
    dd = jnp.tanh(base.astype(jnp.float32) @ params["ddlerp_a"])
    dds = jnp.einsum("btk,ikd->ibtd", dd, params["ddlerp_b"])
    mixed = x[None] + dx[None] * (params["mu"][:, None, None, :] + dds
                                  ).astype(x.dtype)
    return tuple(mixed[i] for i in range(5))


def _wkv_scan(r, k, v, w, u, dh: int):
    """Exact sequential recurrence.  r/k/v/w: (B, T, H, dh) fp32."""
    B, T, H, _ = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,dk,dv)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    S_final, o = jax.lax.scan(step, S0, xs)
    return o.transpose(1, 0, 2, 3), S_final                 # (B,T,H,dh), state


def _wkv_chunked(r, k, v, w, u, dh: int, chunk: int):
    """Chunked linear attention with per-channel decay (fp32 factorized).

    Within a chunk of length Lc: with c[t] = sum_{tau<=t} log w_tau (<= 0),
      o_t = r_t c_exp[t-1] . S_in                       (cross)
          + sum_{s<t} (r_t e^{c[t-1]-c[s]} . k_s) v_s   (intra, strictly lower)
          + (r_t . u k_t) v_t                           (diagonal bonus)
    factorized as a = r_t * e^{c[t-1]}, b = k_s * e^{-c[s]} -- valid while
    |c| stays moderate within a chunk (chunk<=64 for RWKV-scale decays).
    """
    B, T, H, _ = r.shape
    Lc = min(chunk, T)
    pad = (-T) % Lc
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = (T + pad) // Lc
    resh = lambda x: x.reshape(B, nc, Lc, H, dh).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)     # (nc,B,Lc,H,dh)
    logw = jnp.log(jnp.maximum(wc, 1e-12))
    c = jnp.cumsum(logw, axis=2)                            # (nc,B,Lc,H,dh)
    c_prev = c - logw                                       # c[t-1]
    a = rc * jnp.exp(c_prev)
    b = kc * jnp.exp(-c)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)

    def chunk_step(S, xs):
        a_n, b_n, rc_n, kc_n, vc_n, c_n, c_prev_n, logw_n = xs
        # cross: o = r e^{c_prev} . S_in
        o_cross = jnp.einsum("blhk,bhkv->blhv", a_n, S)
        # intra (strictly lower triangular)
        att = jnp.einsum("blhk,bmhk->bhlm", a_n, b_n)
        att = jnp.where(mask[None, None], att, 0.0)
        o_intra = jnp.einsum("bhlm,bmhv->blhv", att, vc_n)
        # diagonal bonus
        o_diag = jnp.einsum("blhk,blhk,blhv->blhv",
                            rc_n, u[None, None] * kc_n, vc_n)
        # state update: S_out = e^{c[L-1]} S_in + sum_s e^{c[L-1]-c[s]} k_s v_s
        decay_last = jnp.exp(c_n[:, -1])                    # (B,H,dh)
        kd = kc_n * jnp.exp(c_n[:, -1][:, None] - c_n)
        S_new = decay_last[..., None] * S + jnp.einsum(
            "blhk,blhv->bhkv", kd, vc_n)
        return S_new, o_cross + o_intra + o_diag

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    S_final, o = jax.lax.scan(chunk_step, S0,
                              (a, b, rc, kc, vc, c, c_prev, logw))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, nc * Lc, H, dh)
    return o[:, :T], S_final


def time_mix(params: Dict, x: jax.Array, cfg,
             state: Optional[Dict] = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, final_wkv_state, last_x)."""
    B, T, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    prev = state["tm_shift"] if state is not None else None
    xx = _shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(params, x, xx)
    w = jnp.exp(-jnp.exp(
        params["w0"] + jnp.tanh(xw.astype(jnp.float32) @ params["w_lora_a"])
        @ params["w_lora_b"]))                              # (B,T,d) in (0,1)
    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    # The recurrence runs replicated over the model axis (heads=40 do not
    # divide the 16-way tensor axis; see DESIGN.md) -- batch stays sharded.
    to_heads = lambda t: t.astype(jnp.float32).reshape(B, T, H, dh)
    u = params["u"].reshape(H, dh)
    rh, kh, vh, wh = map(to_heads, (r, k, v, w))
    if state is not None:
        # Decode path: exact single/short-step scan from carried state.
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[..., :, None] * v_t[..., None, :]
            o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
            S = w_t[..., :, None] * S + kv
            return S, o
        xs = tuple(a.transpose(1, 0, 2, 3) for a in (rh, kh, vh, wh))
        S_final, o = jax.lax.scan(step, state["wkv"].astype(jnp.float32), xs)
        o = o.transpose(1, 0, 2, 3)
    elif SEQ_MODE["mode"] == "chunked":
        o, S_final = _wkv_chunked(rh, kh, vh, wh, u, dh, SEQ_MODE["chunk"])
    else:
        o, S_final = _wkv_scan(rh, kh, vh, wh, u, dh)
    o = o.reshape(B, T, H, dh)
    # Per-head group norm.
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 1e-5)
    o = o.reshape(B, T, d) * (1.0 + params["gn"])
    out = (o.astype(x.dtype) * g) @ params["wo"]
    return sharding.constrain(out, "batch", None, None), S_final, x[:, -1]


def channel_mix(params: Dict, x: jax.Array,
                state: Optional[Dict] = None) -> Tuple[jax.Array, jax.Array]:
    prev = state["cm_shift"] if state is not None else None
    xx = _shift(x, prev)
    dx = xx - x
    xk = x + dx * params["cm_mu_k"].astype(x.dtype)
    xr = x + dx * params["cm_mu_r"].astype(x.dtype)
    kk = jax.nn.relu(xk @ params["cm_wk"])
    kk = kk * kk
    out = jax.nn.sigmoid(xr @ params["cm_wr"]) * (kk @ params["cm_wv"])
    return sharding.constrain(out, "batch", None, None), x[:, -1]


def layer_apply(params: Dict, x: jax.Array, cfg,
                state: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
    h, wkv, tm_last = time_mix(params, L.rms_norm(x, params["ln1"]), cfg,
                               state)
    x = x + h
    h2, cm_last = channel_mix(params, L.rms_norm(x, params["ln2"]), state)
    x = x + h2
    return x, {"wkv": wkv, "tm_shift": tm_last, "cm_shift": cm_last}


def init_params(key, cfg) -> Tuple[Dict, Dict]:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layer_params = jax.vmap(lambda k: init_layer(k, cfg)[0])(layer_keys)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(L.DEFAULT_DTYPE),
        "layers": layer_params,
        "final_norm": L.init_rms_norm(cfg.d_model)[0],
        "head": L.dense_init(kh, cfg.d_model, cfg.vocab),
    }
    return params, param_specs(cfg)


def hidden(params: Dict, cfg, batch: Dict, remat: bool = True) -> jax.Array:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)

    def body(x, layer_params):
        out, _ = layer_apply(layer_params, x, cfg)
        return out, None

    if remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"])


def forward(params: Dict, cfg, batch: Dict, remat: bool = True) -> jax.Array:
    x = hidden(params, cfg, batch, remat)
    logits = x @ params["head"]
    return sharding.constrain(logits, "batch", None, "model")


def prefill(params: Dict, cfg, batch: Dict,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)

    def body(x, layer_params):
        out, st = layer_apply(layer_params, x, cfg)
        return out, st

    x, states = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    logits = x[:, -1:] @ params["head"]
    cache = dict(states)
    cache["index"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    return sharding.constrain(logits, "batch", None, "model"), cache


def decode_step(params: Dict, cfg, batch: Dict, cache: Dict
                ) -> Tuple[jax.Array, Dict]:
    x = sharding.sharded_embed_lookup(params["embed"], batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)

    def body(x, xs):
        layer_params, wkv, tm_s, cm_s = xs
        out, st = layer_apply(layer_params, x, cfg,
                              state={"wkv": wkv, "tm_shift": tm_s,
                                     "cm_shift": cm_s})
        return out, (st["wkv"], st["tm_shift"], st["cm_shift"])

    x, (wkv, tm_s, cm_s) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_shift"],
                  cache["cm_shift"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["head"]
    new_cache = {"wkv": wkv, "tm_shift": tm_s, "cm_shift": cm_s,
                 "index": cache["index"] + 1}
    return sharding.constrain(logits, "batch", None, "model"), new_cache


def cache_spec(cfg, batch: int, max_len: int, seq_axes=("model",)):
    """RWKV decode state is O(1) in sequence length."""
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    Lr = cfg.n_layers
    shapes = {
        "wkv": jax.ShapeDtypeStruct((Lr, batch, H, dh, dh), jnp.float32),
        "tm_shift": jax.ShapeDtypeStruct((Lr, batch, d), L.DEFAULT_DTYPE),
        "cm_shift": jax.ShapeDtypeStruct((Lr, batch, d), L.DEFAULT_DTYPE),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    specs = {
        "wkv": P(None, "batch", None, None, None),
        "tm_shift": P(None, "batch", None),
        "cm_shift": P(None, "batch", None),
        "index": P(),
    }
    return shapes, specs
