"""Model zoo: dense / MoE / VLM / audio transformers, RWKV-6, Mamba-2,
Zamba2 hybrid -- all pure-JAX with logical sharding specs."""
from repro.models import factory, hybrid, layers, losses, mamba2, rwkv6
from repro.models import sharding, transformer
from repro.models.factory import ModelBundle, build_model, input_specs

__all__ = ["ModelBundle", "build_model", "input_specs", "factory", "hybrid",
           "layers", "losses", "mamba2", "rwkv6", "sharding", "transformer"]
