"""Decoder-only transformer covering the dense / MoE / VLM / audio families.

One flexible implementation driven by ``ArchConfig``:
  * GQA attention (+ optional qk-norm), RoPE full/half/none
  * SwiGLU / GeGLU / squared-ReLU / GELU MLP, or top-k MoE
  * token-embedding input, stub-frontend embedding input (audio), or
    mixed prefix-embedding + tokens (VLM prefix-LM with bidirectional prefix)
  * scan-over-layers with full remat (``nothing_saveable``) for training
  * functional KV-cache prefill/decode
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import sharding

# Logical spec names: "fsdp" -> data axes (ZeRO-style), "model" -> tensor axis,
# "batch" -> data axes for activations.  Resolved by launch/mesh.py.


def layer_specs(cfg) -> Dict:
    attn_s = {k: P(*v) for k, v in L.init_attention.specs(cfg).items()}
    specs = {"attn": attn_s, "ln1": P(None), "ln2": P(None)}
    if cfg.moe is not None:
        specs["moe"] = {k: P(*v) for k, v in L.init_moe.specs(cfg).items()}
    else:
        specs["mlp"] = {k: P(*v) for k, v in L.init_mlp.specs(cfg).items()}
    return specs


def init_layer(key, cfg) -> Tuple[Dict, Dict]:
    k1, k2 = jax.random.split(key)
    attn_p, _ = L.init_attention(k1, cfg)
    params = {"attn": attn_p,
              "ln1": L.init_rms_norm(cfg.d_model)[0],
              "ln2": L.init_rms_norm(cfg.d_model)[0]}
    if cfg.moe is not None:
        params["moe"] = L.init_moe(k2, cfg)[0]
    else:
        params["mlp"] = L.init_mlp(k2, cfg)[0]
    return params, layer_specs(cfg)


def param_specs(cfg) -> Dict:
    stacked = jax.tree.map(lambda s: P(None, *s), layer_specs(cfg),
                           is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": P(None, "model"),
        "layers": stacked,
        "final_norm": P(None),
        "head": P("fsdp", "model"),
    }


def init_params(key, cfg) -> Tuple[Dict, Dict]:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layer_params = jax.vmap(lambda k: init_layer(k, cfg)[0])(layer_keys)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(L.DEFAULT_DTYPE),
        "layers": layer_params,
        "final_norm": L.init_rms_norm(cfg.d_model)[0],
        "head": L.dense_init(kh, cfg.d_model, cfg.vocab),
    }
    return params, param_specs(cfg)


def _layer_apply(layer_params: Dict, x: jax.Array, cfg,
                 positions: jax.Array, prefix_len: int,
                 cache: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    h, new_cache = L.attention_apply(
        layer_params["attn"], L.rms_norm(x, layer_params["ln1"]), cfg,
        positions, causal=True, prefix_len=prefix_len, cache=cache)
    x = x + h
    h2 = L.rms_norm(x, layer_params["ln2"])
    if cfg.moe is not None:
        x = x + L.moe_apply(layer_params["moe"], h2, cfg)
    else:
        x = x + L.mlp_apply(layer_params["mlp"], h2, cfg)
    return x, new_cache


def _gather_embed(params: Dict, tokens: jax.Array) -> jax.Array:
    return sharding.sharded_embed_lookup(params["embed"], tokens)


def _embed_input(params: Dict, cfg, batch: Dict) -> jax.Array:
    """Build the input activation stream for any input modality."""
    if cfg.family == "audio":
        x = batch["embeds"].astype(L.DEFAULT_DTYPE)
    elif cfg.family == "vlm":
        tok_emb = _gather_embed(params, batch["tokens"])
        x = jnp.concatenate(
            [batch["embeds"].astype(L.DEFAULT_DTYPE), tok_emb], axis=1)
    else:
        x = _gather_embed(params, batch["tokens"])
    return sharding.constrain_residual(x)


def hidden(params: Dict, cfg, batch: Dict, remat: bool = True) -> jax.Array:
    """Full-sequence forward up to the final norm; returns (B, T, d)."""
    x = _embed_input(params, cfg, batch)
    T = x.shape[1]
    positions = jnp.arange(T)
    prefix_len = cfg.prefix_len if cfg.family == "vlm" else 0

    def body(x, layer_params):
        out, _ = _layer_apply(layer_params, x, cfg, positions, prefix_len)
        return out, None

    if remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"])


def forward(params: Dict, cfg, batch: Dict,
            remat: bool = True) -> jax.Array:
    """Full-sequence forward; returns logits (B, T, V)."""
    x = hidden(params, cfg, batch, remat)
    logits = x @ params["head"]
    return sharding.constrain(logits, "batch", None, "model")


def prefill(params: Dict, cfg, batch: Dict, max_len: Optional[int] = None
            ) -> Tuple[jax.Array, Dict]:
    """Forward returning a KV cache (padded to ``max_len``) for decoding."""
    x = _embed_input(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    S = max_len or T
    positions = jnp.arange(T)
    prefix_len = cfg.prefix_len if cfg.family == "vlm" else 0

    def body(x, layer_params):
        out, kv = _layer_apply(layer_params, x, cfg, positions, prefix_len)
        return out, (kv["k"], kv["v"])

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    if S > T:
        pad = ((0, 0), (0, 0), (0, S - T), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "index": jnp.asarray(T, jnp.int32)}
    x = L.rms_norm(x, params["final_norm"])
    logits = x[:, -1:] @ params["head"]
    return sharding.constrain(logits, "batch", None, "model"), cache


def decode_step(params: Dict, cfg, batch: Dict, cache: Dict
                ) -> Tuple[jax.Array, Dict]:
    """One-token decode against a stacked-layer KV cache.

    cache: {"k"/"v": (L, B, S, Hkv, dh), "index": int32 scalar}.
    """
    if cfg.family == "audio":
        x = batch["embeds"].astype(L.DEFAULT_DTYPE)
    else:
        x = _gather_embed(params, batch["tokens"])
    x = sharding.constrain(x, "batch", None, None)
    idx = cache["index"]
    positions = idx[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)

    def body(x, xs):
        layer_params, k_c, v_c = xs
        out, new_cache = _layer_apply(
            layer_params, x, cfg, positions, prefix_len=0,
            cache={"k": k_c, "v": v_c, "index": idx})
        return out, (new_cache["k"], new_cache["v"])

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["head"]
    new_cache = {"k": ks, "v": vs, "index": idx + 1}
    return sharding.constrain(logits, "batch", None, "model"), new_cache


def cache_spec(cfg, batch: int, max_len: int,
               seq_axes=("model",)) -> Tuple[Dict, Dict]:
    """ShapeDtypeStructs + logical PartitionSpecs for the decode cache."""
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    arr = jax.ShapeDtypeStruct(shape, L.DEFAULT_DTYPE)
    kv_spec = P(None, "batch", seq_axes if len(seq_axes) > 1 else seq_axes[0],
                None, None)
    shapes = {"k": arr, "v": arr,
              "index": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = {"k": kv_spec, "v": kv_spec, "index": P()}
    return shapes, specs
