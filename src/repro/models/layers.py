"""Shared model layers: norms, RoPE, GQA attention, MLPs, MoE.

Pure-JAX (dict params + apply functions).  Every init function returns
``(params, specs)`` where ``specs`` mirrors the param tree with tuples of
logical sharding kinds (resolved by the launcher: 'fsdp' -> data axis,
'model' -> tensor axis).

Attention is a chunked, online-softmax (flash-style) jnp implementation --
the same blocking the Pallas TPU kernel in ``repro.kernels.flash_attention``
uses; ``repro.kernels.flash_attention.ops`` dispatches to the kernel on TPU
and to this implementation elsewhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding

DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Attention block-size knobs (perf hillclimb surface; see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AttnBlocking:
    q_block: int = 1024
    kv_block: int = 1024
    skip_masked_blocks: bool = False   # causal: skip fully-masked kv blocks


_BLOCKING = AttnBlocking()


def set_attn_blocking(q_block: int, kv_block: int,
                      skip_masked_blocks: bool = False) -> None:
    global _BLOCKING
    _BLOCKING = AttnBlocking(q_block, kv_block, skip_masked_blocks)


def get_attn_blocking() -> AttnBlocking:
    return _BLOCKING


# ---------------------------------------------------------------------------
# Initializers / norms
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=DEFAULT_DTYPE) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> Tuple[jax.Array, tuple]:
    return jnp.zeros((d,), jnp.float32), (None,)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, base: float = 10000.0,
         mode: str = "full") -> jax.Array:
    """Rotary embedding.  ``mode='half'`` rotates only the first half of the
    head dims (ChatGLM's 2-d RoPE convention); ``'none'`` is identity.

    x: (B, T, H, dh); positions: (T,) or (B, T).
    """
    if mode == "none":
        return x
    dh = x.shape[-1]
    rot = dh if mode == "full" else dh // 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
        angles = angles[None, :, None, :]                 # (1, T, 1, half)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs
        angles = angles[:, :, None, :]                    # (B, T, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    rotated = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    if mode == "half":
        return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (jnp reference; mirrors the Pallas kernel)
# ---------------------------------------------------------------------------

def _block_mask(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
                prefix_len: int, kv_valid_len: Optional[jax.Array]
                ) -> jax.Array:
    """(qb, kb) bool mask: True = attend."""
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask = kv_pos[None, :] <= q_pos[:, None]
        if prefix_len > 0:       # prefix-LM: bidirectional over the prefix
            mask = mask | (kv_pos[None, :] < prefix_len)
    if kv_valid_len is not None:
        mask = mask & (kv_pos[None, :] < kv_valid_len)
    return mask


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, prefix_len: int = 0,
                    kv_valid_len: Optional[jax.Array] = None,
                    q_offset: int = 0) -> jax.Array:
    """Chunked online-softmax attention with GQA.

    q: (B, T, Hq, dh); k, v: (B, S, Hkv, dh); Hq % Hkv == 0.
    Never materializes the (T, S) score matrix: double scan over q-blocks and
    kv-blocks carrying running (max, denom, acc) in fp32.
    """
    blocking = _BLOCKING
    B, T, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qb = min(blocking.q_block, T)
    kb = min(blocking.kv_block, S)
    # Pad to block multiples.
    T_pad = (T + qb - 1) // qb * qb
    S_pad = (S + kb - 1) // kb * kb
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
    if S_pad != S:
        k = jnp.pad(k, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    nq, nk = T_pad // qb, S_pad // kb
    scale = dh ** -0.5

    # (nq, B, qb, Hkv, g, dh)
    qs = q.reshape(B, nq, qb, Hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 2, 3, 4)

    kv_limit = jnp.asarray(S if kv_valid_len is None else kv_valid_len)

    def q_body(_, iq_and_qblk):
        iq, qblk = iq_and_qblk
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def kv_body(carry, ik_and_kv):
            m, l, acc = carry
            ik, kblk, vblk = ik_and_kv
            kv_pos = ik * kb + jnp.arange(kb)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, kv_pos, causal, prefix_len, kv_limit)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # Guard fully-masked rows (m_new == -inf).
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, g, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, qb, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, g, qb, dh) -> (B, qb, Hq, dh)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qb, Hq, dh)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T_pad, Hq, dh)
    return out[:, :T]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len: jax.Array) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q: (B, 1, Hq, dh); caches: (B, S, Hkv, dh).  Softmax reductions over the
    sharded S dim lower to all-reduces (flash-decoding-style combine).
    """
    B, _, Hq, dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    qr = q.reshape(B, Hkv, g, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    mask = jnp.arange(S)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def attention_specs(cfg) -> Dict:
    specs = {
        "wq": ("fsdp", "model"), "wk": ("fsdp", "model"),
        "wv": ("fsdp", "model"), "wo": ("model", "fsdp"),
    }
    if cfg.qk_norm:
        specs["q_norm"] = (None,)
        specs["k_norm"] = (None,)
    return specs


def init_attention(key, cfg) -> Tuple[Dict, Dict]:
    d, dh = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, d, cfg.n_heads * dh),
        "wk": dense_init(k2, d, cfg.n_kv_heads * dh),
        "wv": dense_init(k3, d, cfg.n_kv_heads * dh),
        "wo": dense_init(k4, cfg.n_heads * dh, d),
    }
    if cfg.qk_norm:
        params["q_norm"] = init_rms_norm(dh)[0]
        params["k_norm"] = init_rms_norm(dh)[0]
    return params, attention_specs(cfg)


init_attention.specs = attention_specs


def attention_apply(params: Dict, x: jax.Array, cfg,
                    positions: jax.Array,
                    causal: bool = True, prefix_len: int = 0,
                    cache: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, T, d).  With ``cache`` (decode): T == 1, cache holds
    k/v (B, S, Hkv, dh) + scalar ``index``; returns updated cache.
    Without cache: full-sequence flash attention; returns (out, new_kv) where
    new_kv holds this segment's k/v for prefill cache construction.
    """
    B, T, d = x.shape
    dh = cfg.head_dim
    q = (x @ params["wq"]).reshape(B, T, cfg.n_heads, dh)
    k = (x @ params["wk"]).reshape(B, T, cfg.n_kv_heads, dh)
    v = (x @ params["wv"]).reshape(B, T, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_base, cfg.rope_mode)
    k = rope(k, positions, cfg.rope_base, cfg.rope_mode)
    # Only q is head-sharded explicitly; k/v inherit the (Hkv, group)-factored
    # sharding through the einsum so GQA configs with Hkv < |model| partition
    # consistently (no conflicting 16-way constraint on an 8-head axis).
    q = sharding.constrain(q, "batch", None, "model", None)
    k = sharding.constrain(k, "batch", None, None, None)
    v = sharding.constrain(v, "batch", None, None, None)

    if cache is not None:
        idx = cache["index"]
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, 1)
        out = decode_attention(q, k_cache, v_cache, valid_len=idx + 1)
        new_cache = {"k": k_cache, "v": v_cache, "index": idx + 1}
    else:
        out = flash_attention(q, k, v, causal=causal, prefix_len=prefix_len)
        new_cache = {"k": k, "v": v}
    out = out.reshape(B, T, cfg.n_heads * dh)
    out = out @ params["wo"]
    return sharding.constrain_residual(out), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg) -> Dict:
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": ("fsdp", "model"), "w_up": ("fsdp", "model"),
                "w_down": ("model", "fsdp")}
    return {"w_in": ("fsdp", "model"), "w_out": ("model", "fsdp")}


def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        params = {"w_gate": dense_init(ks[0], d, ff),
                  "w_up": dense_init(ks[1], d, ff),
                  "w_down": dense_init(ks[2], ff, d)}
    else:
        params = {"w_in": dense_init(ks[0], d, ff),
                  "w_out": dense_init(ks[1], ff, d)}
    return params, mlp_specs(cfg)


init_mlp.specs = mlp_specs


def _act(name: str, h: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(h)
    if name == "sq_relu":                     # squared-ReLU (Nemotron/Primer)
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(name)


def mlp_apply(params: Dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        out = h @ params["w_down"]
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
        out = h @ params["w_down"]
    else:
        out = _act(cfg.act, x @ params["w_in"]) @ params["w_out"]
    return sharding.constrain_residual(out)


# ---------------------------------------------------------------------------
# MoE (top-k routing, sort-based dispatch, expert-parallel on "model")
# ---------------------------------------------------------------------------

def moe_specs(cfg) -> Dict:
    return {
        "router": (None, None),
        "w_gate": ("model", "fsdp", None),
        "w_up": ("model", "fsdp", None),
        "w_down": ("model", None, "fsdp"),
    }


def init_moe(key, cfg) -> Tuple[Dict, Dict]:
    d, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    e = m.num_experts
    fe = m.d_expert
    scale = (1.0 / d) ** 0.5

    def experts(k, d_in, d_out):
        return (jax.random.normal(k, (e, d_in, d_out)) * scale
                ).astype(DEFAULT_DTYPE)

    params = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": experts(ks[1], d, fe),
        "w_up": experts(ks[2], d, fe),
        "w_down": experts(ks[3], fe, d),
    }
    return params, moe_specs(cfg)


init_moe.specs = moe_specs


def _expert_ffn(params: Dict, xb: jax.Array, act: str) -> jax.Array:
    """xb: (..., E, C, d) grouped expert inputs -> same-shaped outputs."""
    gate = jnp.einsum("...ecd,edf->...ecf", xb, params["w_gate"])
    up = jnp.einsum("...ecd,edf->...ecf", xb, params["w_up"])
    if act == "swiglu":
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        h = jax.nn.gelu(gate) * up
    else:
        h = _act(act, gate) * up
    return jnp.einsum("...ecf,efd->...ecd", h, params["w_down"])


# Module-level capacity knob (perf hillclimb surface, see EXPERIMENTS §Perf).
MOE_OPTIONS = {"capacity_factor": 1.25}


def set_moe_capacity_factor(cf: float) -> None:
    MOE_OPTIONS["capacity_factor"] = cf


# Remat policy for the layer scan: "nothing" (full remat, min HBM),
# "dots" (save matmul outputs: no recompute of dots in backward, more HBM).
REMAT_OPTIONS = {"policy": "nothing"}


def set_remat_policy(policy: str) -> None:
    assert policy in ("nothing", "dots")
    REMAT_OPTIONS["policy"] = policy


def remat_policy():
    import jax as _jax
    if REMAT_OPTIONS["policy"] == "dots":
        return _jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return _jax.checkpoint_policies.nothing_saveable


def moe_apply(params: Dict, x: jax.Array, cfg,
              capacity_factor: Optional[float] = None) -> jax.Array:
    """Top-k MoE with per-sequence sort-based dispatch.

    Tokens are grouped by batch row (keeps the argsort local to the data
    shard), scattered into a capacity-bounded (B, E, C, d) buffer that is
    expert-sharded on the model axis (the resharding lowers to an
    all-to-all), pushed through the expert FFNs, and combined back with
    renormalized top-k gates.  Overflowing tokens are dropped (GShard
    convention).

    Decode (T == 1): per-row grouping would give capacity C=1 per expert per
    row -- i.e. every token visits every expert slot (E/k-fold waste).  The
    whole batch is dispatched as ONE group instead (flat path), restoring
    C = B*k/E*cf.
    """
    m = cfg.moe
    # Dispatch sorts tokens per batch row: keep the full sequence local.
    x = sharding.constrain(x, "batch", None, None)
    B, T, d = x.shape
    if capacity_factor is None:
        capacity_factor = MOE_OPTIONS["capacity_factor"]
    E, k = m.num_experts, m.top_k
    if T == 1:
        out = _moe_flat(params, x[:, 0], cfg, capacity_factor)
        return sharding.constrain_residual(out[:, None])
    C = max(int(T * k / E * capacity_factor + 0.999), 1)

    logits = (x.astype(jnp.float32) @ params["router"])          # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_idx.reshape(B, T * k)                   # (B, N)
    flat_gate = gate_vals.reshape(B, T * k)
    token_of = jnp.tile(jnp.repeat(jnp.arange(T), k)[None], (B, 1))

    sort_idx = jnp.argsort(flat_expert, axis=-1)                 # local sort
    sorted_expert = jnp.take_along_axis(flat_expert, sort_idx, -1)
    sorted_gate = jnp.take_along_axis(flat_gate, sort_idx, -1)
    sorted_token = jnp.take_along_axis(token_of, sort_idx, -1)

    # Position of each routed token within its expert's slot list.
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E)))(sorted_expert)
    pos = jnp.arange(T * k)[None, :] - jnp.take_along_axis(
        starts, sorted_expert, -1)
    keep = pos < C
    dest = sorted_expert * C + jnp.minimum(pos, C - 1)           # (B, N)

    gathered = jnp.take_along_axis(
        x, sorted_token[..., None], axis=1)                      # (B, N, d)
    gathered = gathered * keep[..., None].astype(x.dtype)

    buf = jnp.zeros((B, E * C, d), x.dtype)
    buf = jax.vmap(lambda b, dst, g: b.at[dst].add(g))(buf, dest, gathered)
    buf = buf.reshape(B, E, C, d)
    buf = sharding.constrain(buf, "batch", "model", None, None)  # all-to-all

    out_buf = _expert_ffn(params, buf, cfg.act)
    out_buf = sharding.constrain(out_buf, "batch", "model", None, None)
    out_flat = out_buf.reshape(B, E * C, d)

    back = jnp.take_along_axis(out_flat, dest[..., None], axis=1)  # (B, N, d)
    back = back * (sorted_gate * keep)[..., None].astype(x.dtype)
    out = jnp.zeros((B, T, d), x.dtype)
    out = jax.vmap(lambda o, t, bk: o.at[t].add(bk))(out, sorted_token, back)
    return sharding.constrain_residual(out)


def _moe_flat(params: Dict, x: jax.Array, cfg,
              capacity_factor: float) -> jax.Array:
    """Single-group dispatch over the flat (N, d) token batch (decode path)."""
    m = cfg.moe
    N, d = x.shape
    E, k = m.num_experts, m.top_k
    C = max(int(N * k / E * capacity_factor + 0.999), 1)
    logits = (x.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    flat_expert = expert_idx.reshape(N * k)
    flat_gate = gate_vals.reshape(N * k)
    token_of = jnp.repeat(jnp.arange(N), k)
    sort_idx = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[sort_idx]
    sorted_gate = flat_gate[sort_idx]
    sorted_token = token_of[sort_idx]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(E))
    pos = jnp.arange(N * k) - starts[sorted_expert]
    keep = pos < C
    dest = sorted_expert * C + jnp.minimum(pos, C - 1)
    gathered = x[sorted_token] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C, d), x.dtype).at[dest].add(gathered)
    # Shard experts on model AND capacity slots on data: otherwise the whole
    # data axis recomputes every expert redundantly (16x waste at decode).
    buf = sharding.constrain(buf.reshape(E, C, d), "model", "batch", None)
    out_buf = _expert_ffn(params, buf, cfg.act)
    out_buf = sharding.constrain(out_buf, "model", "batch", None)
    back = out_buf.reshape(E * C, d)[dest] * (
        sorted_gate * keep)[:, None].astype(x.dtype)
    return jnp.zeros((N, d), x.dtype).at[sorted_token].add(back)


def moe_aux_loss(params: Dict, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert_idx = jax.lax.top_k(probs, m.top_k)
    counts = jnp.zeros(m.num_experts).at[expert_idx.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(axis=(0, 1))
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
