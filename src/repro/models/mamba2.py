"""Mamba-2 (SSD, arXiv:2405.21060) layer used by the Zamba2 hybrid.

Scalar-per-head decay makes the chunked (matmul/MXU-friendly) form exact and
numerically safe in fp32: all pairwise decay factors within a chunk are
exp(c_t - c_s) with c decreasing, so every exponent is <= 0.

Train/prefill: chunked SSD (intra-chunk masked matmul + inter-chunk scan).
Decode: O(1) recurrent step with conv + SSM state carried in the cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import sharding

CHUNK = 256


def dims(cfg):
    d_in = cfg.ssm.expand * cfg.d_model
    H = d_in // cfg.ssm.head_dim
    return d_in, H, cfg.ssm.d_state, cfg.ssm.conv_width


def layer_specs(cfg) -> Dict:
    return {
        "ln": P(None), "in_proj": P("fsdp", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "A_log": P(None), "D": P(None), "dt_bias": P(None),
        "gn": P("model"), "out_proj": P("model", "fsdp"),
    }


def init_layer(key, cfg) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    d_in, H, ds, cw = dims(cfg)
    conv_ch = d_in + 2 * ds
    ks = jax.random.split(key, 4)
    params = {
        "ln": L.init_rms_norm(d)[0],
        "in_proj": L.dense_init(ks[0], d, 2 * d_in + 2 * ds + H),
        "conv_w": (jax.random.normal(ks[1], (cw, conv_ch)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gn": L.init_rms_norm(d_in)[0],
        "out_proj": L.dense_init(ks[2], d_in, d),
    }
    return params, layer_specs(cfg)


def _split_proj(zxbcdt: jax.Array, cfg):
    d_in, H, ds, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    xin = zxbcdt[..., d_in:2 * d_in]
    Bc = zxbcdt[..., 2 * d_in:2 * d_in + ds]
    Cc = zxbcdt[..., 2 * d_in + ds:2 * d_in + 2 * ds]
    dt = zxbcdt[..., 2 * d_in + 2 * ds:]
    return z, xin, Bc, Cc, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, T, C); w: (cw, C); prev: (B, cw-1, C)."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(cw))
    return jax.nn.silu(out + b[None, None])


def _ssd_chunked(xh, Bc, Cc, dt, a, h0):
    """Chunked SSD.  xh: (B,T,H,dh); Bc/Cc: (B,T,ds); dt: (B,T,H) fp32;
    a: (H,) negative.  Returns (y (B,T,H,dh), h_final (B,H,dh,ds))."""
    B, T, H, dh = xh.shape
    ds = Bc.shape[-1]
    Lc = min(CHUNK, T)
    pad = (-T) % Lc
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // Lc
    xc = xh.reshape(B, nc, Lc, H, dh).transpose(1, 0, 2, 3, 4)
    Bcc = Bc.reshape(B, nc, Lc, ds).transpose(1, 0, 2, 3)
    Ccc = Cc.reshape(B, nc, Lc, ds).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nc, Lc, H).transpose(1, 0, 2, 3)
    dA = dtc * a[None, None, None, :]                    # (nc,B,Lc,H) <= 0
    cum = jnp.cumsum(dA, axis=2)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(h, xs):
        x_n, B_n, C_n, dt_n, cum_n = xs
        # intra-chunk: scores[b,h,t,s] = (C_t.B_s) e^{cum_t - cum_s} dt_s
        cb = jnp.einsum("bts,bms->btm", C_n, B_n)        # (B,Lc,Lc)
        decay = jnp.exp(jnp.clip(
            cum_n[:, :, None, :] - cum_n[:, None, :, :], -60.0, 0.0))
        scores = cb[..., None] * decay * dt_n[:, None, :, :]
        scores = jnp.where(mask[None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, x_n)
        # cross-chunk: y += C_t e^{cum_t} . h_in
        y_cross = jnp.einsum("bts,bhds,bth->bthd", C_n, h,
                             jnp.exp(cum_n))
        # state: h_out = e^{cum_L} h_in + sum_s e^{cum_L - cum_s} dt_s x_s B_s
        w_last = jnp.exp(jnp.clip(cum_n[:, -1][:, None] - cum_n, -60.0, 0.0)
                         ) * dt_n                        # (B,Lc,H)
        h_new = jnp.exp(cum_n[:, -1])[..., None, None] * h + jnp.einsum(
            "bsh,bshd,bss2->bhds2".replace("s2", "z"), w_last, x_n, B_n)
        return h_new, y_intra + y_cross

    h_final, y = jax.lax.scan(chunk_step, h0, (xc, Bcc, Ccc, dtc, cum))
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, nc * Lc, H, dh)
    return y[:, :T], h_final


def layer_apply(params: Dict, x: jax.Array, cfg,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
    """x: (B, T, d).  state (decode): {"conv": (B,cw-1,ch), "h": (B,H,dh,ds)}."""
    B, T, d = x.shape
    d_in, H, ds, cw = dims(cfg)
    dh = cfg.ssm.head_dim
    h_in = L.rms_norm(x, params["ln"])
    zxbcdt = h_in @ params["in_proj"]
    z, xin, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    prev = state["conv"].astype(conv_in.dtype) if state is not None else None
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"], prev)
    xin = conv_out[..., :d_in]
    Bc = conv_out[..., d_in:d_in + ds].astype(jnp.float32)
    Cc = conv_out[..., d_in + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xin.astype(jnp.float32).reshape(B, T, H, dh)

    if state is not None:
        # Recurrent decode step(s): h_t = e^{a dt} h + dt x_t B_t^T.
        def step(h, inp):
            x_t, B_t, C_t, dt_t = inp
            decay = jnp.exp(dt_t * a[None, :])                    # (B,H)
            upd = jnp.einsum("bhd,bs->bhds", dt_t[..., None] * x_t, B_t)
            h = decay[..., None, None] * h + upd
            y = jnp.einsum("bhds,bs->bhd", h, C_t)
            return h, y
        xs = (xh.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2),
              Cc.transpose(1, 0, 2), dt.transpose(1, 0, 2))
        h_final, y = jax.lax.scan(step, state["h"].astype(jnp.float32), xs)
        y = y.transpose(1, 0, 2, 3)
    else:
        h0 = jnp.zeros((B, H, dh, ds), jnp.float32)
        y, h_final = _ssd_chunked(xh, Bc, Cc, dt, a, h0)

    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, params["gn"])
    out = y @ params["out_proj"]
    out = sharding.constrain(out, "batch", None, None)
    conv_state = jnp.concatenate(
        [state["conv"].astype(conv_in.dtype) if state is not None else
         jnp.zeros((B, cw - 1, conv_in.shape[-1]), conv_in.dtype),
         conv_in], axis=1)[:, -(cw - 1):]
    return x + out, {"conv": conv_state.astype(L.DEFAULT_DTYPE),
                     "h": h_final}


def state_spec(cfg, batch: int):
    d_in, H, ds, cw = dims(cfg)
    dh = cfg.ssm.head_dim
    ch = d_in + 2 * ds
    shapes = {"conv": jax.ShapeDtypeStruct((batch, cw - 1, ch),
                                           L.DEFAULT_DTYPE),
              "h": jax.ShapeDtypeStruct((batch, H, dh, ds), jnp.float32)}
    specs = {"conv": P("batch", None, "model"),
             "h": P("batch", "model", None, None)}
    return shapes, specs
