"""paligemma-3b [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216.
SigLIP vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings; the Gemma-style decoder treats them as a bidirectional prefix
(PaliGemma prefix-LM attention).
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_head=256, d_ff=16384, vocab=257216, act="geglu",
    embed_input=True, prefix_len=256,     # 256 SigLIP patch tokens
    source="arXiv:2407.07726 (PaliGemma); gemma-2b decoder",
)

SMOKE = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_head=16, d_ff=128, vocab=521, act="geglu",
    embed_input=True, prefix_len=8,
    source="reduced smoke variant",
)

register(FULL, SMOKE)
