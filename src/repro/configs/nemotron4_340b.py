"""nemotron-4-340b [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU MLP.
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, act="sq_relu",
    source="arXiv:2402.16819 (Nemotron-4 340B)",
)

SMOKE = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=384, vocab=509, act="sq_relu",
    source="reduced smoke variant",
)

register(FULL, SMOKE)
