"""qwen3-1.7b [hf:Qwen/Qwen3-1.7B family, per assignment hf:Qwen/Qwen3-8B].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk-norm.
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_head=128, d_ff=6144, vocab=151936, act="swiglu", qk_norm=True,
    source="hf:Qwen/Qwen3-1.7B (qk_norm, GQA)",
)

SMOKE = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=192, vocab=487, act="swiglu", qk_norm=True,
    source="reduced smoke variant",
)

register(FULL, SMOKE)
