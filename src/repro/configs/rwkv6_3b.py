"""rwkv6-3b (Finch) [arXiv:2404.05892].

32L d_model=2560, attention-free (data-dependent decay linear recurrence),
channel-mix d_ff=8960, vocab=65536, head size 64 (40 heads).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

FULL = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_head=64, d_ff=8960, vocab=65536, act="relu_sq_channelmix",
    rope_mode="none", rwkv_head_dim=64,
    ssm=SSMConfig(d_state=64, head_dim=64),
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)

SMOKE = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=499, act="relu_sq_channelmix",
    rope_mode="none", rwkv_head_dim=16,
    ssm=SSMConfig(d_state=16, head_dim=16),
    source="reduced smoke variant",
)

register(FULL, SMOKE)
