"""musicgen-large [arXiv:2306.05284].

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048; decoder-only
over EnCodec tokens.  The EnCodec frontend is a STUB: ``input_specs``
provides precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, act="gelu", embed_input=True,
    source="arXiv:2306.05284 (MusicGen large)",
)

SMOKE = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act="gelu", embed_input=True,
    source="reduced smoke variant",
)

register(FULL, SMOKE)
