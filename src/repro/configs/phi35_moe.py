"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=6400 vocab=32064,
MoE 16 experts top-2.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064, act="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=503, act="swiglu",
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=96),
    source="reduced smoke variant",
)

register(FULL, SMOKE)
