"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=163840,
MoE 64 experts top-6 (kimi/moonlight).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

FULL = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, act="swiglu",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=48, vocab=769, act="swiglu",
    moe=MoEConfig(num_experts=8, top_k=3, d_expert=48),
    source="reduced smoke variant",
)

register(FULL, SMOKE)
