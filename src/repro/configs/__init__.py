"""Architecture and shape configuration registry."""
from repro.configs.base import (SHAPES, ArchConfig, MoEConfig, ShapeConfig,
                                SSMConfig, get_arch, list_archs,
                                runnable_cells, skipped_cells)

__all__ = ["SHAPES", "ArchConfig", "MoEConfig", "ShapeConfig", "SSMConfig",
           "get_arch", "list_archs", "runnable_cells", "skipped_cells"]
