"""minitron-4b [arXiv:2407.14679] -- pruned Nemotron-4.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, squared-ReLU MLP.
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256000, act="sq_relu",
    source="arXiv:2407.14679 (Minitron)",
)

SMOKE = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=491, act="sq_relu",
    source="reduced smoke variant",
)

register(FULL, SMOKE)
