"""chatglm3-6b [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
2-d RoPE: rotary applied to half the head dims (ChatGLM convention).
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, act="swiglu", rope_mode="half",
    source="arXiv:2406.12793 (ChatGLM); hf:THUDM/chatglm3-6b",
)

SMOKE = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=499, act="swiglu", rope_mode="half",
    source="reduced smoke variant",
)

register(FULL, SMOKE)
