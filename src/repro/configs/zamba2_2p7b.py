"""zamba2-2.7b [arXiv:2411.15242].

54L d_model=2560 (Mamba2 backbone, ssm_state=64) + shared attention block
(32H, kv=32) applied every 6 layers with shared weights; shared-block MLP
d_ff=10240, vocab=32000.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

FULL = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_head=80, d_ff=10240, vocab=32000, act="gelu",
    ssm=SSMConfig(d_state=64, head_dim=64, conv_width=4, expand=2),
    attn_every=6,
    source="arXiv:2411.15242 (Zamba2)",
)

SMOKE = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=251, act="gelu",
    ssm=SSMConfig(d_state=16, head_dim=16, conv_width=4, expand=2),
    attn_every=2,
    source="reduced smoke variant",
)

register(FULL, SMOKE)
