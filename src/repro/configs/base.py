"""Architecture + shape configuration system.

Every assigned architecture gets a module in ``repro.configs`` registering an
:class:`ArchConfig` (exact public config) and a reduced ``smoke`` variant used
by CPU tests.  Shapes (``train_4k`` etc.) are global-batch x sequence cells
from the assignment; ``decode_*``/``long_*`` lower ``serve_step`` instead of
``train_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    num_shared: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    conv_width: int = 4
    expand: int = 2               # inner dim = expand * d_model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # defaults to d_model // n_heads
    act: str = "swiglu"                   # swiglu | geglu | sq_relu | gelu
    qk_norm: bool = False
    rope_mode: str = "full"               # full | half (chatglm 2d) | none
    rope_base: float = 10000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0                   # hybrid: shared attn block period
    embed_input: bool = False             # vlm/audio stub: frontend embeddings
    prefix_len: int = 0                   # vlm: bidirectional prefix length
    tie_embeddings: bool = False
    rwkv_head_dim: int = 64               # ssm family = rwkv6
    source: str = ""                      # public provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM / hybrid only)."""
        return self.family in ("ssm", "hybrid")

    def num_params(self) -> int:
        """Analytic parameter count (embedding + per-layer + head)."""
        d, dh = self.d_model, self.head_dim
        embed = self.vocab * d
        per_layer = 0
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) \
            + (self.n_heads * dh) * d
        if self.act in ("swiglu", "geglu"):
            ffn_dense = 3 * d * self.d_ff
        else:
            ffn_dense = 2 * d * self.d_ff
        if self.family == "ssm":
            d_in = self.ssm.expand * d if self.ssm else 2 * d
            # rwkv6 time-mix + channel-mix rough accounting
            attn = 4 * d * d + d_in
            ffn_dense = 2 * d * self.d_ff
        if self.family == "hybrid":
            # Mamba2 layers have no separate FFN: in_proj + out_proj + conv.
            d_in = self.ssm.expand * d
            attn = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d
            ffn_dense = 0
        if self.moe is not None:
            if self.act in ("swiglu", "geglu"):
                per_expert = 3 * d * self.moe.d_expert
            else:
                per_expert = 2 * d * self.moe.d_expert
            ffn = (self.moe.num_experts + self.moe.num_shared) * per_expert \
                + d * self.moe.num_experts           # router
        else:
            ffn = ffn_dense
        per_layer = attn + ffn + 2 * d
        total = embed + self.n_layers * per_layer + d
        if not self.tie_embeddings:
            total += self.vocab * d
        if self.family == "hybrid" and self.attn_every:
            shared_attn = 4 * d * d + 3 * d * self.d_ff
            total += shared_attn
        return int(total)

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.num_params()
        d = self.d_model
        per_expert = (3 if self.act in ("swiglu", "geglu") else 2) \
            * d * self.moe.d_expert
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(self.num_params() - self.n_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}
_SMOKE_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    _ensure_loaded()
    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch '{name}'; have {sorted(reg)}")
    return reg[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def runnable_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs (full-attention archs skip it -- see DESIGN.md §Arch-applicability)."""
    _ensure_loaded()
    cells = []
    for arch in list_archs():
        cfg = _REGISTRY[arch]
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((arch, shape.name))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    _ensure_loaded()
    out = []
    for arch in list_archs():
        cfg = _REGISTRY[arch]
        if not cfg.sub_quadratic:
            out.append((arch, "long_500k",
                        "full quadratic attention at 524288 tokens"))
    return out


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Importing the modules triggers register() calls.
    from repro.configs import (chatglm3_6b, minitron_4b, moonshot_v1_16b,  # noqa: F401
                               musicgen_large, nemotron4_340b, paligemma_3b,
                               phi35_moe, qwen3_1p7b, rwkv6_3b, zamba2_2p7b)
