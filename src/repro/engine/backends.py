"""Storage backends: the physical layer behind the :class:`LayoutEngine`.

A backend owns the *physical* side of the online loop — which layouts are
registered, which one is currently materialized and serving queries, and what
a query actually costs against the materialized table.  The decision layer
(policies + D-UMTS) only ever sees metadata-level cost estimates, mirroring
the paper's design where candidate exploration never touches row data.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core import layouts as L
from repro.core import workload as wl
from repro.data.partition_store import (PartitionStore, manifest_dict,
                                        write_manifest)

from . import compute
from .state_matrix import StateMatrix


@runtime_checkable
class StorageBackend(Protocol):
    """Physical layer contract consumed by :class:`repro.engine.LayoutEngine`.

    Lifecycle of a state id under this protocol:

    1. :meth:`register` — a policy admits a candidate layout; the backend
       tracks it but does **not** materialize anything (registration is
       metadata-only and therefore cheap).
    2. :meth:`estimate_costs` — per-query, the engine asks for service-cost
       estimates of many registered states in one batched call.  Estimates
       use each layout's *estimated* metadata (``Layout.meta``), never disk.
    3. :meth:`prepare` — the engine announces a reorganization decision.  A
       backend may start background materialization here so the Δ-delay
       between decision and swap overlaps with useful work.
    4. :meth:`activate` — the swap takes effect: the state becomes the
       serving layout (materializing it now if :meth:`prepare` did not).
    5. :meth:`serve` — charge one query against the *currently serving*
       materialized layout, returning the fraction of records accessed.
    6. :meth:`deregister` — the policy evicted the state.  Must be a no-op
       for unknown ids; must not disturb the serving layout even if the
       serving state itself is deregistered (the physical table survives
       until the next swap, exactly like the legacy runner).

    Backends that support the *incremental* reorganization plane
    (:mod:`repro.engine.reorg`) additionally expose ``serving_layout``
    plus the migration triple ``begin_migration(plan)`` /
    ``apply_migration(hybrid_meta, newly_done)`` /
    ``complete_migration(plan)``: while a migration is in flight the
    backend serves from a *hybrid* state whose zone maps mix moved
    (target) and unmoved (source) partitions, and completion snaps to the
    target through the same path :meth:`activate` takes.  These are
    optional capabilities, like ``serve_block`` / ``prime_estimates``.
    """

    def register(self, layout: L.Layout) -> None: ...

    def deregister(self, state_id: int) -> None: ...

    def has(self, state_id: int) -> bool: ...

    def get(self, state_id: int) -> L.Layout: ...

    def estimate_costs(self, state_ids: Sequence[int],
                       query: wl.Query) -> Dict[int, float]: ...

    def prepare(self, state_id: int) -> None: ...

    def activate(self, state_id: int) -> None: ...

    @property
    def serving_state(self) -> Optional[int]: ...

    def serve(self, query: wl.Query) -> float: ...


class _RegistryMixin:
    """Shared metadata registry + batched estimation over a StateMatrix.

    The registry mirrors every registered layout's zone maps into a packed
    :class:`repro.engine.state_matrix.StateMatrix` (O(P*C) per register /
    deregister), so per-query estimation is one masked matrix op over
    persistent tensors instead of a per-query re-pad of all S states.

    ``compute`` selects the estimation path: ``"numpy"`` (default, exact),
    ``"pallas"`` (kernel-backed, float32 with an exactness guard),
    ``"pallas_fused"`` (the decision megakernel, same guard — estimates
    stay bit-exact because non-float32-representable operands fall back to
    the numpy path), or ``"reference"`` — the original per-query
    :func:`repro.core.layouts.eval_cost_states` re-padding path, kept as
    the golden reference and as the benchmark baseline.
    """

    _layouts: Dict[int, L.Layout]

    def _init_registry(self, compute: str = "numpy") -> None:
        if compute not in ("numpy", "pallas", "pallas_fused", "reference"):
            raise ValueError(f"unknown compute mode: {compute!r}")
        self._compute = compute
        self._layouts = {}
        self._matrix: Optional[StateMatrix] = (
            None if compute == "reference"
            else StateMatrix(compute_backend=compute))
        self._primed: Optional[tuple] = None
        self._primed_idx: Optional[tuple] = None

    def prime_estimates(self, query: wl.Query, version: int,
                        costs: np.ndarray) -> None:
        """Install precomputed per-slot costs for one upcoming query.

        Used by the fleet's batched path: ``costs`` is the full per-slot
        vector a :class:`repro.engine.fleet_matrix.FleetMatrix` computed in
        its fused pass, ``version`` the :attr:`StateMatrix.version` it was
        computed against.  :meth:`estimate_costs` consumes it only when the
        *same* query object arrives while the plane is still at that
        version — any state churn in between (a policy registering or
        evicting candidates mid-decision) bumps the version and falls back
        to the exact per-tenant path, so priming can never change results.
        """
        self._primed = (query, version, costs)

    def _primed_costs(self, query: wl.Query) -> Optional[np.ndarray]:
        primed = self._primed
        if (primed is not None and primed[0] is query
                and self._matrix is not None
                and primed[1] == self._matrix.version):
            return primed[2]
        return None

    def _primed_dict(self, costs: np.ndarray,
                     state_ids: Sequence[int]) -> Dict[int, float]:
        """id -> cost dict off a primed per-slot vector, vectorized.

        Policies tend to pass the *same* id list object every query (or
        fresh lists between state churn), so the slot-index gather is
        cached on (ids object, plane version); ``ndarray.tolist`` yields
        the same Python floats ``float(costs[slot])`` would.
        """
        m = self._matrix
        cache = self._primed_idx
        if (cache is not None and cache[0] is state_ids
                and cache[1] == m.version):
            ids, idx = cache[2], cache[3]
        else:
            ids = list(state_ids)
            idx = np.fromiter((m.slot(s) for s in ids), dtype=np.intp,
                              count=len(ids))
            # Holding a reference to state_ids keeps its id() from being
            # recycled while the cache entry is alive.
            self._primed_idx = (state_ids, m.version, ids, idx)
        return dict(zip(ids, costs.take(idx).tolist()))

    def register(self, layout: L.Layout) -> None:
        self._layouts[layout.layout_id] = layout
        if self._matrix is not None:
            self._matrix.register(layout.layout_id, layout.meta)

    def deregister(self, state_id: int) -> None:
        self._layouts.pop(state_id, None)
        if self._matrix is not None:
            self._matrix.deregister(state_id)

    def has(self, state_id: int) -> bool:
        return state_id in self._layouts

    def get(self, state_id: int) -> L.Layout:
        return self._layouts[state_id]

    @property
    def states(self) -> List[int]:
        return sorted(self._layouts)

    @property
    def state_matrix(self) -> Optional[StateMatrix]:
        """The packed metadata plane (None in ``reference`` mode)."""
        return self._matrix

    def estimate_costs(self, state_ids: Sequence[int],
                       query: wl.Query) -> Dict[int, float]:
        """Batched metadata-only c(s, q) for every requested state.

        One masked matrix op over the persistent StateMatrix tensors —
        bit-identical (numpy compute) to ``eval_cost_states`` and to
        evaluating each state individually with ``eval_cost``.
        """
        if self._matrix is not None:
            costs = self._primed_costs(query)
            if costs is not None:
                return self._primed_dict(costs, state_ids)
            return self._matrix.estimate_costs(state_ids, query.lo, query.hi)
        return self._reference_costs(state_ids, query)

    def _reference_costs(self, state_ids: Sequence[int],
                         query: wl.Query) -> Dict[int, float]:
        ids = list(state_ids)
        metas = [self._layouts[s].meta for s in ids]
        costs = L.eval_cost_states(metas, query.lo, query.hi)
        return {s: float(c) for s, c in zip(ids, costs)}

    def estimate_vector(self, query: wl.Query) -> np.ndarray:
        """All registered states' c(s, q) as one float64 per-slot vector.

        The array-native sibling of :meth:`estimate_costs` for policies
        that are pure cost functions (argmin/threshold rules): no per-id
        dict is materialized, slot order is :attr:`StateMatrix.state_ids`
        (look slots up via ``state_matrix.slot``).  Consumes primed fleet
        results when valid, so the values are bit-identical between the
        stepwise and batched fleet paths.  Unavailable (AttributeError) in
        ``reference`` compute mode.
        """
        costs = self._primed_costs(query)
        if costs is not None:
            return costs
        return self._matrix.estimate(query.lo, query.hi)


class InMemoryBackend(_RegistryMixin):
    """Numpy-table backend: the simulation / benchmarking physical layer.

    Materialization computes exact zone maps over the in-memory table;
    serving charges the metadata-derived fraction of records accessed.
    The serving layout's *exact* (materialized) zone maps live in the packed
    plane as a shadow state under the reserved id ``SERVING_SHADOW`` (-1),
    so each ``estimate_costs`` call fuses the serve score into the same
    masked matrix op and :meth:`serve` is usually a memo lookup — still
    bit-identical to ``eval_cost`` on the serving metadata.
    :meth:`serve_block` scores whole query blocks for the engine's batched
    ``run`` fast path.
    """

    #: Reserved StateMatrix id for the materialized serving layout's zone
    #: maps.  Policies must use non-negative state ids.
    SERVING_SHADOW = -1

    def __init__(self, data: np.ndarray, compute: str = "numpy"):
        self.data = data
        self._init_registry(compute)
        self._serving: Optional[L.Layout] = None
        self._serving_cache: Optional[tuple] = None
        self._serve_memo: Optional[tuple] = None
        self._shadow_slot: Optional[tuple] = None   # (plane version, slot)
        self._migration = None                      # in-flight MigrationPlan
        # Streaming ingest (see repro.engine.ingest): pending delta
        # batches over the growing table + the delta-free base zone maps
        # the composed serving state is built from.  None until
        # enable_ingest() — every path below is untouched without it.
        self._delta = None
        self._ingest_base: Optional[L.PartitionMetadata] = None

    def prepare(self, state_id: int) -> None:
        # In-memory reorganization is instantaneous; nothing to overlap.
        pass

    @property
    def pending_states(self) -> List[int]:
        """State ids with in-flight physical work (always empty here)."""
        return []

    def _install_serving_meta(self, meta: L.PartitionMetadata) -> None:
        """Swap the physical serving zone maps (layout or hybrid state)."""
        self._serving_cache = (np.ascontiguousarray(meta.mins.T),
                               np.ascontiguousarray(meta.maxs.T),
                               L.self_rows(meta), max(meta.total_rows, 1))
        self._serve_memo = None
        if self._matrix is not None:
            # Re-registering the shadow fires the StateMatrix listener
            # events, so an attached FleetMatrix keeps scoring this
            # tenant's (possibly hybrid) serving state in the fused pass.
            self._matrix.register(self.SERVING_SHADOW, meta)

    def _install_base_meta(self, meta: L.PartitionMetadata) -> None:
        """Install a delta-free base state, composing pending deltas on top.

        With ingest disabled (or zero pending batches) the composed state
        *is* ``meta`` — the same object — so the serving plane, the shadow
        registration and every downstream estimate are bit-identical to
        the pre-ingest paths.
        """
        self._ingest_base = meta
        d = self._delta
        self._install_serving_meta(meta if d is None else d.compose(meta))

    def _activate_layout(self, layout: L.Layout) -> None:
        self._serving = layout
        d = self._delta
        if d is not None and d.pending:
            # An atomic (re)materialization rewrites the *grown* table:
            # every pending delta batch is routed in and absorbed.
            layout.true_meta = None
            meta = layout.materialize(self.data)
            d.absorb_up_to(len(self.data))
        else:
            meta = layout.materialize(self.data)
        self._install_base_meta(meta)

    def activate(self, state_id: int) -> None:
        self._activate_layout(self._layouts[state_id])

    # -- streaming ingest (see repro.engine.ingest) ---------------------
    def enable_ingest(self):
        """Open the write path: appended rows land as delta partitions."""
        if self._compute == "reference":
            raise ValueError(
                "ingest needs the packed metadata plane (compute="
                "'reference' serves straight off the layout object and "
                "cannot compose delta partitions)")
        if self._delta is None:
            from .ingest import DeltaLog
            self._delta = DeltaLog(len(self.data))
        return self._delta

    @property
    def delta_log(self):
        """The pending-delta state (None until :meth:`enable_ingest`)."""
        return self._delta

    @property
    def ingest_base_meta(self) -> Optional[L.PartitionMetadata]:
        """Zone maps of the clustered base under the composed deltas."""
        return self._ingest_base

    def ingest_rows(self, rows: np.ndarray):
        """Append one batch as an unclustered delta partition.

        The batch is visible to scans immediately: its exact zone maps are
        composed onto the serving state and re-registered through the
        StateMatrix listener events, so an attached FleetMatrix keeps
        scoring this (now delta-bearing) tenant in the fused pass.
        """
        d = self._delta
        if d is None:
            raise RuntimeError("enable_ingest() first")
        start = len(self.data)
        self.data = np.concatenate([self.data, rows])
        batch = d.append(rows, start)
        # Exact (materialized) zone maps are stale for the grown table;
        # estimated candidate metadata is sample-based and untouched.
        for lay in self._layouts.values():
            lay.true_meta = None
        if self._serving is not None:
            self._serving.true_meta = None
            self._install_serving_meta(d.compose(self._ingest_base))
        return batch

    def delta_source(self):
        """(assignment, meta) of the hybrid delta-bearing source state.

        What the migration planner diffs a compaction (or a drift reorg
        with deltas pending) against: clustered base partitions plus one
        pseudo-partition per delta batch.  None with no pending deltas —
        the plain planning path stays bit-identical.
        """
        d = self._delta
        if d is None or not d.pending:
            return None
        base_len = d.clustered_len
        serving = self._serving
        if serving is not None and serving.route is not None:
            base_assign = np.asarray(serving.route(self.data[:base_len]),
                                     dtype=np.int64)
        else:
            base_assign = np.zeros(base_len, dtype=np.int64)
        base = self._ingest_base
        assign = d.source_assignment(base_assign, base.num_partitions,
                                     len(self.data))
        return assign, d.compose(base)

    @property
    def serving_state(self) -> Optional[int]:
        return None if self._serving is None else self._serving.layout_id

    # -- incremental migration (see repro.engine.reorg) -----------------
    @property
    def serving_layout(self) -> Optional[L.Layout]:
        """The Layout object behind :attr:`serving_state` (source of an
        in-flight migration)."""
        return self._serving

    @property
    def supports_incremental(self) -> bool:
        """Hybrid serving needs the packed plane (``reference`` compute
        serves straight off the layout object and cannot mix states)."""
        return self._compute != "reference"

    @property
    def migrating(self) -> bool:
        return self._migration is not None

    def begin_migration(self, plan) -> None:
        """An incremental migration starts; serving is untouched until the
        first completed micro-batch lands via :meth:`apply_migration`."""
        if self._migration is not None:
            raise RuntimeError("a migration is already in flight")
        self._migration = plan
        if self._delta is not None:
            # The plan routed the table as of planning time: those rows
            # (pending deltas included — they are source pseudo-partitions
            # of the plan) now belong to the migration, and its hybrid
            # zone maps track them partition by partition.  Batches
            # appended mid-flight stack as fresh deltas on top.
            self._delta.absorb_up_to(len(plan.target_assignment))

    def apply_migration(self, hybrid_meta: L.PartitionMetadata,
                        newly_done: Sequence[int]) -> None:
        """A micro-batch of moves completed: serve the hybrid state.

        The hybrid zone maps become the physical serving state (and the
        SERVING_SHADOW plane entry), so estimates, serve fusion and block
        serving all score the mixed moved/unmoved partitioning exactly.
        """
        self._install_base_meta(hybrid_meta)

    def complete_migration(self, plan) -> None:
        """The last move landed: snap to the target layout through the
        same path :meth:`activate` takes (bitwise the atomic end state,
        even if the target state was evicted mid-flight)."""
        self._migration = None
        d = self._delta
        if d is not None:
            # The completed target covers exactly the rows the plan
            # routed; mid-flight batches stay pending delta partitions.
            d.absorb_up_to(len(plan.target_assignment))
            self._serving = plan.target
            self._install_base_meta(plan.target_meta)
        else:
            self._activate_layout(plan.target)

    def estimate_costs(self, state_ids: Sequence[int],
                       query: wl.Query) -> Dict[int, float]:
        m = self._matrix
        if m is None:
            return super().estimate_costs(state_ids, query)
        costs = self._primed_costs(query)
        if costs is None:
            costs = m.estimate(query.lo, query.hi)
            out = {s: float(costs[m.slot(s)]) for s in state_ids}
        else:
            out = self._primed_dict(costs, state_ids)
        if self._serve_primable and self.SERVING_SHADOW in m:
            # The shadow serving state rode along in the same packed pass:
            # remember its score so serve() on this query is a lookup.
            # (exact-estimate computes only — the unguarded pallas plane
            # estimates in float32, and serve must stay exact.)
            self._serve_memo = (query,
                                float(costs[m.slot(self.SERVING_SHADOW)]))
        return out

    def estimate_vector(self, query: wl.Query) -> np.ndarray:
        # Flat re-implementation of the mixin path plus the serve-score
        # fusion of estimate_costs (numpy only), lean enough for the
        # per-event hot loop.  The primed return skips the memo update:
        # the fleet's batched driver installs the serve memo together with
        # the primed costs (see FleetEngine.run_batched), and a layout
        # activation between then and serving clears it either way.
        m = self._matrix
        primed = self._primed
        version = m.version
        if (primed is not None and primed[0] is query
                and primed[1] == version):
            return primed[2]
        costs = m.estimate(query.lo, query.hi)
        if self._serve_primable:
            shadow = self.shadow_slot(version)
            if shadow >= 0:
                self._serve_memo = (query, float(costs[shadow]))
        return costs

    def shadow_slot(self, version: int) -> int:
        """Packed slot of the serving-shadow state (-1 if absent), cached
        per plane version."""
        shadow = self._shadow_slot
        if shadow is None or shadow[0] != version:
            m = self._matrix
            slot = (m.slot(self.SERVING_SHADOW)
                    if self.SERVING_SHADOW in m else -1)
            self._shadow_slot = (version, slot)
            return slot
        return shadow[1]

    @property
    def _serve_primable(self) -> bool:
        """True when a primed shadow-slot score is a valid serve memo —
        i.e. estimation charges exact metadata scores.  ``numpy`` is exact
        by construction; ``pallas_fused`` is exact because its float32
        kernel only runs when the operands are float32-representable
        (bit-identical comparisons) and falls back to numpy otherwise."""
        return self._compute in ("numpy", "pallas_fused")

    @property
    def serve_primable(self) -> bool:
        """Deprecated alias of the internal ``_serve_primable`` flag."""
        warnings.warn("serve_primable is an internal detail of the "
                      "priming machinery; it is now _serve_primable",
                      DeprecationWarning, stacklevel=2)
        return self._serve_primable

    def serve(self, query: wl.Query) -> float:
        if self._compute == "reference":
            return float(L.eval_cost(self._serving.serving_meta(),
                                     query.lo, query.hi))
        memo = self._serve_memo
        if memo is not None and memo[0] is query:
            return memo[1]
        minsT, maxsT, rows, total = self._serving_cache
        acc = compute.masked_overlap(minsT, maxsT, query.lo, query.hi)
        return float(L.scanned_dot(acc, rows) / total)

    def serve_block(self, q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
        """Serve a (B, C) block of queries against the current layout.

        Used by ``LayoutEngine.run``'s batched fast path between layout
        swaps; each element is bit-identical to the per-query :meth:`serve`.
        """
        if self._compute == "reference":
            return np.atleast_1d(L.eval_cost(self._serving.serving_meta(),
                                             q_lo, q_hi))
        if len(q_lo) == 0:
            return np.zeros(0)
        minsT, maxsT, rows, total = self._serving_cache
        acc: Optional[np.ndarray] = None
        for c in range(minsT.shape[0]):
            term = minsT[c] <= q_hi[:, c, None]            # (B, P)
            acc = term if acc is None else np.logical_and(acc, term, out=acc)
            np.logical_and(acc, maxsT[c] >= q_lo[:, c, None], out=acc)
        if acc is None:     # zero-column table: every partition is scanned
            acc = np.ones((len(q_lo), minsT.shape[1]), dtype=bool)
        return L.scanned_dot(acc, rows) / total


class DiskBackend(_RegistryMixin):
    """On-disk backend over :class:`repro.data.partition_store.PartitionStore`.

    Every materialized layout lives in its own versioned directory under
    ``root``; :meth:`prepare` rewrites the table into a *fresh* directory on
    a background thread while queries keep scanning the old one, and
    :meth:`activate` flips the serving pointer (joining the writer first if
    the Δ-delay elapsed before the rewrite finished).  This gives the
    paper's §VI-D5 semantics for real files: reorganization cost is incurred
    at decision time, the swap is deferred, and serving is never interrupted.
    """

    def __init__(self, data: np.ndarray, root: str, compress: bool = True,
                 background: bool = True, compute: str = "numpy",
                 durable: bool = False, wal_snapshot_every: int = 64):
        self.data = data
        self.root = root
        self.compress = compress
        self.background = background
        os.makedirs(root, exist_ok=True)
        self._init_registry(compute)
        self._serving_layout: Optional[L.Layout] = None
        self._serving_store: Optional[PartitionStore] = None
        self._version = 0
        self._lock = threading.Lock()
        self._pending: Dict[int, Tuple[Optional[threading.Thread],
                                       PartitionStore, dict]] = {}
        self.initial_write_seconds = 0.0
        self.reorg_seconds: List[float] = []
        # In-flight incremental migration (see repro.engine.reorg):
        # (plan, partial target store, done mask, hybrid metadata).
        self._migration: Optional[tuple] = None
        # Streaming ingest: pending delta batches (files under deltas/).
        self._delta = None
        self._delta_dir = os.path.join(root, "deltas")
        #: Crash-safe manifest WAL (``durable=True``): every manifest
        #: mutation — initial write, layout swap, delta append, migration
        #: micro-batch — is logged *before* it takes effect, with periodic
        #: snapshots, so recovery replays to a bitwise-identical manifest.
        self.wal = None
        if durable:
            from repro.data.wal import ManifestWAL
            self.wal = ManifestWAL(os.path.join(root, "wal"),
                                   snapshot_every=wal_snapshot_every)

    # ------------------------------------------------------------------
    def _new_store(self) -> PartitionStore:
        self._version += 1
        return PartitionStore(os.path.join(self.root,
                                           f"v{self._version:05d}"))

    def deregister(self, state_id: int) -> None:
        super().deregister(state_id)
        pending = self._pending.pop(state_id, None)
        if pending is None:
            return
        thread, store, entry = pending
        # Never block serving on an in-flight rewrite whose output is being
        # discarded: flag it cancelled and let the writer thread delete its
        # own directory; only clean up here if the write already finished.
        with self._lock:
            entry["cancelled"] = True
            finished = entry["done"] or thread is None
        if finished:
            shutil.rmtree(store.root, ignore_errors=True)

    def prepare(self, state_id: int) -> None:
        if state_id in self._pending or state_id not in self._layouts:
            return
        layout = self._layouts[state_id]
        store = self._new_store()
        entry = {"done": False, "cancelled": False}

        def work() -> None:
            secs = store.write(self.data, layout, compress=self.compress)
            with self._lock:
                entry["done"] = True
                cancelled = entry["cancelled"]
            if cancelled:
                shutil.rmtree(store.root, ignore_errors=True)
            else:
                self.reorg_seconds.append(secs)

        if self.background:
            thread = threading.Thread(target=work, daemon=True)
            thread.start()
        else:
            work()
            thread = None
        self._pending[state_id] = (thread, store, entry)

    def activate(self, state_id: int) -> None:
        layout = self._layouts[state_id]
        pending = self._pending.pop(state_id, None)
        if pending is None:
            store = self._new_store()
            secs = store.write(self.data, layout, compress=self.compress)
            if self._serving_store is None:
                # First materialization: the initial table load, not a reorg.
                self.initial_write_seconds += secs
            else:
                self.reorg_seconds.append(secs)
        else:
            thread, store, _ = pending
            if thread is not None:
                thread.join()
        self._log_swap(store)
        old = self._serving_store
        self._serving_store, self._serving_layout = store, layout
        if old is not None:
            shutil.rmtree(old.root, ignore_errors=True)
        self._absorb_deltas()

    def _log_swap(self, store: PartitionStore) -> None:
        """WAL-commit a layout swap *before* the pointer flips: the record
        carries the new store's exact manifest, so replay reconstructs it
        bitwise even if the crash lands mid-flip."""
        if self.wal is None:
            return
        with open(os.path.join(store.root, "manifest.json")) as f:
            manifest = json.load(f)
        op = "init" if self._serving_store is None else "swap"
        self.wal.append({"op": op,
                         "store": os.path.basename(store.root),
                         "manifest": manifest})

    def _absorb_deltas(self) -> None:
        """A full (re)write just routed every pending delta row into the
        new clustered store: retire the delta files."""
        d = self._delta
        if d is None or not d.pending:
            return
        for batch in d.batches:
            os.remove(os.path.join(self._delta_dir,
                                   f"delta_{batch.batch_id:05d}.npz"))
        d.absorb_up_to(len(self.data))

    @property
    def serving_state(self) -> Optional[int]:
        return (None if self._serving_layout is None
                else self._serving_layout.layout_id)

    @property
    def pending_states(self) -> List[int]:
        """State ids with an in-flight (prepared) background rewrite."""
        return sorted(self._pending)

    def materializing(self, state_id: int) -> bool:
        """True while ``state_id``'s background rewrite has not finished.

        Used by fleet schedulers to observe in-flight physical work; a
        state that was never prepared, or whose write completed, is False.
        """
        pending = self._pending.get(state_id)
        if pending is None:
            return False
        _, _, entry = pending
        with self._lock:
            return not entry["done"]

    # -- streaming ingest (see repro.engine.ingest) ---------------------
    def enable_ingest(self):
        """Open the write path: appended rows land as on-disk delta files
        (``deltas/delta_*.npz``) that scans read alongside the clustered
        store until the next full (re)write absorbs them."""
        if self._delta is None:
            from .ingest import DeltaLog
            self._delta = DeltaLog(len(self.data))
            os.makedirs(self._delta_dir, exist_ok=True)
        return self._delta

    @property
    def delta_log(self):
        """The pending-delta state (None until :meth:`enable_ingest`)."""
        return self._delta

    @property
    def ingest_base_meta(self) -> Optional[L.PartitionMetadata]:
        """Zone maps of the clustered base store (manifest-derived)."""
        if self._serving_store is None:
            return None
        return self._serving_store.metadata()

    def ingest_rows(self, rows: np.ndarray):
        """Append one batch as an unclustered on-disk delta partition.

        Commit protocol (crash-safe under ``durable=True``): the delta
        file is written first, then the WAL record — the record is the
        commit point, so a crash between the two leaves an orphaned file
        that replay simply never references.
        """
        d = self._delta
        if d is None:
            raise RuntimeError("enable_ingest() first")
        start = len(self.data)
        self.data = np.concatenate([self.data, rows])
        batch = d.append(rows, start)
        fname = f"delta_{batch.batch_id:05d}.npz"
        save = np.savez_compressed if self.compress else np.savez
        save(os.path.join(self._delta_dir, fname), rows=rows)
        if self.wal is not None:
            self.wal.append({"op": "append_delta",
                             "batch_id": batch.batch_id,
                             "file": fname,
                             "mins": [float(x) for x in batch.mins],
                             "maxs": [float(x) for x in batch.maxs],
                             "rows": batch.rows})
        # Prepared stores were written against the pre-append table: their
        # output is stale.  Cancel them; activation rewrites from scratch.
        for sid in list(self._pending):
            thread, store, entry = self._pending.pop(sid)
            with self._lock:
                entry["cancelled"] = True
                finished = entry["done"] or thread is None
            if finished:
                shutil.rmtree(store.root, ignore_errors=True)
        for lay in self._layouts.values():
            lay.true_meta = None
        return batch

    @staticmethod
    def recover_state(root: str) -> dict:
        """Replay the manifest WAL under ``root`` after a crash.

        Returns the reduced manifest state (serving store + manifest,
        pending delta batches, in-flight migration) — bitwise identical,
        via :func:`repro.data.wal.canonical_manifest`, to the state an
        uninterrupted run would have logged.
        """
        from repro.data.wal import ManifestWAL
        return ManifestWAL(os.path.join(root, "wal")).replay()

    # -- incremental migration (see repro.engine.reorg) -----------------
    @property
    def serving_layout(self) -> Optional[L.Layout]:
        """The Layout object behind :attr:`serving_state`."""
        return self._serving_layout

    @property
    def supports_incremental(self) -> bool:
        return True

    @property
    def migrating(self) -> bool:
        return self._migration is not None

    def begin_migration(self, plan) -> None:
        """Open a partial target store; partition files land move by move."""
        if self._migration is not None:
            raise RuntimeError("a migration is already in flight")
        store = self._new_store()
        done = np.zeros(plan.num_target_partitions, dtype=bool)
        self._migration = (plan, store, done, None)
        if self.wal is not None:
            self.wal.append({"op": "migration_begin",
                             "store": os.path.basename(store.root),
                             "target_state": plan.target.layout_id,
                             "num_targets": plan.num_target_partitions})

    def _write_target_partition(self, plan, store: PartitionStore,
                                j: int) -> None:
        save = np.savez_compressed if self.compress else np.savez
        save(os.path.join(store.root, f"part_{j:05d}.npz"),
             rows=plan.target_partition_rows(self.data, j))

    def apply_migration(self, hybrid_meta: L.PartitionMetadata,
                        newly_done: Sequence[int]) -> None:
        """A micro-batch of moves completed: write the moved target
        partitions' files and serve the hybrid state from here on.

        Moved rows physically live in the partial target store; the old
        store's files are left untouched and their moved rows are filtered
        out logically at scan time (rewriting every touched source file
        per micro-batch would re-pay the move many times over — the same
        reasoning the skip-aware ``PartitionStore.reorganize`` applies).
        """
        plan, store, done, _ = self._migration
        for j in newly_done:
            self._write_target_partition(plan, store, j)
        if self.wal is not None:
            # Logged after the files land: a crash before this record
            # replays to the pre-batch done set, and the orphaned partition
            # files are rewritten when the moves re-run.
            self.wal.append({"op": "migration_apply",
                             "done": [int(j) for j in newly_done]})
        done[list(newly_done)] = True
        self._migration = (plan, store, done, hybrid_meta)

    def complete_migration(self, plan) -> None:
        """The last move landed: finish the target store and flip to it.

        Identical partitions (never moved) are copied file-for-file from
        the old store; remaining empty partitions get empty files; the
        manifest is the target's exact metadata.  No full rewrite happens.
        """
        _, store, done, _ = self._migration
        self._migration = None
        meta = plan.target_meta
        save = np.savez_compressed if self.compress else np.savez
        for j in range(plan.num_target_partitions):
            if done[j]:
                continue
            src = plan.identical.get(j)
            if src is not None and self._serving_store is not None:
                shutil.copyfile(
                    os.path.join(self._serving_store.root,
                                 f"part_{src:05d}.npz"),
                    os.path.join(store.root, f"part_{j:05d}.npz"))
            else:
                # Only empty target partitions reach here (every non-empty
                # non-identical partition was a planned move).
                save(os.path.join(store.root, f"part_{j:05d}.npz"),
                     rows=self.data[plan.target_assignment == j])
        write_manifest(store.root, plan.num_target_partitions,
                       meta.mins.tolist(), meta.maxs.tolist(), meta.rows,
                       plan.target.name)
        if self.wal is not None:
            self.wal.append({"op": "swap",
                             "store": os.path.basename(store.root),
                             "manifest": manifest_dict(
                                 plan.num_target_partitions,
                                 meta.mins.tolist(), meta.maxs.tolist(),
                                 meta.rows, plan.target.name)})
        old = self._serving_store
        self._serving_store, self._serving_layout = store, plan.target
        if old is not None:
            shutil.rmtree(old.root, ignore_errors=True)

    def _serve_hybrid(self, query: wl.Query) -> float:
        """Scan the hybrid state: residual source partitions (moved rows
        filtered out) + moved target partitions, skipped by the hybrid
        zone maps.  ``rows_read`` counts logical hybrid rows, matching the
        metadata cost model the simulation backends charge."""
        plan, store, done, hybrid_meta = self._migration
        scanned = L.partitions_scanned(hybrid_meta, query.lo, query.hi)
        p_s = plan.num_source_partitions
        rows_read = 0
        for p in np.nonzero(scanned)[0]:
            if p < p_s:
                path = os.path.join(self._serving_store.root,
                                    f"part_{p:05d}.npz")
                # The physical read (scan realism for wall-clock numbers);
                # the *logical* row count comes from the mask alone — no
                # filtered copy is materialized just to be measured.
                with np.load(path) as z:
                    rows_in_file = len(z["rows"])
                moved = plan.source_moved_mask(int(p), done)
                rows_read += rows_in_file - int(moved.sum())
            else:
                j = int(p) - p_s
                with np.load(os.path.join(store.root,
                                          f"part_{j:05d}.npz")) as z:
                    rows_read += len(z["rows"])
        return rows_read / max(len(self.data), 1)

    def _serve_deltas(self, query: wl.Query) -> int:
        """Rows read from pending delta files the query cannot skip."""
        d = self._delta
        if d is None or not d.pending:
            return 0
        rows_read = 0
        for batch in d.batches:
            if ((batch.mins <= query.hi) & (batch.maxs >= query.lo)).all():
                path = os.path.join(self._delta_dir,
                                    f"delta_{batch.batch_id:05d}.npz")
                with np.load(path) as z:
                    rows_read += len(z["rows"])
        return rows_read

    def serve(self, query: wl.Query) -> float:
        if self._migration is not None and self._migration[3] is not None:
            return self._serve_hybrid(query)
        _, stats = self._serving_store.scan(query)
        return ((stats.rows_read + self._serve_deltas(query))
                / max(len(self.data), 1))

    def close(self) -> None:
        """Join background writers and remove all materialized directories."""
        for state_id in list(self._pending):
            thread, store, entry = self._pending.pop(state_id)
            with self._lock:
                entry["cancelled"] = True
            if thread is not None:
                thread.join()
            shutil.rmtree(store.root, ignore_errors=True)
        if self._migration is not None:
            _, store, _, _ = self._migration
            shutil.rmtree(store.root, ignore_errors=True)
            self._migration = None
        if self._serving_store is not None:
            shutil.rmtree(self._serving_store.root, ignore_errors=True)
            self._serving_store = self._serving_layout = None
        shutil.rmtree(self._delta_dir, ignore_errors=True)
