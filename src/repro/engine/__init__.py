"""Online layout-optimization engine: stepwise loop, policies, backends.

The public API for running OREO (and every method of comparison) as an
online *service* rather than a batch simulation::

    from repro.engine import LayoutEngine, InMemoryBackend, OreoPolicy

    policy = OreoPolicy(data, initial_layout, generator, OreoConfig(alpha=80))
    engine = LayoutEngine(policy, InMemoryBackend(data), delta=policy.config.delta)
    for query in live_traffic:
        step = engine.step(query)          # serve + decide + maybe reorg
    trace = engine.result()                # RunResult, same as the old runner

Layers:

* :class:`LayoutEngine` — the shared loop (Δ-delayed swaps, cost trace).
* :class:`Policy` — decision layer: :class:`OreoPolicy`,
  :class:`GreedyPolicy`, :class:`RegretPolicy`, :class:`StaticPolicy`,
  :class:`MTSOptimalPolicy`, :class:`OfflineOptimalPolicy`.
* :class:`StorageBackend` — physical layer: :class:`InMemoryBackend`
  (vectorized numpy simulation) and :class:`DiskBackend` (versioned
  partition files with background materialization).
* :class:`StateMatrix` — the packed, incrementally-maintained metadata
  plane every registry-backed backend scores queries against, with
  pluggable compute (:func:`repro.engine.compute.scan_matrix`: ``numpy``
  exact / ``pallas`` kernel).
* :class:`FleetEngine` — multi-tenant layer: N engines over one
  interleaved stream of typed events (:class:`QueryEvent` /
  :class:`IngestEvent`, re-exported here from
  :mod:`repro.core.workload`), fed through the single
  :meth:`FleetEngine.submit` / :meth:`FleetEngine.drain` entry point
  (``run`` / ``run_batched`` are drivers over it; legacy bare
  ``(tenant_id, payload)`` tuples still coerce, with a
  :class:`DeprecationWarning`).  Physical reorganization is arbitrated
  by a :class:`ReorgScheduler` (:class:`UnlimitedScheduler` /
  :class:`KConcurrentScheduler` / :class:`TokenBucketScheduler`), with
  drift scenarios in :data:`repro.core.workload.DRIFT_SCENARIOS`.  The
  traffic-facing tier above this — admission control, load shedding,
  versioned caching — lives in :mod:`repro.serve`.
* :mod:`repro.engine.reorg` — the incremental reorganization plane:
  ``LayoutEngine(..., incremental=True)`` turns each charged
  reorganization into a planned sequence of micro-moves
  (:func:`plan_migration`) executed under a per-tick row budget
  (:class:`ReorgExecutor`), with the backends serving a *hybrid* state
  mixing moved and unmoved partitions while a migration is in flight.
  Charges are untouched (α at decision time, worst-case accounting
  intact); with an unbounded budget the traces are bit-identical to the
  atomic loop.
* :mod:`repro.engine.ingest` — the streaming ingest plane:
  ``LayoutEngine(..., ingest=IngestConfig())`` opens the write path.
  Appended rows land as unclustered **delta partitions**
  (:class:`DeltaLog`) visible to scans immediately; a :class:`DebtMeter`
  prices their *clustering debt* (realized excess scan cost over a
  hypothetical compacted table) and, past ``debt_threshold * α``, the
  engine charges a reclustering reorganization through the same
  α-charged, Δ-delayed, scheduler-arbitrated drift-reorg path —
  executed as budgeted micro-moves in incremental mode.  On
  :class:`DiskBackend`, ``durable=True`` adds a crash-safe manifest WAL
  (:class:`repro.data.wal.ManifestWAL`) that replays interrupted
  ingest/migration to a bitwise-identical manifest.
* :class:`FleetMatrix` — the packed multi-tenant decision plane behind
  :meth:`FleetEngine.run_batched`: every tenant's StateMatrix stacked
  into one ``(T, S_max, P_max, C)`` tensor family, maintained
  incrementally and scored for all tenants in one fused pass
  (:func:`repro.engine.compute.fleet_scan_matrix`: ``numpy`` exact /
  ``pallas`` kernel) with traces bit-identical to the stepwise loop.
"""
from repro.core.workload import Event, IngestEvent, QueryEvent, as_event
from repro.engine.backends import DiskBackend, InMemoryBackend, StorageBackend
from repro.engine.compute import fleet_scan_matrix, scan_matrix
from repro.engine.core import LayoutEngine, StepResult
from repro.engine.fleet import FleetEngine, FleetResult, FleetStepResult
from repro.engine.fleet_matrix import FleetMatrix
from repro.engine.ingest import DebtMeter, DeltaBatch, DeltaLog, IngestConfig
from repro.engine.policies import (BatchablePolicy, Decision, GreedyPolicy,
                                   MTSOptimalPolicy, OfflineOptimalPolicy,
                                   OreoPolicy, Policy, RegretPolicy,
                                   StaticPolicy, ThresholdSwitchPolicy)
from repro.engine.reorg import (MicroMove, MigrationPlan, MigrationRecord,
                                ReorgExecutor, plan_migration)
from repro.engine.scheduler import (KConcurrentScheduler, ReorgScheduler,
                                    TokenBucketScheduler, UnlimitedScheduler)
from repro.engine.state_matrix import StateMatrix

__all__ = [
    "BatchablePolicy",
    "DebtMeter", "Decision", "DeltaBatch", "DeltaLog", "DiskBackend",
    "Event", "FleetEngine", "FleetMatrix", "FleetResult",
    "FleetStepResult", "GreedyPolicy", "InMemoryBackend", "IngestConfig",
    "IngestEvent", "KConcurrentScheduler", "LayoutEngine",
    "MTSOptimalPolicy", "MicroMove",
    "MigrationPlan", "MigrationRecord", "OfflineOptimalPolicy", "OreoPolicy",
    "Policy", "QueryEvent", "RegretPolicy", "ReorgExecutor",
    "ReorgScheduler",
    "StateMatrix", "StaticPolicy", "StepResult", "StorageBackend",
    "ThresholdSwitchPolicy", "TokenBucketScheduler", "UnlimitedScheduler",
    "as_event", "fleet_scan_matrix", "plan_migration", "scan_matrix",
]
