"""Online layout-optimization engine: stepwise loop, policies, backends.

The public API for running OREO (and every method of comparison) as an
online *service* rather than a batch simulation::

    from repro.engine import LayoutEngine, InMemoryBackend, OreoPolicy

    policy = OreoPolicy(data, initial_layout, generator, OreoConfig(alpha=80))
    engine = LayoutEngine(policy, InMemoryBackend(data), delta=policy.config.delta)
    for query in live_traffic:
        step = engine.step(query)          # serve + decide + maybe reorg
    trace = engine.result()                # RunResult, same as the old runner

Layers:

* :class:`LayoutEngine` — the shared loop (Δ-delayed swaps, cost trace).
* :class:`Policy` — decision layer: :class:`OreoPolicy`,
  :class:`GreedyPolicy`, :class:`RegretPolicy`, :class:`StaticPolicy`,
  :class:`MTSOptimalPolicy`, :class:`OfflineOptimalPolicy`.
* :class:`StorageBackend` — physical layer: :class:`InMemoryBackend`
  (vectorized numpy simulation) and :class:`DiskBackend` (versioned
  partition files with background materialization).
* :class:`StateMatrix` — the packed, incrementally-maintained metadata
  plane every registry-backed backend scores queries against, with
  pluggable compute (:func:`repro.engine.compute.scan_matrix`: ``numpy``
  exact / ``pallas`` kernel).
"""
from repro.engine.backends import DiskBackend, InMemoryBackend, StorageBackend
from repro.engine.compute import scan_matrix
from repro.engine.core import LayoutEngine, StepResult
from repro.engine.policies import (Decision, GreedyPolicy, MTSOptimalPolicy,
                                   OfflineOptimalPolicy, OreoPolicy, Policy,
                                   RegretPolicy, StaticPolicy)
from repro.engine.state_matrix import StateMatrix

__all__ = [
    "Decision", "DiskBackend", "GreedyPolicy", "InMemoryBackend",
    "LayoutEngine", "MTSOptimalPolicy", "OfflineOptimalPolicy", "OreoPolicy",
    "Policy", "RegretPolicy", "StateMatrix", "StaticPolicy", "StepResult",
    "StorageBackend", "scan_matrix",
]
