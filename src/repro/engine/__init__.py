"""Online layout-optimization engine: stepwise loop, policies, backends.

The public API for running OREO (and every method of comparison) as an
online *service* rather than a batch simulation::

    from repro.engine import LayoutEngine, InMemoryBackend, OreoPolicy

    policy = OreoPolicy(data, initial_layout, generator, OreoConfig(alpha=80))
    engine = LayoutEngine(policy, InMemoryBackend(data), delta=policy.config.delta)
    for query in live_traffic:
        step = engine.step(query)          # serve + decide + maybe reorg
    trace = engine.result()                # RunResult, same as the old runner

Layers:

* :class:`LayoutEngine` — the shared loop (Δ-delayed swaps, cost trace).
* :class:`Policy` — decision layer: :class:`OreoPolicy`,
  :class:`GreedyPolicy`, :class:`RegretPolicy`, :class:`StaticPolicy`,
  :class:`MTSOptimalPolicy`, :class:`OfflineOptimalPolicy`.
* :class:`StorageBackend` — physical layer: :class:`InMemoryBackend`
  (vectorized numpy simulation) and :class:`DiskBackend` (versioned
  partition files with background materialization).
* :class:`StateMatrix` — the packed, incrementally-maintained metadata
  plane every registry-backed backend scores queries against, with
  pluggable compute (:func:`repro.engine.compute.scan_matrix`: ``numpy``
  exact / ``pallas`` kernel).
* :class:`FleetEngine` — multi-tenant layer: N engines over one
  interleaved stream of typed events (:class:`QueryEvent` /
  :class:`IngestEvent`, re-exported here from
  :mod:`repro.core.workload`), fed through the single
  :meth:`FleetEngine.submit` / :meth:`FleetEngine.drain` entry point
  (``run`` / ``run_batched`` are drivers over it; legacy bare
  ``(tenant_id, payload)`` tuples still coerce, with a
  :class:`DeprecationWarning`).  Physical reorganization is arbitrated
  by a :class:`ReorgScheduler` (:class:`UnlimitedScheduler` /
  :class:`KConcurrentScheduler` / :class:`TokenBucketScheduler`;
  declaratively via :class:`SchedulerSpec`), with
  drift scenarios in :data:`repro.core.workload.DRIFT_SCENARIOS`.  The
  traffic-facing tier above this — admission control, load shedding,
  versioned caching — lives in :mod:`repro.serve`.
* :class:`FleetRouter` — the sharded fleet-of-fleets
  (:mod:`repro.engine.router`): N fleet shards behind a
  consistent-hash :class:`PartitionDirectory`
  (:mod:`repro.engine.placement`), with live tenant migration that
  carries α charge ledgers bitwise and hysteresis-gated load-skew
  rebalancing.  Both :class:`FleetEngine` and :class:`FleetRouter`
  satisfy the :class:`EventSink` protocol — submit / drain / stats —
  so :class:`repro.serve.ServeFrontend` (and any other driver) sits
  over a single fleet or a routed shard set unchanged; process-
  parallel shard execution lives in :mod:`repro.launch.shard_host`.
* :mod:`repro.engine.reorg` — the incremental reorganization plane:
  ``LayoutEngine(..., incremental=True)`` turns each charged
  reorganization into a planned sequence of micro-moves
  (:func:`plan_migration`) executed under a per-tick row budget
  (:class:`ReorgExecutor`), with the backends serving a *hybrid* state
  mixing moved and unmoved partitions while a migration is in flight.
  Charges are untouched (α at decision time, worst-case accounting
  intact); with an unbounded budget the traces are bit-identical to the
  atomic loop.
* :mod:`repro.engine.ingest` — the streaming ingest plane:
  ``LayoutEngine(..., ingest=IngestConfig())`` opens the write path.
  Appended rows land as unclustered **delta partitions**
  (:class:`DeltaLog`) visible to scans immediately; a :class:`DebtMeter`
  prices their *clustering debt* (realized excess scan cost over a
  hypothetical compacted table) and, past ``debt_threshold * α``, the
  engine charges a reclustering reorganization through the same
  α-charged, Δ-delayed, scheduler-arbitrated drift-reorg path —
  executed as budgeted micro-moves in incremental mode.  On
  :class:`DiskBackend`, ``durable=True`` adds a crash-safe manifest WAL
  (:class:`repro.data.wal.ManifestWAL`) that replays interrupted
  ingest/migration to a bitwise-identical manifest.
* :mod:`repro.forecast` — the predictive decision plane:
  :class:`ForecastPolicy` wraps :class:`OreoPolicy` with workload
  forecasting (period detection + EWMA trend), online qd-tree state
  growth through the StateMatrix dynamic-state events, and α-safe
  pre-positioning moves hard-clamped to the reactive OREO envelope.
* :class:`FleetMatrix` — the packed multi-tenant decision plane behind
  :meth:`FleetEngine.run_batched`: every tenant's StateMatrix stacked
  into one ``(T, S_max, P_max, C)`` tensor family, maintained
  incrementally and scored for all tenants in one fused pass
  (:func:`repro.engine.compute.fleet_scan_matrix`: ``numpy`` exact /
  ``pallas`` kernel) with traces bit-identical to the stepwise loop.
"""
from typing import Protocol, runtime_checkable

from repro.core.workload import Event, IngestEvent, QueryEvent, as_event
from repro.engine.backends import DiskBackend, InMemoryBackend, StorageBackend
from repro.engine.compute import fleet_scan_matrix, scan_matrix
from repro.engine.core import LayoutEngine, StepResult
from repro.engine.fleet import FleetEngine, FleetResult, FleetStepResult
from repro.engine.fleet_matrix import FleetMatrix
from repro.engine.ingest import DebtMeter, DeltaBatch, DeltaLog, IngestConfig
from repro.engine.placement import (HashRing, PartitionDirectory,
                                    RebalanceConfig, ShardLoadMeter)
from repro.engine.policies import (BatchablePolicy, Decision, GreedyPolicy,
                                   MTSOptimalPolicy, OfflineOptimalPolicy,
                                   OreoPolicy, Policy, RegretPolicy,
                                   StaticPolicy, ThresholdSwitchPolicy)
from repro.engine.reorg import (MicroMove, MigrationPlan, MigrationRecord,
                                ReorgExecutor, plan_migration)
from repro.engine.router import FleetRouter
from repro.engine.scheduler import (KConcurrentScheduler, ReorgScheduler,
                                    SchedulerSpec, TokenBucketScheduler,
                                    UnlimitedScheduler, as_scheduler_spec)
from repro.engine.state_matrix import StateMatrix


def __getattr__(name: str):
    # PEP 562: the predictive plane (repro.forecast) wraps OreoPolicy and
    # imports Decision from repro.engine.policies, so its re-export here
    # must be lazy to keep either import order cycle-free.
    if name in ("ForecastPolicy", "ForecastConfig"):
        from repro import forecast as _forecast
        return getattr(_forecast, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@runtime_checkable
class EventSink(Protocol):
    """Anything that accepts typed events and processes them on demand.

    The contract the serving tier programs against, implemented by
    :class:`FleetEngine` (one fleet) and :class:`FleetRouter` (a routed
    shard set); the core surface is ``submit(event)`` → queue, ``drain``
    → process, ``stats()`` → counters.  The rest of the surface a
    driver can rely on: ``queue_depth``, ``result(name)`` for the
    merged :class:`FleetResult`, ``tenant(tenant_id)`` for the backing
    :class:`LayoutEngine`, and ``shard_fleets()`` — the concrete fleets
    behind the sink (a fleet returns ``[self]``), which is how
    :class:`repro.serve.ServeFrontend` reaches every shard's scheduler
    to shed reorg work under overload.
    """

    def submit(self, event) -> None: ...

    def drain(self, *, batched: bool = ..., compute: str = ...,
              frames_per_pass=..., collect: bool = ...): ...

    def stats(self) -> dict: ...

    @property
    def queue_depth(self) -> int: ...

    def result(self, name=None) -> FleetResult: ...

    def tenant(self, tenant_id: str) -> LayoutEngine: ...

    def shard_fleets(self): ...


__all__ = [
    "BatchablePolicy",
    "DebtMeter", "Decision", "DeltaBatch", "DeltaLog", "DiskBackend",
    "Event", "EventSink", "FleetEngine", "FleetMatrix", "FleetResult",
    "FleetRouter",
    "FleetStepResult", "ForecastConfig", "ForecastPolicy", "GreedyPolicy",
    "HashRing", "InMemoryBackend",
    "IngestConfig",
    "IngestEvent", "KConcurrentScheduler", "LayoutEngine",
    "MTSOptimalPolicy", "MicroMove",
    "MigrationPlan", "MigrationRecord", "OfflineOptimalPolicy", "OreoPolicy",
    "PartitionDirectory",
    "Policy", "QueryEvent", "RebalanceConfig", "RegretPolicy",
    "ReorgExecutor",
    "ReorgScheduler", "SchedulerSpec", "ShardLoadMeter",
    "StateMatrix", "StaticPolicy", "StepResult", "StorageBackend",
    "ThresholdSwitchPolicy", "TokenBucketScheduler", "UnlimitedScheduler",
    "as_event", "as_scheduler_spec", "fleet_scan_matrix", "plan_migration",
    "scan_matrix",
]
