"""Tenant placement for the sharded fleet: ring, directory, load meter.

The routing plane (:class:`repro.engine.router.FleetRouter`) decides
which :class:`~repro.engine.fleet.FleetEngine` shard owns each tenant
with three small, deterministic pieces:

* :class:`HashRing` — consistent hashing with virtual nodes.  Placement
  is a pure function of (tenant id, shard set): adding or removing a
  shard relocates only the tenants whose arc the change touches —
  ~1/N of them — never reshuffles the rest (the classic property the
  partitioning patterns in PAPERS.md's *Distributed Data Placement via
  Graph Partitioning* build on).
* :class:`PartitionDirectory` — explicit tenant → shard overrides
  layered over the ring.  A lookup is a pure function of
  ``(ring, overrides)``; live migrations record their destination here
  so placement survives ring arithmetic and restarts alike.
* :class:`ShardLoadMeter` — per-shard load accounting (events per
  window + queue depth) with a hysteresis trigger: past
  ``high`` imbalance it suggests moving the hottest tenant off the
  hottest shard, then re-arms only after imbalance falls below ``low``
  so a borderline fleet does not thrash tenants back and forth.

Everything here is clocked by event counters, never wall time, so
placement decisions are deterministic and replayable.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, Iterable, List, Mapping, Optional, Tuple


def _stable_hash(key: str) -> int:
    """64-bit position on the ring; stable across processes and runs.

    Python's builtin ``hash`` is salted per process (PYTHONHASHSEED), so
    a directory computed in the router process would disagree with one
    computed inside a shard worker — blake2b is not.
    """
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big")


class HashRing:
    """Consistent-hash ring over shard ids with virtual nodes.

    Each shard owns ``replicas`` points on a 64-bit ring; a key maps to
    the shard owning the first point clockwise from the key's hash.
    More replicas smooth the arc lengths (64 per shard keeps the
    largest/mean tenant-count ratio low at fleet sizes we run).
    """

    def __init__(self, shard_ids: Iterable[str] = (), replicas: int = 64):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = int(replicas)
        self._points: List[Tuple[int, str]] = []   # sorted (hash, shard)
        self._shards: Dict[str, List[int]] = {}
        for sid in shard_ids:
            self.add_shard(sid)

    @property
    def shard_ids(self) -> List[str]:
        return sorted(self._shards)

    def __len__(self) -> int:
        return len(self._shards)

    def add_shard(self, shard_id: str) -> None:
        if shard_id in self._shards:
            raise ValueError(f"shard {shard_id!r} already on the ring")
        hashes = [_stable_hash(f"{shard_id}#{i}")
                  for i in range(self.replicas)]
        self._shards[shard_id] = hashes
        for h in hashes:
            bisect.insort(self._points, (h, shard_id))

    def remove_shard(self, shard_id: str) -> None:
        self._shards.pop(shard_id)   # KeyError for unknown shards
        self._points = [(h, s) for h, s in self._points if s != shard_id]

    def lookup(self, key: str) -> str:
        """The shard owning ``key`` — pure in (key, shard set, replicas)."""
        if not self._points:
            raise ValueError("ring has no shards")
        h = _stable_hash(key)
        # (h,) sorts before every (h, shard) tuple, so a key hashing
        # exactly onto a virtual node maps to that node.
        idx = bisect.bisect_right(self._points, (h,))
        if idx == len(self._points):
            idx = 0                                 # wrap past 2**64
        return self._points[idx][1]


class PartitionDirectory:
    """Tenant → shard lookups: explicit overrides over the hash ring.

    The ring gives every tenant a default home; :meth:`assign` pins a
    tenant elsewhere (live migration, rebalancing).  ``lookup`` is a
    pure function of ``(ring, overrides)`` — no hidden state, so two
    directories built from the same parts agree on every tenant.
    """

    def __init__(self, ring: HashRing,
                 overrides: Optional[Mapping[str, str]] = None):
        self.ring = ring
        self._overrides: Dict[str, str] = dict(overrides or {})

    @property
    def overrides(self) -> Dict[str, str]:
        return dict(self._overrides)

    def lookup(self, tenant_id: str) -> str:
        override = self._overrides.get(tenant_id)
        if override is not None:
            return override
        return self.ring.lookup(tenant_id)

    def assign(self, tenant_id: str, shard_id: str) -> None:
        """Pin ``tenant_id`` to ``shard_id`` (drops a redundant pin)."""
        if self.ring.lookup(tenant_id) == shard_id:
            self._overrides.pop(tenant_id, None)
        else:
            self._overrides[tenant_id] = shard_id

    def clear(self, tenant_id: str) -> None:
        self._overrides.pop(tenant_id, None)

    def placement(self, tenant_ids: Iterable[str]) -> Dict[str, str]:
        return {tid: self.lookup(tid) for tid in tenant_ids}


@dataclasses.dataclass
class RebalanceConfig:
    """Hysteresis knobs for :class:`ShardLoadMeter`."""

    #: Events per evaluation window (the meter's clock).
    window: int = 512
    #: Trigger a move when max/mean shard load exceeds this ...
    high: float = 1.5
    #: ... and re-arm only once it falls back below this.
    low: float = 1.1
    #: Queue-depth weight relative to one window event.
    queue_weight: float = 1.0

    def __post_init__(self) -> None:
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not self.high > self.low >= 1.0:
            raise ValueError("need high > low >= 1.0 for hysteresis")


class ShardLoadMeter:
    """Per-shard load windows with a hysteresis rebalance trigger.

    Feed it one :meth:`observe` per routed event and the per-shard queue
    depths at evaluation time; every ``window`` events it computes the
    imbalance ``max(load) / mean(load)`` and, while armed and above
    ``high``, :meth:`suggest`\\ s moving the hottest tenant off the
    hottest shard onto the coldest.  After suggesting it disarms until
    imbalance falls below ``low`` — one genuine skew produces one burst
    of moves, borderline oscillation produces none.
    """

    def __init__(self, shard_ids: Iterable[str],
                 config: Optional[RebalanceConfig] = None):
        self.config = config or RebalanceConfig()
        self._events: Dict[str, int] = {sid: 0 for sid in shard_ids}
        self._tenant_events: Dict[str, Dict[str, int]] = {
            sid: {} for sid in self._events}
        self._depths: Dict[str, int] = {sid: 0 for sid in self._events}
        self._window_count = 0
        self.armed = True
        self.windows_evaluated = 0
        self.moves_suggested = 0

    def add_shard(self, shard_id: str) -> None:
        self._events.setdefault(shard_id, 0)
        self._tenant_events.setdefault(shard_id, {})
        self._depths.setdefault(shard_id, 0)

    def observe(self, shard_id: str, tenant_id: str) -> None:
        """Account one event routed to ``shard_id`` for ``tenant_id``."""
        self._events[shard_id] += 1
        per = self._tenant_events[shard_id]
        per[tenant_id] = per.get(tenant_id, 0) + 1
        self._window_count += 1

    def note_queue_depth(self, shard_id: str, depth: int) -> None:
        self._depths[shard_id] = int(depth)

    @property
    def window_complete(self) -> bool:
        return self._window_count >= self.config.window

    def loads(self) -> Dict[str, float]:
        w = self.config.queue_weight
        return {sid: self._events[sid] + w * self._depths[sid]
                for sid in self._events}

    def imbalance(self) -> float:
        loads = list(self.loads().values())
        if not loads:
            return 1.0
        mean = sum(loads) / len(loads)
        if mean <= 0:
            return 1.0
        return max(loads) / mean

    def suggest(self) -> Optional[Tuple[str, str, str]]:
        """``(tenant_id, from_shard, to_shard)`` or None.

        Evaluated once per completed window; resets the window either
        way.  Only the hysteresis-armed, above-``high`` case suggests,
        and only a move that actually helps: the hottest shard's hottest
        tenant whose load fits in the gap to the mean (moving a tenant
        hotter than the whole skew would just relocate the hotspot).
        """
        if not self.window_complete:
            return None
        self.windows_evaluated += 1
        imbalance = self.imbalance()
        loads = self.loads()
        suggestion = None
        if not self.armed and imbalance < self.config.low:
            self.armed = True
        if self.armed and imbalance > self.config.high and len(loads) > 1:
            hot = max(sorted(loads), key=lambda s: loads[s])
            cold = min(sorted(loads), key=lambda s: loads[s])
            mean = sum(loads.values()) / len(loads)
            headroom = mean - loads[cold]
            per = self._tenant_events[hot]
            movable = [t for t in sorted(per) if per[t] <= headroom]
            if movable:
                tenant = max(movable, key=lambda t: per[t])
                suggestion = (tenant, hot, cold)
                self.moves_suggested += 1
                self.armed = False
        self._reset_window()
        return suggestion

    def _reset_window(self) -> None:
        self._window_count = 0
        for sid in self._events:
            self._events[sid] = 0
            self._tenant_events[sid] = {}

    def stats(self) -> dict:
        return {
            "imbalance": float(self.imbalance()),
            "loads": self.loads(),
            "armed": self.armed,
            "windows_evaluated": self.windows_evaluated,
            "moves_suggested": self.moves_suggested,
        }


__all__ = ["HashRing", "PartitionDirectory", "RebalanceConfig",
           "ShardLoadMeter"]
