"""Layout policies: the decision layer plugged into the :class:`LayoutEngine`.

A :class:`Policy` decides, one query at a time, which layout state the system
should be in and when a reorganization is charged; the engine turns those
decisions into physical actions against a :class:`StorageBackend`.  OREO and
every method of comparison from the paper (§VI-A3, §VI-C) are expressed as
policies over the *same* shared loop — the per-method run loops that used to
live in ``repro.core.baselines`` are gone.

The predictive wrapper (:class:`repro.forecast.policy.ForecastPolicy`,
which pre-positions α-charged moves ahead of forecasted drift and grows
the qd-tree state space online) lives in :mod:`repro.forecast` and is
re-exported here lazily — it wraps an :class:`OreoPolicy` and imports
:class:`Decision` from this module, so a top-level import would be
circular.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import baselines as _baselines
from repro.core import layout_manager as lm
from repro.core import layouts, mts, oreo, predictors, sampling, workload as wl


@dataclasses.dataclass
class Decision:
    """One per-query decision emitted by a policy.

    ``state`` is the decision state the system is in while servicing the
    query.  ``reorg`` charges one reorganization (cost alpha) *now*; the
    engine applies the physical swap after its configured Δ-delay.  ``added``
    / ``removed`` report state-management events for tracing.
    """

    state: int
    reorg: bool = False
    added: List[int] = dataclasses.field(default_factory=list)
    removed: List[int] = dataclasses.field(default_factory=list)


@runtime_checkable
class Policy(Protocol):
    """Decision-layer contract consumed by :class:`repro.engine.LayoutEngine`.

    * ``name`` labels run results; ``alpha`` is the reorganization cost the
      engine charges per ``Decision.reorg``.
    * :meth:`bind` is called once before the first query: the policy
      registers its initial layout(s) with the backend and returns the state
      id the engine should activate as the initial serving layout.
    * :meth:`decide` is called once per query *before* the query is served.
      The policy may register/deregister candidate layouts on the backend
      and should use ``backend.estimate_costs`` (batched, metadata-only) for
      its decision making — never the physical table.
    * :meth:`info` contributes diagnostics to ``RunResult.info``.
    """

    name: str
    alpha: float

    def bind(self, backend) -> int: ...

    def decide(self, index: int, query: wl.Query, backend) -> Decision: ...

    def info(self) -> dict: ...


class BatchablePolicy(Policy, Protocol):
    """A policy whose decision rule can be applied to a block of frames.

    :meth:`repro.engine.FleetEngine.run_batched` resolves whole no-swap
    passes without per-event Python for fleets where every policy exposes
    :meth:`decide_frames`.  The contract:

    * **pure**: no backend mutation (register/deregister) and no policy
      state update — the engine may discard the result and replay the same
      events through per-event :meth:`Policy.decide` (it does so whenever
      any row charges a reorganization, so swap frames keep the exact
      bookkeeping path and traces stay bit-identical);
    * **bit-identical**: row ``r`` of the result must equal the
      :class:`Decision` that sequential ``decide`` calls would produce
      given the same cost vectors — the rule may only depend on the costs
      and policy state, never on the step index;
    * ``costs`` is ``(k, n_slots)`` in :class:`StateMatrix` slot order
      (exactly what ``backend.estimate_vector`` returns per query); the
      returned ``states`` is ``(k,)`` decision state ids and ``reorg`` is
      a ``(k,)`` bool mask, or ``None`` meaning "never charges".
    """

    def decide_frames(self, costs: np.ndarray, backend): ...


# ---------------------------------------------------------------------------
# OREO (the paper's full system: D-UMTS + LAYOUT MANAGER)
# ---------------------------------------------------------------------------

class OreoPolicy:
    """The paper's online loop: LayoutManager candidates + D-UMTS switching."""

    name = "OREO"

    def __init__(self, data: np.ndarray, initial_layout: layouts.Layout,
                 generator: lm.GeneratorFn,
                 config: Optional[oreo.OreoConfig] = None):
        self.config = config or oreo.OreoConfig()
        self.alpha = self.config.alpha
        self.initial_layout = initial_layout
        self.manager = lm.LayoutManager(data, generator, initial_layout,
                                        self.config.manager,
                                        seed=self.config.seed)
        self.dumts = mts.DynamicUMTS(
            alpha=self.config.alpha,
            initial_states=[initial_layout.layout_id],
            seed=self.config.seed,
            transition_fn=predictors.gamma_biased_transition(self.config.gamma),
            stay_on_phase_start=self.config.stay_on_phase_start,
        )

    def bind(self, backend) -> int:
        backend.register(self.initial_layout)
        return self.dumts.current_state

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        added, removed = self.manager.on_query(query, self.dumts.current_state)
        for sid in added:
            self.dumts.add_state(sid)
        for sid in removed:
            self.dumts.remove_state(sid)
        for sid in added:
            if sid in self.manager.store:       # not evicted in the same step
                backend.register(self.manager.store[sid])
        for sid in removed:
            backend.deregister(sid)

        # Service-cost estimates for all states known to the decision maker,
        # one batched metadata-only call; states not yet generated (deferred
        # additions) are pessimistically priced at a full scan.
        sids = set(self.dumts.states) | set(self.dumts.pending_additions)
        known = [s for s in sids if backend.has(s)]
        estimates = backend.estimate_costs(known, query)
        costs = {s: estimates.get(s, 1.0) for s in sids}

        prev_moves = self.dumts.num_moves
        state = self.dumts.observe(costs)
        return Decision(state=state, reorg=self.dumts.num_moves > prev_moves,
                        added=added, removed=removed)

    def info(self) -> dict:
        return {
            "phases": self.dumts.phase,
            "max_state_space": self.dumts.max_state_space,
            "competitive_bound": self.dumts.competitive_bound(),
            "candidates_generated": self.manager.num_generated,
            "candidates_admitted": self.manager.num_admitted,
        }


# ---------------------------------------------------------------------------
# Online baselines (same candidate cadence as OREO, different switching rule)
# ---------------------------------------------------------------------------

class GreedyPolicy:
    """Switch to any fresh candidate that beats the current layout on the
    sliding window, ignoring reorganization cost (§VI-A3)."""

    name = "Greedy"

    def __init__(self, data: np.ndarray, initial_layout: layouts.Layout,
                 generator: lm.GeneratorFn, alpha: float,
                 mgr_cfg: Optional[lm.LayoutManagerConfig] = None):
        self.data = data
        self.generator = generator
        self.alpha = alpha
        self.cfg = mgr_cfg or lm.LayoutManagerConfig()
        self.window: sampling.SlidingWindow[wl.Query] = sampling.SlidingWindow(
            self.cfg.window_size)
        self.current = initial_layout
        self.next_id = initial_layout.layout_id + 1

    def bind(self, backend) -> int:
        backend.register(self.current)
        return self.current.layout_id

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        self.window.add(query)
        added: List[int] = []
        removed: List[int] = []
        reorg = False
        if ((index + 1) % self.cfg.gen_every == 0
                and len(self.window) >= self.cfg.window_size // 2):
            qs = self.window.sample()
            cand = self.generator(self.next_id, self.data, qs,
                                  self.cfg.target_partitions)
            self.next_id += 1
            w_lo, w_hi = wl.stack_queries(qs)
            cur_cost = layouts.eval_cost(self.current.meta, w_lo, w_hi).mean()
            cand_cost = layouts.eval_cost(cand.meta, w_lo, w_hi).mean()
            if cand_cost < cur_cost:
                old = self.current.layout_id
                self.current = cand
                backend.register(cand)
                backend.deregister(old)
                added.append(cand.layout_id)
                removed.append(old)
                reorg = True
        return Decision(state=self.current.layout_id, reorg=reorg,
                        added=added, removed=removed)

    def info(self) -> dict:
        return {}


class RegretPolicy:
    """Switch once a candidate's cumulative query-cost saving over the
    current layout exceeds alpha (TASM-style, §VI-A3)."""

    name = "Regret"

    def __init__(self, data: np.ndarray, initial_layout: layouts.Layout,
                 generator: lm.GeneratorFn, alpha: float,
                 mgr_cfg: Optional[lm.LayoutManagerConfig] = None,
                 max_candidates: int = 8):
        self.data = data
        self.generator = generator
        self.alpha = alpha
        self.cfg = mgr_cfg or lm.LayoutManagerConfig()
        self.max_candidates = max_candidates
        self.window: sampling.SlidingWindow[wl.Query] = sampling.SlidingWindow(
            self.cfg.window_size)
        self.current = initial_layout
        self.next_id = initial_layout.layout_id + 1
        self.candidates: Dict[int, layouts.Layout] = {}
        self.cum_saving: Dict[int, float] = {}

    def bind(self, backend) -> int:
        backend.register(self.current)
        return self.current.layout_id

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        self.window.add(query)
        added: List[int] = []
        removed: List[int] = []
        reorg = False
        if ((index + 1) % self.cfg.gen_every == 0
                and len(self.window) >= self.cfg.window_size // 2):
            cand = self.generator(self.next_id, self.data,
                                  self.window.sample(),
                                  self.cfg.target_partitions)
            self.candidates[self.next_id] = cand
            self.cum_saving[self.next_id] = 0.0
            backend.register(cand)
            added.append(self.next_id)
            self.next_id += 1
            if len(self.candidates) > self.max_candidates:
                oldest = min(self.candidates)
                del self.candidates[oldest]
                del self.cum_saving[oldest]
                backend.deregister(oldest)
                removed.append(oldest)

        sids = [self.current.layout_id] + list(self.candidates)
        estimates = backend.estimate_costs(sids, query)
        cur_cost = estimates[self.current.layout_id]
        for sid in self.candidates:
            self.cum_saving[sid] += cur_cost - estimates[sid]
        if self.cum_saving:
            best = max(self.cum_saving, key=self.cum_saving.get)
            if self.cum_saving[best] > self.alpha:
                old = self.current.layout_id
                self.current = self.candidates.pop(best)
                self.cum_saving = {sid: 0.0 for sid in self.candidates}
                backend.deregister(old)
                removed.append(old)
                reorg = True
        return Decision(state=self.current.layout_id, reorg=reorg,
                        added=added, removed=removed)

    def info(self) -> dict:
        return {}


class ThresholdSwitchPolicy:
    """Argmin-with-hysteresis over a fixed state space, batch-decidable.

    Serves from the current state and charges a reorganization to the
    cheapest candidate whenever its estimated cost undercuts the current
    state's by more than ``threshold``.  The rule is a pure function of
    the packed cost vector, so it implements the
    :class:`BatchablePolicy` contract: :meth:`decide_frames` resolves a
    whole block of frames at once, bit-identically to sequential
    :meth:`decide` calls.  Needs a matrix-backed backend
    (``backend.estimate_vector``); candidate slots are the bind-order
    registrations ``0..S-1`` (the serving shadow, if any, registers
    after them and is never considered).
    """

    name = "threshold-switch"

    def __init__(self, state_space: List[layouts.Layout], alpha: float,
                 threshold: float = 0.0):
        if not state_space:
            raise ValueError("state_space must not be empty")
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.space = list(state_space)
        self.ids = np.asarray([lay.layout_id for lay in self.space],
                              dtype=np.int64)
        self.num = len(self.space)
        self._cur_slot = 0
        self.switches = 0

    def bind(self, backend) -> int:
        for lay in self.space:
            backend.register(lay)
        self._cur_slot = 0
        return int(self.ids[0])

    def _switch_slot(self, costs_row: np.ndarray) -> int:
        """Slot to switch to, or -1 to stay (one row of the pure rule)."""
        sub = costs_row[:self.num]
        best = int(sub.argmin())
        if sub[best] < sub[self._cur_slot] - self.threshold:
            return best
        return -1

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        costs = np.asarray(backend.estimate_vector(query))
        slot = self._switch_slot(costs)
        if slot >= 0:
            self._cur_slot = slot
            self.switches += 1
            return Decision(state=int(self.ids[slot]), reorg=True)
        return Decision(state=int(self.ids[self._cur_slot]))

    def decide_frames(self, costs: np.ndarray, backend):
        """(k, n_slots) primed costs -> (states, reorg), no side effects.

        Fast path: when no row would trigger a switch from the current
        state (the common case between drifts), the answer is one
        vectorized comparison.  Otherwise the sequential evolution is
        simulated without committing — the fleet replays the pass through
        :meth:`decide` anyway whenever any row charges.
        """
        sub = costs[:, :self.num]
        k = sub.shape[0]
        cur = self._cur_slot
        if not (sub.min(axis=1) < sub[:, cur] - self.threshold).any():
            return np.full(k, self.ids[cur], dtype=np.int64), None
        states = np.empty(k, dtype=np.int64)
        reorg = np.zeros(k, dtype=bool)
        for r in range(k):
            sub_r = sub[r]
            best = int(sub_r.argmin())
            if sub_r[best] < sub_r[cur] - self.threshold:
                cur = best
                reorg[r] = True
            states[r] = self.ids[cur]
        return states, reorg

    def info(self) -> dict:
        return {"threshold": self.threshold, "switches": self.switches}


# ---------------------------------------------------------------------------
# Offline / oracle baselines (workload knowledge)
# ---------------------------------------------------------------------------

class StaticPolicy:
    """One layout optimized for the whole workload; never switches."""

    name = "Static"

    def __init__(self, data: np.ndarray, stream: wl.WorkloadStream,
                 generator: lm.GeneratorFn, alpha: float,
                 target_partitions: int = 32,
                 layout: Optional[layouts.Layout] = None):
        self.alpha = alpha
        self.layout = layout if layout is not None else generator(
            0, data, stream.queries, target_partitions)

    def bind(self, backend) -> int:
        backend.register(self.layout)
        return self.layout.layout_id

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        return Decision(state=self.layout.layout_id)

    def info(self) -> dict:
        return {}


class MTSOptimalPolicy:
    """Fixed precomputed state space (best layout per template) + OREO's
    D-UMTS switching; no dynamic state management (§VI-C)."""

    name = "MTS Optimal"

    def __init__(self, data: np.ndarray, stream: wl.WorkloadStream,
                 generator: lm.GeneratorFn, alpha: float,
                 target_partitions: int = 32, gamma: float = 1.0,
                 seed: int = 0):
        self.alpha = alpha
        per_template = _baselines.per_template_layouts(
            data, stream, generator, target_partitions)
        self.store = {lay.layout_id: lay for lay in per_template.values()}
        self.dumts = mts.DynamicUMTS(
            alpha=alpha, initial_states=sorted(self.store), seed=seed,
            transition_fn=predictors.gamma_biased_transition(gamma))

    def bind(self, backend) -> int:
        for lay in self.store.values():
            backend.register(lay)
        return self.dumts.current_state

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        costs = backend.estimate_costs(sorted(self.store), query)
        prev_moves = self.dumts.num_moves
        state = self.dumts.observe(costs)
        return Decision(state=state, reorg=self.dumts.num_moves > prev_moves)

    def info(self) -> dict:
        return {
            "phases": self.dumts.phase,
            "max_state_space": self.dumts.max_state_space,
            "competitive_bound": self.dumts.competitive_bound(),
        }


def __getattr__(name: str):
    # PEP 562: lazy re-export of the predictive plane (avoids the
    # forecast -> policies -> forecast import cycle).
    if name in ("ForecastPolicy", "ForecastConfig"):
        from repro import forecast as _forecast
        return getattr(_forecast, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class OfflineOptimalPolicy:
    """Sees the whole stream: per-template layout, switching exactly at
    template boundaries — the lower bound for online methods (§VI-C)."""

    name = "Offline Optimal"

    def __init__(self, data: np.ndarray, stream: wl.WorkloadStream,
                 generator: lm.GeneratorFn, alpha: float,
                 target_partitions: int = 32):
        self.alpha = alpha
        per_template = _baselines.per_template_layouts(
            data, stream, generator, target_partitions)
        self.store = {lay.layout_id: lay for lay in per_template.values()}
        self._state_per_query = np.zeros(len(stream), dtype=np.int64)
        self._reorg_at: set[int] = set()
        prev_tid = None
        for start, end, tid in stream.segments:
            self._state_per_query[start:end] = per_template[tid].layout_id
            if prev_tid is not None and tid != prev_tid:
                self._reorg_at.add(start)
            prev_tid = tid

    def bind(self, backend) -> int:
        for lay in self.store.values():
            backend.register(lay)
        return int(self._state_per_query[0]) if len(self._state_per_query) \
            else min(self.store)

    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        return Decision(state=int(self._state_per_query[index]),
                        reorg=index in self._reorg_at)

    def info(self) -> dict:
        return {}
