"""Sharded fleet-of-fleets: consistent-hash routing over FleetEngine shards.

One :class:`~repro.engine.fleet.FleetEngine` scales a process to T=64
tenants; the ROADMAP's millions-of-users target needs many such shards
behind a routing plane.  :class:`FleetRouter` owns N shards and routes
typed :data:`repro.core.workload.Event` traffic to them through a
:class:`~repro.engine.placement.PartitionDirectory` (consistent-hash
ring + explicit overrides), presenting the same
:class:`repro.engine.EventSink` surface as a single fleet — submit /
drain / stats — so the serving tier (:class:`repro.serve.ServeFrontend`)
sits over either unchanged, and at one shard the router is trace-bitwise
invisible.

The three routing-plane capabilities:

* **Live tenant migration** (:meth:`FleetRouter.migrate_tenant`): the
  tenant's queued events are taken from the source shard's inbox, its
  engine detached via :meth:`FleetEngine.remove_tenant` (grants
  released, in-flight incremental migrations transplanted with their
  partially-summed charge ledgers — or finished, closing the ledger
  bitwise on α), re-attached on the target via
  :meth:`FleetEngine.add_tenant`, and the events replayed there.  α is
  charged at decision time (paper §VI-D5) and the StateMatrix plane,
  pending deltas and micro-move ledger all live on the engine object
  that moves, so per-tenant charge ledgers — and, under unlimited
  schedulers, full traces — are bitwise identical to an unsharded run.
* **Load-skew rebalancing**: with a
  :class:`~repro.engine.placement.RebalanceConfig`, a
  :class:`~repro.engine.placement.ShardLoadMeter` tracks events/window
  and queue depth per shard and, past the hysteresis threshold, moves
  the hottest movable tenant onto the coldest shard via the same
  migration path, recording the new home as a directory override.
* **Parallel shard execution**: shards share no mutable state — each
  has its own scheduler (built per shard from a
  :class:`~repro.engine.scheduler.SchedulerSpec`), its own packed
  plane, its own inbox — so they drain independently.
  :class:`repro.launch.shard_host.ProcessShardSet` runs the same
  placement over one OS process per shard (JAX device sharding via
  :mod:`repro.launch.mesh` is the accelerator-resident alternative);
  ``benchmarks/bench_router.py`` sweeps shard counts and checks the
  scaling into ``BENCH_router.json``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Optional

from repro.core import workload as wl

from .core import LayoutEngine
from .fleet import FleetEngine, FleetResult, FleetStepResult
from .placement import (HashRing, PartitionDirectory, RebalanceConfig,
                        ShardLoadMeter)
from .scheduler import SchedulerSpec, as_scheduler_spec


def shard_ids_for(num_shards: int) -> List[str]:
    """The canonical shard-id set ``["s0", ..., s{N-1}]``.

    Shard ids are placement keys on the hash ring, deliberately
    independent of the router's display name so two routers (or a
    router and a :class:`~repro.launch.shard_host.ProcessShardSet`)
    with the same shard count agree on every tenant's home.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    return [f"s{i}" for i in range(num_shards)]


class FleetRouter:
    """Routes tenant traffic across N independent FleetEngine shards.

    ``tenants`` maps tenant id → a fresh :class:`LayoutEngine`, exactly
    as for :class:`FleetEngine`; the router places each tenant on a
    shard via the consistent-hash directory and builds one fleet per
    shard, each with its own scheduler from ``scheduler``
    (a :class:`SchedulerSpec`; a bare instance is accepted through the
    single-use deprecation shim, which necessarily refuses more than
    one shard).  ``rebalance`` opts into load-skew rebalancing,
    evaluated at drain boundaries so behaviour stays deterministic and
    replayable.
    """

    def __init__(self, tenants: Mapping[str, LayoutEngine],
                 num_shards: int = 1,
                 scheduler=None,
                 name: str = "router",
                 replicas: int = 64,
                 incremental: Optional[bool] = None,
                 rebalance: Optional[RebalanceConfig] = None):
        if not tenants:
            raise ValueError("a router needs at least one tenant")
        self.name = name
        spec = (SchedulerSpec.unlimited() if scheduler is None
                else as_scheduler_spec(scheduler))
        self.scheduler_spec = spec
        modes = {tid: e.incremental for tid, e in tenants.items()}
        if incremental is None:
            if len(set(modes.values())) > 1:
                raise ValueError(
                    f"tenants mix incremental and atomic engines: {modes}")
            incremental = next(iter(modes.values()))
        self.incremental = bool(incremental)
        self.ring = HashRing(shard_ids_for(num_shards), replicas=replicas)
        self.directory = PartitionDirectory(self.ring)
        by_shard: Dict[str, Dict[str, LayoutEngine]] = {
            sid: {} for sid in self.ring.shard_ids}
        for tid, engine in tenants.items():
            by_shard[self.directory.lookup(tid)][tid] = engine
        self._shards: Dict[str, FleetEngine] = {
            sid: FleetEngine(by_shard[sid], spec.build(),
                             name=f"{name}/{sid}",
                             incremental=self.incremental)
            for sid in self.ring.shard_ids}
        self._known = set(tenants)
        self._meter = (None if rebalance is None
                       else ShardLoadMeter(self.ring.shard_ids, rebalance))
        self.migrations = 0

    # ------------------------------------------------------------------
    # Topology accessors
    # ------------------------------------------------------------------
    @property
    def shard_ids(self) -> List[str]:
        return self.ring.shard_ids

    @property
    def num_shards(self) -> int:
        return len(self._shards)

    def shard(self, shard_id: str) -> FleetEngine:
        return self._shards[shard_id]

    def shard_fleets(self) -> List[FleetEngine]:
        """Every shard's fleet, in shard-id order (EventSink surface)."""
        return [self._shards[sid] for sid in self.ring.shard_ids]

    def shard_of(self, tenant_id: str) -> str:
        if tenant_id not in self._known:
            raise KeyError(f"unknown tenant {tenant_id!r}")
        return self.directory.lookup(tenant_id)

    def tenant(self, tenant_id: str) -> LayoutEngine:
        return self._shards[self.shard_of(tenant_id)].tenant(tenant_id)

    @property
    def tenant_ids(self) -> List[str]:
        return sorted(self._known)

    def placement(self) -> Dict[str, str]:
        return self.directory.placement(sorted(self._known))

    # ------------------------------------------------------------------
    # EventSink: submit / drain / stats
    # ------------------------------------------------------------------
    def submit(self, event) -> None:
        """Route one event to its tenant's shard (nothing runs yet)."""
        ev = wl.as_event(event)
        shard_id = self.shard_of(ev.tenant_id)
        self._shards[shard_id].submit(ev)
        if self._meter is not None:
            self._meter.observe(shard_id, ev.tenant_id)

    @property
    def queue_depth(self) -> int:
        return sum(f.queue_depth for f in self._shards.values())

    def drain(self, *, batched: bool = False, compute: str = "numpy",
              frames_per_pass: Optional[int] = None,
              collect: bool = False):
        """Drain every shard, in shard-id order.

        Same contract as :meth:`FleetEngine.drain` per shard; the
        default returns the total events processed, ``collect=True``
        concatenates the shards' :class:`FleetStepResult` lists (events
        stay in submission order within each tenant — cross-shard
        interleaving is inherently shard-local).  Inline shards drain
        sequentially in this process; see
        :class:`repro.launch.shard_host.ProcessShardSet` for draining
        the same placement over parallel worker processes.  A completed
        drain is a rebalancing boundary: with a meter configured, full
        load windows are evaluated here (and only here).
        """
        meter = self._meter
        if meter is not None:
            for sid in self.ring.shard_ids:
                meter.note_queue_depth(sid, self._shards[sid].queue_depth)
        if collect:
            out: List[FleetStepResult] = []
            for sid in self.ring.shard_ids:
                out.extend(self._shards[sid].drain(collect=True))
            self.maybe_rebalance()
            return out
        n = 0
        for sid in self.ring.shard_ids:
            n += self._shards[sid].drain(batched=batched, compute=compute,
                                         frames_per_pass=frames_per_pass)
        self.maybe_rebalance()
        return n

    def stats(self) -> dict:
        return {
            "name": self.name,
            "num_shards": self.num_shards,
            "tenants": len(self._known),
            "queue_depth": self.queue_depth,
            "migrations": self.migrations,
            "overrides": len(self.directory.overrides),
            "shards": {sid: self._shards[sid].stats()
                       for sid in self.ring.shard_ids},
            "rebalancer": (None if self._meter is None
                           else self._meter.stats()),
        }

    # ------------------------------------------------------------------
    # Drivers (same shapes as FleetEngine's)
    # ------------------------------------------------------------------
    def run(self, events: Iterable[wl.Event],
            name: Optional[str] = None) -> FleetResult:
        for event in events:
            self.submit(event)
        self.drain()
        return self.result(name)

    def run_batched(self, events: Iterable[wl.Event],
                    name: Optional[str] = None, compute: str = "numpy",
                    frames_per_pass: Optional[int] = None) -> FleetResult:
        for event in events:
            self.submit(event)
        self.drain(batched=True, compute=compute,
                   frames_per_pass=frames_per_pass)
        return self.result(name)

    def result(self, name: Optional[str] = None) -> FleetResult:
        """Merged fleet trace across shards.

        At one shard this is exactly the shard's own
        :meth:`FleetEngine.result` (the 1-shard router is trace-bitwise
        a plain fleet); with more, per-tenant traces union (tenants
        live on exactly one shard), fleet counters sum, and the
        per-shard scheduler stats nest under ``"shards"``.
        """
        if self.num_shards == 1:
            only = next(iter(self._shards.values()))
            return only.result(name or self.name)
        per_tenant = {}
        ticks = deferred = deferred_ticks = 0
        shard_stats = {}
        sched_name = ""
        for sid in self.ring.shard_ids:
            r = self._shards[sid].result()
            per_tenant.update(r.per_tenant)
            ticks += r.ticks
            deferred += r.swaps_deferred
            deferred_ticks += r.deferred_ticks
            shard_stats[sid] = r.scheduler_stats
            sched_name = r.scheduler
        return FleetResult(
            name=name or self.name,
            scheduler=sched_name,
            per_tenant=per_tenant,
            ticks=ticks,
            swaps_deferred=deferred,
            deferred_ticks=deferred_ticks,
            scheduler_stats={"shards": shard_stats},
        )

    # ------------------------------------------------------------------
    # Live migration + rebalancing
    # ------------------------------------------------------------------
    def migrate_tenant(self, tenant_id: str, target_shard: str,
                       finish: bool = False) -> bool:
        """Move a tenant between shards, mid-flight, without losing a bit.

        Handoff order: queued events out of the source inbox, engine
        detached (grants released; an in-flight incremental migration
        travels with its partially-summed ledger, or — ``finish=True`` —
        completes first, closing the ledger on α at the current index),
        engine re-attached on the target, events replayed there, and the
        directory updated so subsequent submits route to the new home.
        Returns False for a tenant already on ``target_shard``.
        """
        if target_shard not in self._shards:
            raise KeyError(f"unknown shard {target_shard!r}")
        source_shard = self.shard_of(tenant_id)
        if source_shard == target_shard:
            return False
        source = self._shards[source_shard]
        target = self._shards[target_shard]
        inbox = source.take_inbox(tenant_id)
        engine = source.remove_tenant(tenant_id, finish=finish)
        target.add_tenant(tenant_id, engine)
        for ev in inbox:
            target.submit(ev)
        self.directory.assign(tenant_id, target_shard)
        self.migrations += 1
        return True

    def maybe_rebalance(self) -> Optional[tuple]:
        """One hysteresis-gated rebalancing step (drain boundaries only).

        Evaluates the load meter's completed window, if any; on a
        suggestion, migrates that tenant and returns the
        ``(tenant_id, from_shard, to_shard)`` move.  Without a
        configured meter (or with an incomplete window) this is a no-op
        returning None.
        """
        meter = self._meter
        if meter is None or not meter.window_complete:
            return None
        suggestion = meter.suggest()
        if suggestion is None:
            return None
        tenant_id, source_shard, target_shard = suggestion
        if (tenant_id not in self._known
                or self.directory.lookup(tenant_id) != source_shard):
            return None
        self.migrate_tenant(tenant_id, target_shard)
        return suggestion


__all__ = ["FleetRouter", "shard_ids_for"]
