"""Shared reorganization-work schedulers for multi-tenant fleets.

A warehouse serving many tables cannot rewrite all of them at once: physical
reorganization competes for a shared maintenance budget (cf. Snowflake's
incremental reclustering).  A :class:`ReorgScheduler` is the fleet-wide
arbiter of that budget: each charged reorganization must *acquire* one unit
of physical work before its background materialization may start, and
*releases* it when the swap takes effect.

Deferral never changes what a tenant is charged — the decision layer runs
unmodified and reorganization cost is incurred at decision time exactly as
in the single-tenant loop — it only delays when the physical swap lands,
and never before the tenant's own Δ-delay has elapsed.

Schedulers are deliberately tiny state machines driven by the fleet clock
(one tick per interleaved query event):

* :class:`UnlimitedScheduler` — every acquire granted immediately; a fleet
  under it is bit-identical, per tenant, to running each engine alone.
* :class:`KConcurrentScheduler` — at most ``k`` reorganizations in flight
  (acquired and not yet swapped) across all tenants.
* :class:`TokenBucketScheduler` — a refillable budget: each reorganization
  costs one token, ``rate`` tokens drip in per tick up to ``capacity``.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable


@runtime_checkable
class ReorgScheduler(Protocol):
    """Fleet-wide admission control for physical reorganization work.

    * :meth:`tick` advances the scheduler's clock; called once per fleet
      event before any acquire attempt at that tick.
    * :meth:`try_acquire` asks to start one unit of physical work for a
      tenant; True grants it.  The fleet guarantees per-tenant FIFO: it
      never requests a grant for a tenant's later swap while an earlier
      one is still waiting.
    * :meth:`release` returns a granted unit once the swap has taken
      effect (or the target state was evicted and the swap skipped).
    """

    name: str

    def tick(self, now: int) -> None: ...

    def try_acquire(self, tenant_id: str) -> bool: ...

    def release(self, tenant_id: str) -> None: ...


class _StatsMixin:
    """Grant/denial counters shared by the concrete schedulers.

    ``grants`` counts distinct granted work units.  ``denied_attempts``
    counts *acquire attempts* that were refused — the fleet re-polls every
    waiting swap each tick, so this scales with time spent waiting, not
    with distinct swaps; for per-swap deferral counts see
    :attr:`repro.engine.FleetResult.swaps_deferred`.
    """

    grants: int
    denied_attempts: int

    def _init_stats(self) -> None:
        self.grants = 0
        self.denied_attempts = 0

    def _count(self, granted: bool) -> bool:
        if granted:
            self.grants += 1
        else:
            self.denied_attempts += 1
        return granted

    def stats(self) -> dict:
        return {"scheduler": self.name, "grants": self.grants,
                "denied_attempts": self.denied_attempts}


class UnlimitedScheduler(_StatsMixin):
    """No contention: physical work starts the moment it is charged.

    The golden scheduler — a fleet under it reproduces each tenant's
    standalone trace bit for bit.
    """

    name = "unlimited"

    def __init__(self) -> None:
        self._init_stats()

    def tick(self, now: int) -> None:
        pass

    def try_acquire(self, tenant_id: str) -> bool:
        return self._count(True)

    def release(self, tenant_id: str) -> None:
        pass


class KConcurrentScheduler(_StatsMixin):
    """At most ``k`` reorganizations in flight fleet-wide.

    A reorganization is in flight from the tick its work is granted until
    the tick its swap takes effect; with ``k=1`` the fleet serializes all
    physical reorganization onto one maintenance worker.
    """

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = k
        self.name = f"k{k}"
        self.in_flight = 0
        self._init_stats()

    def tick(self, now: int) -> None:
        pass

    def try_acquire(self, tenant_id: str) -> bool:
        if self.in_flight < self.k:
            self.in_flight += 1
            return self._count(True)
        return self._count(False)

    def release(self, tenant_id: str) -> None:
        if self.in_flight > 0:
            self.in_flight -= 1


class TokenBucketScheduler(_StatsMixin):
    """Token-bucket reorganization budget.

    ``rate`` tokens accrue per fleet tick up to ``capacity``; each granted
    reorganization consumes one whole token.  ``rate=0`` with an initial
    burst models a fixed budget; fractional rates model "one reorg every
    1/rate queries fleet-wide".
    """

    def __init__(self, rate: float, capacity: float,
                 initial: float | None = None):
        if rate < 0 or capacity < 0:
            raise ValueError("rate and capacity must be >= 0")
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.tokens = float(capacity if initial is None else initial)
        self.name = f"bucket{rate:g}x{capacity:g}"
        self._now = 0
        self._init_stats()

    def tick(self, now: int) -> None:
        elapsed = max(now - self._now, 0)
        self._now = now
        self.tokens = min(self.capacity, self.tokens + self.rate * elapsed)

    def try_acquire(self, tenant_id: str) -> bool:
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return self._count(True)
        return self._count(False)

    def release(self, tenant_id: str) -> None:
        pass
