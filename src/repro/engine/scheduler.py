"""Shared reorganization-work schedulers for multi-tenant fleets.

A warehouse serving many tables cannot rewrite all of them at once: physical
reorganization competes for a shared maintenance budget (cf. Snowflake's
incremental reclustering).  A :class:`ReorgScheduler` is the fleet-wide
arbiter of that budget: each charged reorganization must *acquire* one unit
of physical work before its background materialization may start, and
*releases* it when the swap takes effect.

Deferral never changes what a tenant is charged — the decision layer runs
unmodified and reorganization cost is incurred at decision time exactly as
in the single-tenant loop — it only delays when the physical swap lands,
and never before the tenant's own Δ-delay has elapsed.

Schedulers are deliberately tiny state machines driven by the fleet clock
(one tick per interleaved query event):

* :class:`UnlimitedScheduler` — every acquire granted immediately; a fleet
  under it is bit-identical, per tenant, to running each engine alone.
* :class:`KConcurrentScheduler` — at most ``k`` reorganizations in flight
  (acquired and not yet swapped) across all tenants.
* :class:`TokenBucketScheduler` — a refillable budget: each reorganization
  costs one token, ``rate`` tokens drip in per tick up to ``capacity``.

Schedulers are *stateful* and therefore per-fleet: two shards sharing one
instance would share its token bucket and in-flight counts, silently
coupling budgets that must be independent.  :class:`SchedulerSpec` is the
declarative form — ``spec.build()`` mints a fresh scheduler per shard —
and the canonical way to configure a sharded
:class:`repro.engine.router.FleetRouter`; passing a bare instance where a
spec is expected still works through :func:`as_scheduler_spec`'s
single-use deprecation shim.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Protocol, runtime_checkable


@runtime_checkable
class ReorgScheduler(Protocol):
    """Fleet-wide admission control for physical reorganization work.

    * :meth:`tick` advances the scheduler's clock; called once per fleet
      event before any acquire attempt at that tick.
    * :meth:`try_acquire` asks to start one unit of physical work for a
      tenant; True grants it.  The fleet guarantees per-tenant FIFO: it
      never requests a grant for a tenant's later swap while an earlier
      one is still waiting.
    * :meth:`release` returns a granted unit once the swap has taken
      effect (or the target state was evicted and the swap skipped).
      Under an *incremental* fleet (see :mod:`repro.engine.reorg`) the
      unit is instead held for the whole migration — from the step its
      moves begin until the step the target layout takes over — so
      e.g. :class:`KConcurrentScheduler` bounds concurrent migrations.
    * :meth:`grant_rows` turns the grant into a *row budget*: an engine
      holding a granted unit asks, each tick, how many rows its in-flight
      migration may move now.  The default (and the behavior of every
      scheduler without a tighter rule) is to grant the full request, so
      atomic semantics — swap permission only — are the degenerate case.
    """

    name: str

    def tick(self, now: int) -> None: ...

    def try_acquire(self, tenant_id: str) -> bool: ...

    def release(self, tenant_id: str) -> None: ...

    def grant_rows(self, tenant_id: str, want: int) -> int: ...


class _StatsMixin:
    """Grant/denial counters shared by the concrete schedulers.

    ``grants`` counts distinct granted work units.  ``denied_attempts``
    counts *acquire attempts* that were refused — the fleet re-polls every
    waiting swap each tick, so this scales with time spent waiting, not
    with distinct swaps; for per-swap deferral counts see
    :attr:`repro.engine.FleetResult.swaps_deferred`.
    """

    grants: int
    denied_attempts: int

    def _init_stats(self) -> None:
        self.grants = 0
        self.denied_attempts = 0

    def _count(self, granted: bool) -> bool:
        if granted:
            self.grants += 1
        else:
            self.denied_attempts += 1
        return granted

    def stats(self) -> dict:
        return {"scheduler": self.name, "grants": self.grants,
                "denied_attempts": self.denied_attempts}


class UnlimitedScheduler(_StatsMixin):
    """No contention: physical work starts the moment it is charged.

    The golden scheduler — a fleet under it reproduces each tenant's
    standalone trace bit for bit.
    """

    name = "unlimited"

    def __init__(self) -> None:
        self._init_stats()

    def tick(self, now: int) -> None:
        pass

    def try_acquire(self, tenant_id: str) -> bool:
        return self._count(True)

    def release(self, tenant_id: str) -> None:
        pass

    def grant_rows(self, tenant_id: str, want: int) -> int:
        return want


class KConcurrentScheduler(_StatsMixin):
    """At most ``k`` reorganizations in flight fleet-wide.

    A reorganization is in flight from the tick its work is granted until
    the tick its swap takes effect; with ``k=1`` the fleet serializes all
    physical reorganization onto one maintenance worker.
    """

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = k
        self.name = f"k{k}"
        self.in_flight = 0
        self._init_stats()

    def tick(self, now: int) -> None:
        pass

    def try_acquire(self, tenant_id: str) -> bool:
        if self.in_flight < self.k:
            self.in_flight += 1
            return self._count(True)
        return self._count(False)

    def release(self, tenant_id: str) -> None:
        if self.in_flight > 0:
            self.in_flight -= 1

    def grant_rows(self, tenant_id: str, want: int) -> int:
        # Concurrency is this scheduler's budget axis: a migration holding
        # one of the k units moves as fast as its engine allows.
        return want


class TokenBucketScheduler(_StatsMixin):
    """Token-bucket reorganization budget.

    ``rate`` tokens accrue per fleet tick up to ``capacity``; each granted
    reorganization consumes one whole token.  ``rate=0`` with an initial
    burst models a fixed budget; fractional rates model "one reorg every
    1/rate queries fleet-wide".

    With ``rows_per_token`` set, the bucket is denominated in *rows* for
    incremental fleets (see :mod:`repro.engine.reorg`): admission is free
    (:meth:`try_acquire` always grants, so migrations *start* on their
    Δ-due step) and :meth:`grant_rows` meters how many rows may move per
    tick — one token buys ``rows_per_token`` rows, so the bucket models a
    shared maintenance bandwidth of ``rate * rows_per_token`` rows/tick
    instead of "one wholesale swap every 1/rate ticks".
    """

    def __init__(self, rate: float, capacity: float,
                 initial: float | None = None,
                 rows_per_token: float | None = None):
        if rate < 0 or capacity < 0:
            raise ValueError("rate and capacity must be >= 0")
        if rows_per_token is not None and rows_per_token <= 0:
            raise ValueError("rows_per_token must be positive (None = "
                             "swap-permission mode)")
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.tokens = float(capacity if initial is None else initial)
        self.rows_per_token = rows_per_token
        self.name = (f"bucket{rate:g}x{capacity:g}" if rows_per_token is None
                     else f"bucket{rate:g}x{capacity:g}rows{rows_per_token:g}")
        self._now = 0
        self._init_stats()

    def tick(self, now: int) -> None:
        elapsed = max(now - self._now, 0)
        self._now = now
        self.tokens = min(self.capacity, self.tokens + self.rate * elapsed)

    def try_acquire(self, tenant_id: str) -> bool:
        if self.rows_per_token is not None:
            # Row-denominated bucket: pacing happens in grant_rows.
            return self._count(True)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return self._count(True)
        return self._count(False)

    def release(self, tenant_id: str) -> None:
        pass

    def grant_rows(self, tenant_id: str, want: int) -> int:
        if self.rows_per_token is None:
            return want
        granted = min(int(want), int(self.tokens * self.rows_per_token))
        if granted > 0:
            self.tokens -= granted / self.rows_per_token
        return granted


# ---------------------------------------------------------------------------
# Declarative scheduler configuration (one fresh instance per shard)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """A scheduler *recipe*: :meth:`build` mints a fresh instance.

    Shards of a :class:`repro.engine.router.FleetRouter` each need their
    own :class:`ReorgScheduler` (the instances are stateful), so the
    router takes a spec and calls ``spec.build()`` per shard.
    :class:`repro.engine.fleet.FleetEngine` accepts a spec anywhere it
    accepts an instance.  Use the classmethod constructors::

        SchedulerSpec.unlimited()
        SchedulerSpec.k_concurrent(2)
        SchedulerSpec.token_bucket(rate=0.1, capacity=4.0)
    """

    kind: str
    params: tuple = ()          # sorted (name, value) pairs, hash-stable

    @classmethod
    def unlimited(cls) -> "SchedulerSpec":
        return cls("unlimited")

    @classmethod
    def k_concurrent(cls, k: int = 1) -> "SchedulerSpec":
        return cls("k_concurrent", (("k", int(k)),))

    @classmethod
    def token_bucket(cls, rate: float, capacity: float,
                     initial: Optional[float] = None,
                     rows_per_token: Optional[float] = None
                     ) -> "SchedulerSpec":
        return cls("token_bucket", (("capacity", float(capacity)),
                                    ("initial", initial),
                                    ("rate", float(rate)),
                                    ("rows_per_token", rows_per_token)))

    def build(self) -> ReorgScheduler:
        kwargs: Dict[str, Any] = dict(self.params)
        factory = _SPEC_KINDS.get(self.kind)
        if factory is None:
            raise ValueError(f"unknown scheduler kind {self.kind!r} "
                             f"(one of {sorted(_SPEC_KINDS)})")
        return factory(**kwargs)

    @property
    def name(self) -> str:
        """The name the built scheduler will carry (for labels/results)."""
        return self.build().name


_SPEC_KINDS = {
    "unlimited": UnlimitedScheduler,
    "k_concurrent": KConcurrentScheduler,
    "token_bucket": TokenBucketScheduler,
}


class _SingleUseSpec(SchedulerSpec):
    """Deprecation shim: a live instance masquerading as a spec.

    Hands out the wrapped instance exactly once — a second ``build()``
    means two shards would share mutable scheduler state, which is the
    bug :class:`SchedulerSpec` exists to prevent, so it raises instead.
    """

    def __init__(self, instance: ReorgScheduler):
        object.__setattr__(self, "kind", f"instance:{instance.name}")
        object.__setattr__(self, "params", ())
        object.__setattr__(self, "_instance", instance)

    def build(self) -> ReorgScheduler:
        instance = object.__getattribute__(self, "_instance")
        if instance is None:
            raise ValueError(
                "this ReorgScheduler instance was already handed to a "
                "shard; schedulers are stateful and cannot be shared — "
                "pass a SchedulerSpec so each shard builds its own")
        object.__setattr__(self, "_instance", None)
        return instance

    @property
    def name(self) -> str:
        instance = object.__getattribute__(self, "_instance")
        return self.kind if instance is None else instance.name


def as_scheduler_spec(scheduler, warn: bool = True) -> SchedulerSpec:
    """Coerce a spec-or-instance argument into a :class:`SchedulerSpec`.

    Specs pass through; a bare :class:`ReorgScheduler` instance is
    wrapped in a single-use spec (with a :class:`DeprecationWarning`
    when ``warn`` — the multi-shard call sites where sharing would be a
    real bug warn, :class:`~repro.engine.fleet.FleetEngine` itself keeps
    accepting instances silently since one fleet owning one instance is
    still well-defined).
    """
    if isinstance(scheduler, SchedulerSpec):
        return scheduler
    if isinstance(scheduler, ReorgScheduler):
        if warn:
            warnings.warn(
                "passing a ReorgScheduler instance where a SchedulerSpec "
                "is expected is deprecated: instances are stateful and "
                "single-use across shards — pass e.g. "
                "SchedulerSpec.k_concurrent(2) instead",
                DeprecationWarning, stacklevel=3)
        return _SingleUseSpec(scheduler)
    raise TypeError(f"expected a SchedulerSpec or ReorgScheduler, got "
                    f"{type(scheduler).__name__}")
