"""Delta partitions: unclustered append batches visible to scans at once.

Streaming ingest lands rows *without* routing them through the serving
layout: each :meth:`DeltaLog.append` becomes one **delta partition** with
exact zone maps, stacked on top of the clustered base table's metadata by
:meth:`DeltaLog.compose`.  Scans see appended rows immediately (the
composed zone maps are installed as the backend's serving state, so the
packed StateMatrix / FleetMatrix planes score delta-bearing tenants in the
same fused pass), but skipping over deltas is poor by construction — a
batch's bounds span whatever arrived — which is exactly the *clustering
debt* the decision plane meters (:mod:`repro.engine.ingest.debt`).

``clustered_len`` tracks the prefix of the backing table covered by the
serving layout's clustering; everything beyond it lives in delta batches.
A reorganization (atomic activate, or an incremental compaction planned
over the deltas) *absorbs* batches: :meth:`absorb_up_to` drops every batch
the rewrite covered.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import layouts as L


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One ingest batch: a [start, end) row range with exact zone maps."""

    batch_id: int
    start: int
    end: int
    mins: np.ndarray        # (C,)
    maxs: np.ndarray        # (C,)

    @property
    def rows(self) -> int:
        return self.end - self.start


class DeltaLog:
    """Pending delta batches over a growing table."""

    def __init__(self, clustered_len: int):
        self.clustered_len = int(clustered_len)
        self.batches: List[DeltaBatch] = []
        self._next_id = 0
        #: Bumped whenever batches are absorbed (consumers reset caches).
        self.generation = 0

    @property
    def pending(self) -> bool:
        return bool(self.batches)

    @property
    def num_batches(self) -> int:
        return len(self.batches)

    @property
    def delta_rows(self) -> int:
        return sum(b.rows for b in self.batches)

    def append(self, rows: np.ndarray, start: int) -> DeltaBatch:
        """Record one appended batch occupying ``[start, start+len)``."""
        if rows.ndim != 2 or len(rows) == 0:
            raise ValueError("an ingest batch must be a non-empty (N, C) "
                             "array")
        batch = DeltaBatch(batch_id=self._next_id, start=int(start),
                           end=int(start) + len(rows),
                           mins=rows.min(axis=0), maxs=rows.max(axis=0))
        self._next_id += 1
        self.batches.append(batch)
        return batch

    def compose(self, base: L.PartitionMetadata) -> L.PartitionMetadata:
        """Base zone maps + one partition per pending delta batch.

        With no pending batches this returns ``base`` itself (the same
        object), so an ingest-enabled engine that never ingests serves
        bit-identically to one without ingest.
        """
        if not self.batches:
            return base
        d_mins = np.stack([b.mins for b in self.batches])
        d_maxs = np.stack([b.maxs for b in self.batches])
        d_rows = np.array([float(b.rows) for b in self.batches])
        return L.PartitionMetadata(
            mins=np.concatenate([base.mins, d_mins]),
            maxs=np.concatenate([base.maxs, d_maxs]),
            rows=np.concatenate([base.rows, d_rows]))

    def source_assignment(self, base_assignment: np.ndarray,
                          num_base_partitions: int,
                          total_len: int) -> Optional[np.ndarray]:
        """Row -> partition assignment of the composed (hybrid) source.

        Base rows keep their clustered assignment; batch ``k``'s rows map
        to pseudo-partition ``num_base_partitions + k`` — the layout the
        migration planner diffs a compaction (or a delta-bearing drift
        reorg) against.  Rows beyond the last batch (none in practice:
        every appended row is logged) are unreachable.
        """
        if not self.batches:
            return None
        out = np.empty(total_len, dtype=np.int64)
        out[:self.clustered_len] = base_assignment
        for k, b in enumerate(self.batches):
            out[b.start:b.end] = num_base_partitions + k
        return out

    def absorb_up_to(self, length: int) -> None:
        """A rewrite clustered rows [0, length): drop the covered batches."""
        self.batches = [b for b in self.batches if b.start >= length]
        self.clustered_len = max(self.clustered_len, int(length))
        self.generation += 1


__all__ = ["DeltaBatch", "DeltaLog"]
