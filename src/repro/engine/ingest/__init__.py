"""Streaming ingest plane: delta partitions, clustering debt, compaction.

``LayoutEngine(..., ingest=IngestConfig())`` opens the write path: rows
appended through :meth:`repro.engine.LayoutEngine.ingest` land in
unclustered **delta partitions** (:class:`DeltaLog`) that are visible to
scans immediately — their zone maps ride the existing StateMatrix
listener events, so FleetMatrix keeps scoring delta-bearing tenants in
the fused pass.  A :class:`DebtMeter` folds the resulting *clustering
debt* into the decision plane: once the workload's realized excess scan
cost crosses ``debt_threshold * α``, the engine charges a reclustering
reorganization through the same α-charged, Δ-delayed, scheduler-
arbitrated path drift reorgs take, and (in incremental mode) the PR-5
:class:`repro.engine.reorg.ReorgExecutor` executes the compaction as
budgeted micro-moves with the bitwise-α charge ledger intact.
"""
from .debt import DebtMeter, IngestConfig
from .delta import DeltaBatch, DeltaLog

__all__ = ["DebtMeter", "DeltaBatch", "DeltaLog", "IngestConfig"]
