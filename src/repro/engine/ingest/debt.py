"""Clustering debt: metering what unclustered deltas cost the workload.

Every served query pays the *composed* serving state's scan cost — base
partitions plus wide-bounded delta partitions.  The **debt meter** tracks
the excess of that realized cost over the cost the same query would have
paid against a hypothetical *compacted* table (delta rows routed through
the serving layout and merged into its partitions' zone maps):

    debt += max(0, c(composed, q) - c(compacted, q))

The compacted zone maps are maintained incrementally — O(B*C) per append,
never a re-route of the whole table — so the meter stays metadata-only,
like every other decision-plane estimate.

Compaction triggering is the same amortization argument OREO's D-UMTS
layer applies to drift reorgs: reclustering is worth its α charge once
the workload has *demonstrated* at least ``debt_threshold * α`` of excess
scan cost under the recent query window.  ``debt_threshold=1.0`` is the
worst-case-safe default (pay α only after α of damage — total compaction
spend is bounded by realized excess), ``0.0`` degenerates to
always-recluster, and disabling auto-compaction gives never-recluster;
the benchmark (``benchmarks/bench_ingest.py``) runs all three arms.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import layouts as L


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Engine-level ingest behaviour.

    ``auto_compact`` — fold clustering debt into the decision plane: when
    the meter crosses ``debt_threshold * α`` the engine charges a
    reclustering reorg (α at decision time, Δ-delayed swap, scheduler
    arbitration — the drift-reorg machinery, one shared budget).
    ``debt_threshold`` — multiples of α the debt must reach; ``0.0``
    compacts at the first delta-touching query, ``float("inf")`` never.
    """

    auto_compact: bool = True
    debt_threshold: float = 1.0


class DebtMeter:
    """Incrementally-maintained clustering-debt accumulator."""

    def __init__(self):
        self.debt = 0.0
        #: Zone maps of the hypothetical compacted table (base layout with
        #: delta rows routed in); None while no deltas are pending.
        self._compacted: Optional[L.PartitionMetadata] = None
        #: Lifetime counters (benchmarks / traces).
        self.total_excess = 0.0
        self.compactions_triggered = 0

    @property
    def active(self) -> bool:
        return self._compacted is not None

    # -- maintenance ---------------------------------------------------
    def on_append(self, base_meta: L.PartitionMetadata, rows: np.ndarray,
                  assignment: np.ndarray) -> None:
        """Merge one routed batch into the compacted zone maps (O(B*C))."""
        current = self._compacted if self._compacted is not None else base_meta
        p = current.num_partitions
        batch = L.metadata_from_assignment(rows, assignment, p)
        self._compacted = L.PartitionMetadata(
            mins=np.minimum(current.mins, batch.mins),
            maxs=np.maximum(current.maxs, batch.maxs),
            rows=current.rows + batch.rows)

    def reset(self) -> None:
        """Deltas were absorbed (compaction or drift reorg): debt is paid."""
        self.debt = 0.0
        self._compacted = None

    # -- metering ------------------------------------------------------
    def observe(self, query_cost: float, q_lo: np.ndarray,
                q_hi: np.ndarray) -> float:
        """Accrue one served query's excess cost; returns the increment."""
        if self._compacted is None:
            return 0.0
        ideal = float(L.eval_cost(self._compacted, q_lo, q_hi))
        excess = max(0.0, query_cost - ideal)
        self.debt += excess
        self.total_excess += excess
        return excess

    def triggered(self, alpha: float, config: IngestConfig) -> bool:
        """Should a reclustering reorg be charged now?"""
        if not config.auto_compact or self._compacted is None:
            return False
        return self.debt >= config.debt_threshold * alpha


__all__ = ["DebtMeter", "IngestConfig"]
