"""Pluggable compute backends for the metadata plane's scan matrix.

Everything the decision loop evaluates — service-cost estimates over all
candidate states, cost vectors over the R-TBS sample — reduces to the
(Q, P) interval-overlap *scan matrix* over C columns.  This module is the
single entry point for computing it:

* ``numpy`` (default): exact float64 comparisons; bit-identical to
  :func:`repro.core.layouts.partitions_scanned`.
* ``pallas``: the TPU kernel :func:`repro.kernels.pruning.scan_matrix_pallas`
  (compiled on TPU/GPU, interpreter on CPU — auto-selected).  Operands are
  cast to float32 on the way in; when any bound would not survive that
  cast exactly the call warns and falls back to the exact numpy path
  (:func:`float32_exact` is the check), so the kernel path never silently
  changes results.
* ``pallas_fused``: the decision megakernel
  (:func:`repro.kernels.decision_fused.decision_fused.fused_decision_pallas`)
  — the same overlap semantics, but one operand pass produces the scan
  matrix for a whole block of query frames (plus cost and move-frequency
  outputs for callers that want them).  Same float32 guard as ``pallas``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

BACKENDS = ("numpy", "pallas", "pallas_fused")


def float32_exact(*arrays: np.ndarray) -> bool:
    """True iff every value survives a float64 -> float32 round-trip.

    ``±inf`` round-trips exactly; a finite bound like ``nextafter(1, 2)``
    does not — the Pallas kernels cast operands to float32, so only
    float32-exact inputs keep the kernel paths bit-identical to the
    float64 numpy comparisons.
    """
    for a in arrays:
        a = np.asarray(a)
        if a.dtype == np.float32:
            continue
        if not np.array_equal(a, a.astype(np.float32).astype(a.dtype)):
            return False
    return True


def _f32_guard(name: str, *arrays: np.ndarray) -> bool:
    """Warn and return False when a kernel path must fall back to numpy."""
    if float32_exact(*arrays):
        return True
    warnings.warn(
        f"{name}: bounds are not exactly float32-representable; the pallas "
        f"kernel's float32 cast would silently change the scan matrix — "
        f"falling back to the exact numpy path",
        RuntimeWarning, stacklevel=3)
    return False


def scan_matrix(q_lo: np.ndarray, q_hi: np.ndarray, mins: np.ndarray,
                maxs: np.ndarray, backend: str = "numpy") -> np.ndarray:
    """(Q, C) query bounds x (P, C) partition bounds -> (Q, P) bool.

    ``out[q, p]`` is True iff partition p must be scanned for query q, i.e.
    every column's [min, max] zone overlaps the query's [lo, hi] range.
    """
    if backend == "numpy":
        overlap = ((mins[None, :, :] <= q_hi[:, None, :])
                   & (maxs[None, :, :] >= q_lo[:, None, :]))
        return overlap.all(axis=-1)
    if backend in ("pallas", "pallas_fused"):
        if not _f32_guard("scan_matrix", q_lo, q_hi, mins, maxs):
            return scan_matrix(q_lo, q_hi, mins, maxs, backend="numpy")
        if backend == "pallas":
            return _scan_matrix_pallas(q_lo, q_hi, mins, maxs)
        return _scan_matrix_fused(q_lo, q_hi, mins, maxs)
    raise ValueError(f"unknown compute backend: {backend!r} "
                     f"(expected one of {BACKENDS})")


def masked_overlap(minsT: np.ndarray, maxsT: np.ndarray, q_lo: np.ndarray,
                   q_hi: np.ndarray) -> np.ndarray:
    """Exact overlap test over column-major bounds, one query at a time.

    ``minsT``/``maxsT`` are ``(C, ..., P)`` (leading column axis; the rest
    broadcasts — ``(C, P)`` for a single layout, ``(C, S, P)`` for a packed
    plane).  Columns whose query bound is infinite are skipped outright:
    ``min <= +inf`` and ``max >= -inf`` are identically True, so skipping
    cannot change the result — it is bit-identical to the full comparison.
    This is the single implementation behind StateMatrix estimation and
    InMemoryBackend serving; their cross-path bit-identity rests on it.
    """
    acc: Optional[np.ndarray] = None
    for c in (q_hi != np.inf).nonzero()[0].tolist():
        term = minsT[c] <= q_hi[c]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    for c in (q_lo != -np.inf).nonzero()[0].tolist():
        term = maxsT[c] >= q_lo[c]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    if acc is None:     # fully unbounded query: every partition is scanned
        acc = np.ones(minsT.shape[1:], dtype=bool)
    return acc


def fleet_masked_overlap(minsT: np.ndarray, maxsT: np.ndarray,
                         q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
    """Exact overlap test for a whole fleet, one query *per tenant*.

    ``minsT``/``maxsT`` are ``(C, T, S, P)`` — the transposed packed fleet
    plane, each column of one tenant a contiguous ``(S, P)`` block
    compared against that tenant's scalar bound (long contiguous runs keep
    numpy's fast comparison loops engaged) — and ``q_lo``/``q_hi`` are
    ``(T, C)`` or ``(B, T, C)``: one bound pair per tenant row, optionally
    for a block of B query *frames*.  Returns bool ``(T, S, P)`` (or
    ``(B, T, S, P)``): tenant t's ``[..., t, :, :]`` slice is bit-identical
    to :func:`masked_overlap` over t's own ``(C, S, P)`` plane with t's
    query, because a column only ever adds ``min <= +inf`` /
    ``max >= -inf`` terms (identically True) for tenants unbounded on it,
    and columns unbounded for *every* tenant and frame are skipped
    outright.
    """
    single = q_lo.ndim == 2
    if single:
        q_lo = q_lo[None]
        q_hi = q_hi[None]
    flat_hi = q_hi.reshape(-1, q_hi.shape[-1])
    flat_lo = q_lo.reshape(-1, q_lo.shape[-1])
    acc: Optional[np.ndarray] = None
    for c in np.nonzero(~(flat_hi == np.inf).all(axis=0))[0].tolist():
        term = minsT[c][None] <= q_hi[:, :, c, None, None]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    for c in np.nonzero(~(flat_lo == -np.inf).all(axis=0))[0].tolist():
        term = maxsT[c][None] >= q_lo[:, :, c, None, None]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    if acc is None:     # every tenant fully unbounded: scan everything
        acc = np.ones((q_lo.shape[0],) + minsT.shape[1:], dtype=bool)
    return acc[0] if single else acc


def fleet_scan_matrix(q_lo: np.ndarray, q_hi: np.ndarray, mins: np.ndarray,
                      maxs: np.ndarray, backend: str = "numpy") -> np.ndarray:
    """(T, C) per-tenant bounds x (T, N, C) packed bounds -> (T, N) bool.

    The fused fleet-wide scan: every tenant's candidate states are scored
    against that tenant's query in one pass.  ``numpy`` is exact float64;
    ``pallas`` routes through :func:`repro.kernels.fleet_scan.fleet_scan.
    scan_fleet_pallas` (float32 — see the module docstring caveat).
    """
    if backend == "numpy":
        overlap = ((mins <= q_hi[:, None, :]) & (maxs >= q_lo[:, None, :]))
        return overlap.all(axis=-1)
    if backend in ("pallas", "pallas_fused"):
        if not _f32_guard("fleet_scan_matrix", q_lo, q_hi, mins, maxs):
            return fleet_scan_matrix(q_lo, q_hi, mins, maxs,
                                     backend="numpy")
        if backend == "pallas":
            return _fleet_scan_pallas(q_lo, q_hi, mins, maxs)
        return np.asarray(fused_frames_scan(
            q_lo[None], q_hi[None], mins[:, None, :, :],
            maxs[:, None, :, :]))[0, :, 0, :]
    raise ValueError(f"unknown compute backend: {backend!r} "
                     f"(expected one of {BACKENDS})")


def _fleet_scan_pallas(q_lo, q_hi, mins, maxs) -> np.ndarray:
    import jax.numpy as jnp

    from repro.kernels.fleet_scan import fleet_scan

    out = fleet_scan.scan_fleet_pallas(
        jnp.asarray(q_lo, jnp.float32), jnp.asarray(q_hi, jnp.float32),
        jnp.asarray(mins, jnp.float32), jnp.asarray(maxs, jnp.float32))
    return np.asarray(out) > 0.5


def _scan_matrix_pallas(q_lo, q_hi, mins, maxs) -> np.ndarray:
    import jax.numpy as jnp

    from repro.kernels.pruning import pruning

    out = pruning.scan_matrix_pallas(
        jnp.asarray(q_lo, jnp.float32), jnp.asarray(q_hi, jnp.float32),
        jnp.asarray(mins, jnp.float32), jnp.asarray(maxs, jnp.float32))
    return np.asarray(out) > 0.5


def fused_frames_scan(q_lo: np.ndarray, q_hi: np.ndarray, p_min: np.ndarray,
                      p_max: np.ndarray) -> np.ndarray:
    """(B, T, C) frame bounds x (T, S, P, C) plane -> (B, T, S, P) bool.

    One megakernel launch scores every frame of a batched pass for every
    tenant — the ``pallas_fused`` replacement for B separate
    :func:`fleet_scan_matrix` calls.  Operands are cast to float32;
    callers owning the bit-identity contract must check
    :func:`float32_exact` first (see ``FleetMatrix._scanned_all``).
    """
    import jax.numpy as jnp

    from repro.kernels.decision_fused import decision_fused

    scan, _, _ = decision_fused.fused_decision_pallas(
        jnp.asarray(q_lo, jnp.float32), jnp.asarray(q_hi, jnp.float32),
        jnp.asarray(p_min, jnp.float32), jnp.asarray(p_max, jnp.float32))
    return np.asarray(scan) > 0.5


def _scan_matrix_fused(q_lo, q_hi, mins, maxs) -> np.ndarray:
    # (Q, C) x (P, C) through the megakernel: Q query frames of a single
    # tenant whose plane has one state of P partitions.
    out = fused_frames_scan(q_lo[:, None, :], q_hi[:, None, :],
                            mins[None, None, :, :], maxs[None, None, :, :])
    return out[:, 0, 0, :]
