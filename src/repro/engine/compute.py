"""Pluggable compute backends for the metadata plane's scan matrix.

Everything the decision loop evaluates — service-cost estimates over all
candidate states, cost vectors over the R-TBS sample — reduces to the
(Q, P) interval-overlap *scan matrix* over C columns.  This module is the
single entry point for computing it:

* ``numpy`` (default): exact float64 comparisons; bit-identical to
  :func:`repro.core.layouts.partitions_scanned`.
* ``pallas``: the TPU kernel :func:`repro.kernels.pruning.scan_matrix_pallas`
  (compiled on TPU/GPU, interpreter on CPU — auto-selected).  Operands are
  cast to float32 on the way in, so results are exact only for
  float32-representable bounds; use it for throughput on accelerators, not
  for the bit-identical decision paths.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

BACKENDS = ("numpy", "pallas")


def scan_matrix(q_lo: np.ndarray, q_hi: np.ndarray, mins: np.ndarray,
                maxs: np.ndarray, backend: str = "numpy") -> np.ndarray:
    """(Q, C) query bounds x (P, C) partition bounds -> (Q, P) bool.

    ``out[q, p]`` is True iff partition p must be scanned for query q, i.e.
    every column's [min, max] zone overlaps the query's [lo, hi] range.
    """
    if backend == "numpy":
        overlap = ((mins[None, :, :] <= q_hi[:, None, :])
                   & (maxs[None, :, :] >= q_lo[:, None, :]))
        return overlap.all(axis=-1)
    if backend == "pallas":
        return _scan_matrix_pallas(q_lo, q_hi, mins, maxs)
    raise ValueError(f"unknown compute backend: {backend!r} "
                     f"(expected one of {BACKENDS})")


def masked_overlap(minsT: np.ndarray, maxsT: np.ndarray, q_lo: np.ndarray,
                   q_hi: np.ndarray) -> np.ndarray:
    """Exact overlap test over column-major bounds, one query at a time.

    ``minsT``/``maxsT`` are ``(C, ..., P)`` (leading column axis; the rest
    broadcasts — ``(C, P)`` for a single layout, ``(C, S, P)`` for a packed
    plane).  Columns whose query bound is infinite are skipped outright:
    ``min <= +inf`` and ``max >= -inf`` are identically True, so skipping
    cannot change the result — it is bit-identical to the full comparison.
    This is the single implementation behind StateMatrix estimation and
    InMemoryBackend serving; their cross-path bit-identity rests on it.
    """
    acc: Optional[np.ndarray] = None
    for c in (q_hi != np.inf).nonzero()[0].tolist():
        term = minsT[c] <= q_hi[c]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    for c in (q_lo != -np.inf).nonzero()[0].tolist():
        term = maxsT[c] >= q_lo[c]
        acc = term if acc is None else np.logical_and(acc, term, out=acc)
    if acc is None:     # fully unbounded query: every partition is scanned
        acc = np.ones(minsT.shape[1:], dtype=bool)
    return acc


def _scan_matrix_pallas(q_lo, q_hi, mins, maxs) -> np.ndarray:
    import jax.numpy as jnp

    from repro.kernels.pruning import pruning

    out = pruning.scan_matrix_pallas(
        jnp.asarray(q_lo, jnp.float32), jnp.asarray(q_hi, jnp.float32),
        jnp.asarray(mins, jnp.float32), jnp.asarray(maxs, jnp.float32))
    return np.asarray(out) > 0.5
