"""The stepwise online loop: one engine, pluggable policies and backends.

``LayoutEngine.step(query)`` interleaves the three concerns of Figure 1 for a
single query — decision (policy), physical reorganization (backend, with the
paper's §VI-D5 Δ-delay between charging a reorg and the swap taking effect),
and serving — and returns a :class:`StepResult`.  ``run(stream)`` produces
the same :class:`repro.core.oreo.RunResult` trace the legacy batch runner
did; when the backend supports block serving it pre-stacks the stream's
query bounds and evaluates serve costs in blocks between layout swaps (the
decision loop stays strictly per-query), which is bit-identical to stepping
because decisions never depend on realized serve costs.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.core import oreo as _oreo
from repro.core import workload as wl

from .backends import StorageBackend
from .ingest import DebtMeter, IngestConfig
from .policies import Decision, Policy


@dataclasses.dataclass
class StepResult:
    """Everything observable about one query's pass through the loop."""

    index: int
    query: wl.Query
    query_cost: float               # fraction of records accessed serving it
    decision_state: int             # state per the decision maker
    serving_state: Optional[int]    # physically materialized state
    reorg_charged: bool             # alpha charged at this query
    states_added: List[int]
    states_removed: List[int]
    decide_seconds: float
    reorg_seconds: float            # prepare + any swap applied this query
    serve_seconds: float


class LayoutEngine:
    """Drives a :class:`Policy` against a :class:`StorageBackend`, query by
    query.  Single-use and stateful: feed it one logical stream (via
    :meth:`step` or :meth:`run`) and read the trace with :meth:`result`.
    """

    def __init__(self, policy: Policy, backend: StorageBackend,
                 delta: int = 0, name: Optional[str] = None,
                 governor: Optional[object] = None,
                 incremental: bool = False,
                 rows_per_tick: Optional[int] = None,
                 reorg_window: int = 64,
                 reorg_compute: str = "numpy",
                 ingest: Optional[IngestConfig] = None):
        self.policy = policy
        self.backend = backend
        self.delta = delta
        self.name = name or policy.name
        self.alpha = policy.alpha
        #: Incremental reorganization mode (see :mod:`repro.engine.reorg`):
        #: instead of one wholesale swap at the Δ-due step, a charged
        #: reorganization becomes a planned migration executed a
        #: micro-batch at a time under a per-tick row budget
        #: (``rows_per_tick``, None = unbounded; a fleet scheduler with
        #: ``grant_rows`` can tighten it further).  Charges are untouched
        #: — α still lands at decision time — and with an unbounded budget
        #: the trace is bit-identical to the atomic loop.
        self.incremental = bool(incremental)
        self.reorg_executor = None
        if self.incremental:
            if not getattr(backend, "supports_incremental", False):
                raise ValueError(
                    "incremental=True needs a backend with hybrid-serving "
                    "support (InMemoryBackend compute='reference' serves "
                    "straight off the layout object)")
            from .reorg import ReorgExecutor
            self.reorg_executor = ReorgExecutor(
                backend, rows_per_tick=rows_per_tick,
                recent_window=reorg_window, compute=reorg_compute)
        elif rows_per_tick is not None:
            raise ValueError("rows_per_tick requires incremental=True")
        #: Optional reorg governor (see :mod:`repro.engine.scheduler`): an
        #: object with ``on_charge(engine, index, state_id) -> bool`` (may
        #: physical work start now?) and ``may_apply(engine, due_index,
        #: state_id) -> bool`` (may the due swap take effect now?).  None —
        #: the standalone default — starts work at charge time and applies
        #: every swap the moment it is due, i.e. the paper's single-tenant
        #: Δ-delay semantics.  A governor can only *defer* physical work,
        #: never advance it, so per-tenant Δ-delay bounds are preserved.
        self.governor = governor
        #: Streaming ingest (see :mod:`repro.engine.ingest`): rows appended
        #: through :meth:`ingest` land as unclustered delta partitions
        #: visible to scans immediately; a :class:`DebtMeter` accrues the
        #: workload's excess scan cost over a hypothetical compacted table
        #: and, once it crosses ``debt_threshold * α``, the engine charges
        #: a reclustering reorganization through the exact drift-reorg
        #: path (α at decision time, Δ-delayed swap, governor arbitration,
        #: and — in incremental mode — budgeted micro-move execution).
        self.ingest_config = ingest
        self._debt: Optional[DebtMeter] = None
        self._delta_generation = 0
        #: Decision indices where a debt-triggered compaction was charged
        #: (a subset of the trace's ``reorg_indices``).
        self.compaction_indices: List[int] = []
        self.ingested_rows = 0
        if ingest is not None:
            enable = getattr(backend, "enable_ingest", None)
            if enable is None:
                raise ValueError(
                    f"ingest needs a backend with streaming-ingest support "
                    f"({type(backend).__name__} has no enable_ingest)")
            if self.incremental and getattr(backend, "delta_source",
                                            None) is None:
                raise ValueError(
                    "incremental=True ingest needs a backend exposing the "
                    "hybrid delta source for compaction planning "
                    "(delta_source); use atomic mode with "
                    f"{type(backend).__name__}")
            enable()
            self._debt = DebtMeter()
        self._started = False
        self._index = 0
        self._query_costs: List[float] = []
        self._reorg_indices: List[int] = []
        self._state_seq: List[int] = []
        # (effective_idx, sid); appended in index order, drained from the
        # front — a deque keeps the drain O(1) per swap.
        self._pending_swaps: Deque[Tuple[int, int]] = collections.deque()
        self._decide_seconds = 0.0
        self._reorg_seconds = 0.0
        self._serve_seconds = 0.0

    # ------------------------------------------------------------------
    def start(self) -> None:
        """Bind the policy and materialize the initial serving layout."""
        if self._started:
            return
        initial_state = self.policy.bind(self.backend)
        self.backend.activate(initial_state)
        self._started = True

    # -- streaming ingest (see repro.engine.ingest) ---------------------
    def ingest(self, rows: np.ndarray):
        """Append one batch of rows as an unclustered delta partition.

        The rows are visible to scans from the very next query (the
        backend composes their exact zone maps onto the serving state);
        the debt meter starts tracking what their lack of clustering
        costs.  Does not advance the query index — ingest events and
        queries are independent positions in a mixed stream.  Returns the
        backend's :class:`repro.engine.ingest.DeltaBatch`.
        """
        if self.ingest_config is None:
            raise RuntimeError(
                "this engine was built without ingest support (pass "
                "ingest=IngestConfig() to LayoutEngine)")
        rows = np.asarray(rows, dtype=np.float64)
        self.start()
        self._sync_debt()
        backend = self.backend
        migrating = bool(getattr(backend, "migrating", False))
        base = backend.ingest_base_meta
        serving = backend.serving_layout
        batch = backend.ingest_rows(rows)
        self.ingested_rows += len(rows)
        if not migrating:
            # Mid-migration appends stay out of the meter until the
            # migration completes and _sync_debt rebuilds against the new
            # base (the generation bump at completion triggers it).
            assignment = (serving.route(rows)
                          if serving is not None and serving.route is not None
                          else np.zeros(len(rows), dtype=np.int64))
            self._debt.on_append(base, rows,
                                 np.asarray(assignment, dtype=np.int64))
        return batch

    def _sync_debt(self) -> None:
        """Re-anchor the debt meter after any delta absorption.

        Absorptions bump the :class:`DeltaLog` generation (atomic
        activation, migration begin/complete); the meter then resets —
        debt is considered paid by the rewrite — and rebuilds its
        compacted zone maps from whichever batches are *still* pending
        against the new base.
        """
        d = getattr(self.backend, "delta_log", None)
        if d is None or d.generation == self._delta_generation:
            return
        self._delta_generation = d.generation
        self._debt.reset()
        if getattr(self.backend, "migrating", False) or not d.pending:
            return
        base = self.backend.ingest_base_meta
        serving = self.backend.serving_layout
        for b in d.batches:
            rows = self.backend.data[b.start:b.end]
            assignment = (serving.route(rows)
                          if serving is not None and serving.route is not None
                          else np.zeros(len(rows), dtype=np.int64))
            self._debt.on_append(base, rows,
                                 np.asarray(assignment, dtype=np.int64))

    def _maybe_compact(self, i: int) -> None:
        """Charge a debt-triggered reclustering through the drift-reorg
        path.  Deferred while any swap or migration is in flight — the
        debt keeps accruing and re-triggers at the next clean step."""
        self._sync_debt()
        if self._pending_swaps or getattr(self.backend, "migrating", False):
            return
        if not self._debt.triggered(self.alpha, self.ingest_config):
            return
        sid = self.backend.serving_state
        if sid is None or not self.backend.has(sid):
            return
        self._debt.compactions_triggered += 1
        self.compaction_indices.append(i)
        self._charge_reorg(i, Decision(state=sid, reorg=True))

    def ingest_stats(self) -> dict:
        """Ingest-plane counters (kept out of :meth:`result`'s trace so
        ingest-disabled traces stay bit-comparable)."""
        d = getattr(self.backend, "delta_log", None)
        meter = self._debt
        return {
            "ingested_rows": int(self.ingested_rows),
            "pending_batches": 0 if d is None else d.num_batches,
            "pending_rows": 0 if d is None else d.delta_rows,
            "clustering_debt": 0.0 if meter is None else float(meter.debt),
            "total_excess": (0.0 if meter is None
                             else float(meter.total_excess)),
            "compactions": list(self.compaction_indices),
        }

    # ------------------------------------------------------------------
    def _charge_reorg(self, i: int, decision: Decision) -> None:
        """Bookkeeping for a charged reorganization (shared by step/run).

        The cost is charged at decision time (paper §VI-D5); the physical
        swap lands Δ queries later.  Backends may overlap the wait with
        background materialization started by ``prepare``.
        """
        if decision.reorg:
            self._reorg_indices.append(i)
            granted = (self.governor is None
                       or self.governor.on_charge(self, i, decision.state))
            if granted and not self.incremental:
                # Incremental mode never pre-materializes: physical work
                # happens at apply time, a micro-batch per tick.
                self.backend.prepare(decision.state)
            self._pending_swaps.append((i + self.delta, decision.state))

    def _apply_due_swaps(self, i: int) -> None:
        """Apply any swap whose background reorganization has finished; a
        state evicted while its swap was in flight is skipped.  Swaps apply
        strictly in charge order: a due swap the governor keeps deferred
        blocks everything queued behind it.

        In incremental mode "applying" a live swap *begins* a migration,
        and this step's row budget is spent on it right away — so with an
        unbounded budget several due swaps can begin, complete and
        activate within one step, exactly like the atomic loop applies
        them back to back.  Under a finite budget an in-flight migration
        blocks later swaps until it completes (those waits are migration-
        queue time, not scheduler deferral, and are not counted in the
        deferral stats).  Evicted states are skipped through the same
        bookkeeping as the atomic path.
        """
        executor = self.reorg_executor
        if executor is not None:
            # Governors that predate the incremental hooks (only the
            # documented on_charge/may_apply pair) still work: may_apply's
            # release-on-grant semantics are the degenerate hold.
            may_begin = (None if self.governor is None else getattr(
                self.governor, "may_begin", self.governor.may_apply))
            while True:
                if executor.active is not None:
                    executor.advance(self, i)
                    if executor.active is not None:
                        return              # tick budget exhausted
                if not (self._pending_swaps
                        and self._pending_swaps[0][0] <= i):
                    return
                due, sid = self._pending_swaps[0]
                if may_begin is not None and not may_begin(self, due, sid):
                    return
                self._pending_swaps.popleft()
                if self.backend.has(sid):
                    executor.begin(self, sid, i, charged_at=due - self.delta)
        while self._pending_swaps and self._pending_swaps[0][0] <= i:
            due, sid = self._pending_swaps[0]
            if (self.governor is not None
                    and not self.governor.may_apply(self, due, sid)):
                break
            self._pending_swaps.popleft()
            if self.backend.has(sid):
                self.backend.activate(sid)

    @property
    def pending_swaps(self) -> Tuple[Tuple[int, int], ...]:
        """Charged-but-not-yet-applied swaps as (due_index, state_id)."""
        return tuple(self._pending_swaps)

    def finish_migration(self) -> None:
        """Drive any in-flight incremental migration to completion now.

        The finish half of the fleet's finish-or-transplant detach
        (:meth:`repro.engine.FleetEngine.remove_tenant`): the remaining
        micro-moves land at the *current* index under an unmetered
        budget, so the migration's charge ledger closes bitwise on α
        right here instead of travelling with the engine.  No-op when
        idle or atomic.
        """
        executor = self.reorg_executor
        if executor is None or executor.active is None:
            return
        saved_governor = self.governor
        saved_cap = executor.rows_per_tick
        self.governor = None            # no grant_rows metering
        executor.rows_per_tick = None
        try:
            executor.advance(self, self._index)
        finally:
            self.governor = saved_governor
            executor.rows_per_tick = saved_cap
        assert executor.active is None, \
            "unbounded advance must complete the migration"

    def _step_core(self, query: wl.Query):
        """The decide/charge/swap/serve sequence shared by :meth:`step`
        and :meth:`step_fast` — one implementation so the two entry points
        can never drift apart (the fleet's loop/batched bit-identity
        rests on that)."""
        self.start()
        i = self._index
        executor = self.reorg_executor
        if executor is not None:
            executor.observe(query)
        if self._debt is not None:
            self._maybe_compact(i)
        t0 = time.perf_counter()
        decision = self.policy.decide(i, query, self.backend)
        t1 = time.perf_counter()
        self._charge_reorg(i, decision)
        self._apply_due_swaps(i)        # incremental: also spends the
        t2 = time.perf_counter()        # step's migration row budget
        query_cost = float(self.backend.serve(query))
        t3 = time.perf_counter()
        if self._debt is not None:
            self._sync_debt()
            self._debt.observe(query_cost, query.lo, query.hi)
        self._query_costs.append(query_cost)
        self._state_seq.append(decision.state)
        self._index += 1
        decide, reorg, serve = t1 - t0, t2 - t1, t3 - t2
        self._decide_seconds += decide
        self._reorg_seconds += reorg
        self._serve_seconds += serve
        return i, decision, query_cost, decide, reorg, serve

    def step(self, query: wl.Query) -> StepResult:
        """Advance the online loop by one query."""
        i, decision, query_cost, decide, reorg, serve = \
            self._step_core(query)
        return StepResult(
            index=i,
            query=query,
            query_cost=query_cost,
            decision_state=decision.state,
            serving_state=self.backend.serving_state,
            reorg_charged=decision.reorg,
            states_added=decision.added,
            states_removed=decision.removed,
            decide_seconds=decide,
            reorg_seconds=reorg,
            serve_seconds=serve,
        )

    def step_fast(self, query: wl.Query) -> float:
        """One query through the loop without materializing a StepResult.

        Identical decide/charge/swap/serve sequence and identical trace to
        :meth:`step` (same :meth:`_step_core`) — only the per-step
        observation object is skipped, for batch drivers
        (:meth:`repro.engine.FleetEngine.run_batched`) that read the trace
        from :meth:`result` instead.  Returns the query cost.
        """
        return self._step_core(query)[2]

    # ------------------------------------------------------------------
    def result(self, name: Optional[str] = None) -> _oreo.RunResult:
        """Trace of every query stepped so far, as a legacy RunResult."""
        return _oreo.RunResult(
            name=name or self.name,
            alpha=self.alpha,
            query_costs=np.asarray(self._query_costs),
            reorg_indices=list(self._reorg_indices),
            state_seq=np.asarray(self._state_seq, dtype=np.int64),
            info=dict(self.policy.info()),
            decide_seconds=self._decide_seconds,
            reorg_seconds=self._reorg_seconds,
            serve_seconds=self._serve_seconds,
        )

    def run(self, stream: wl.WorkloadStream, name: Optional[str] = None,
            batch_serve: Optional[bool] = None) -> _oreo.RunResult:
        """Step every query of ``stream`` and return the trace.

        When the backend exposes ``serve_block`` (``batch_serve=None`` auto-
        detects; pass False to force the stepwise loop), serve costs are
        evaluated in blocks of consecutive queries served by the same
        physical layout: the per-query decision loop runs unchanged, serves
        are deferred, and each block is flushed right before a layout swap
        takes effect.  The resulting trace is bit-identical to stepping.
        """
        queries = list(stream)
        has_block = callable(getattr(self.backend, "serve_block", None))
        if self.ingest_config is not None:
            # Debt metering consumes every realized serve cost in step
            # order, and a debt-triggered compaction can swap the layout
            # at any step — both break the swap-aligned block flushing.
            if batch_serve:
                raise ValueError(
                    "batch_serve=True is incompatible with ingest (debt "
                    "metering is per-step)")
            batch_serve = False
        if self.incremental:
            # Hybrid serving can change the layout at *any* step a
            # micro-batch lands, not only at pending-swap applies, so the
            # swap-aligned block flushing below would serve stale blocks.
            if batch_serve:
                raise ValueError(
                    "batch_serve=True is incompatible with incremental=True"
                    " (hybrid updates land between swaps)")
            batch_serve = False
        elif batch_serve is None:
            batch_serve = has_block
        elif batch_serve and not has_block:
            raise ValueError(
                "batch_serve=True requires a backend with serve_block")
        if not batch_serve:
            for query in queries:
                self.step(query)
            return self.result(name)
        if not queries:
            return self.result(name)
        self.start()
        q_lo, q_hi = wl.stack_queries(queries)
        costs = np.empty(len(queries))
        block = 0
        for k, query in enumerate(queries):
            i = self._index
            t0 = time.perf_counter()
            decision = self.policy.decide(i, query, self.backend)
            t1 = time.perf_counter()
            self._charge_reorg(i, decision)
            flush = 0.0
            if self._pending_swaps and self._pending_swaps[0][0] <= i:
                # Flush the open serve block before the swap changes the
                # serving layout (a step serves *after* applying due swaps,
                # so query k itself belongs to the next block).
                if k > block:
                    ts = time.perf_counter()
                    costs[block:k] = self.backend.serve_block(
                        q_lo[block:k], q_hi[block:k])
                    flush = time.perf_counter() - ts
                block = k
                self._apply_due_swaps(i)
            t2 = time.perf_counter()
            self._state_seq.append(decision.state)
            self._index += 1
            self._decide_seconds += t1 - t0
            self._reorg_seconds += t2 - t1 - flush
            self._serve_seconds += flush
        ts = time.perf_counter()
        costs[block:] = self.backend.serve_block(q_lo[block:], q_hi[block:])
        self._serve_seconds += time.perf_counter() - ts
        self._query_costs.extend(float(c) for c in costs)
        return self.result(name)
