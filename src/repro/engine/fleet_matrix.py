"""FleetMatrix: one packed decision plane for every tenant in a fleet.

:class:`repro.engine.state_matrix.StateMatrix` made the *single-table* hot
path hardware-shaped: persistent packed zone maps, one fused op per query.
A fleet of T tenants still pays T separate passes per round of traffic —
one ``estimate`` per tenant engine, each a handful of numpy calls over tiny
operands, so fleet throughput scales with Python call count instead of
with hardware.  :class:`FleetMatrix` stacks every tenant's plane into one
``(T, S_max, P_max, C)`` tensor family and scores *all* tenants' candidate
states against *each tenant's own* current query in a single fused pass
(:func:`repro.engine.compute.fleet_scan_matrix`: exact numpy, or the
Pallas kernel :func:`repro.kernels.fleet_scan.fleet_scan.scan_fleet_pallas`).

Maintenance is strictly incremental — the plane is **never rebuilt per
tick**:

* tenant attach/detach adds/removes one tenant *row* (swap-with-last, like
  a StateMatrix slot);
* per-tenant state add/evict events stream in through a listener installed
  on each attached :class:`StateMatrix`
  (``StateMatrix._add_listener``), replaying the same append /
  swap-with-last slot algorithm, so fleet slots provably coincide with
  each tenant's local slots;
* capacity growth (more tenants, more states, wider partitions) is
  geometric and amortized.

Bit-identity contract (numpy path): for each tenant, the fused fleet scan
restricted to that tenant's ``(n, P_cap_local)`` window equals the
booleans its own plane would compute — padded slots carry ``[+inf, -inf]``
bounds, and a column is only skipped when *every* tenant is unbounded on
it, so the extra comparisons are identically True — and the final
reduction is delegated to the tenant's own
:meth:`StateMatrix.reduce_scanned` on that window.  Estimates are
therefore bit-for-bit the ones the per-tenant loop computes, which is what
lets :meth:`repro.engine.FleetEngine.run_batched` reproduce the stepwise
fleet trace exactly.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import layouts as L

from . import compute
from .state_matrix import StateMatrix


class _TenantMirror:
    """Listener bridging one tenant's StateMatrix events into the plane."""

    __slots__ = ("fleet", "tenant_id")

    def __init__(self, fleet: "FleetMatrix", tenant_id: str):
        self.fleet = fleet
        self.tenant_id = tenant_id

    def on_register(self, state_id: int, meta: L.PartitionMetadata) -> None:
        self.fleet._register(self.tenant_id, state_id, meta)

    def on_deregister(self, state_id: int) -> None:
        self.fleet._deregister(self.tenant_id, state_id)


class FleetMatrix:
    """Packed multi-tenant zone-map plane with incremental maintenance."""

    def __init__(self, compute_backend: str = "numpy",
                 tenant_capacity: int = 4, state_capacity: int = 8):
        self.set_compute_backend(compute_backend)
        self._tcap = max(int(tenant_capacity), 1)
        self._scap = max(int(state_capacity), 1)
        self._pcap = 0
        self._c: Optional[int] = None
        self._t = 0                                  # attached tenant rows
        self._tids: List[str] = []                   # row -> tenant id
        self._trows: Dict[str, int] = {}             # tenant id -> row
        self._sms: Dict[str, StateMatrix] = {}       # attached local planes
        self._mirrors: Dict[str, _TenantMirror] = {}
        self._ids: Dict[str, List[int]] = {}         # tenant -> slot -> sid
        self._slots: Dict[str, Dict[int, int]] = {}  # tenant -> sid -> slot
        self._counts: Dict[str, List[int]] = {}      # tenant -> slot -> P_s
        self._mins: Optional[np.ndarray] = None      # (T_cap,S_cap,P_cap,C)
        self._maxs: Optional[np.ndarray] = None
        # Transposed planes keep one column's bounds for one tenant — its
        # whole (S_cap, P_cap) block — contiguous: the fused scan compares
        # each such block against that tenant's scalar bound, and long
        # contiguous runs are what numpy's fast comparison loops need.
        # The scan covers full capacity (no slicing to the states in use):
        # capacity slack is bounded by the geometric growth factor, and
        # padded slots cost less than breaking the runs would.
        self._minsT: Optional[np.ndarray] = None     # (C,T_cap,S_cap,P_cap)
        self._maxsT: Optional[np.ndarray] = None
        self._rows: Optional[np.ndarray] = None      # (T_cap,S_cap,P_cap)
        self._totals: Optional[np.ndarray] = None    # (T_cap,S_cap) f64
        #: Bumped on every plane mutation (any tenant's register/deregister,
        #: attach, detach); consumers may key caches on it.
        self.version = 0
        # Cached float32-representability of the packed plane, keyed on
        # version (pallas_fused bit-identity guard).
        self._f32_version = -1
        self._f32_exact = False
        #: Dense view of the most recent :meth:`estimate_frames` pass —
        #: ``(batched, {tid: (row, n_states, version, shadow_slot)})`` for
        #: the tenants whose costs came out of the batched (B, T, S)
        #: reduction with a mirrored serving shadow, or None.  Consumers
        #: (the fleet's bulk decide path) read whole per-tenant cost
        #: matrices as ``batched[:, row, :n]`` instead of re-stacking B
        #: per-frame prime vectors; reset at the start of every pass.
        self.last_pass_dense: Optional[tuple] = None

    def set_compute_backend(self, compute_backend: str) -> None:
        """Switch the fused-scan compute path (validated; tensors shared)."""
        if compute_backend not in compute.BACKENDS:
            raise ValueError(f"unknown compute backend: {compute_backend!r}")
        self.compute_backend = compute_backend

    # -- introspection --------------------------------------------------
    def __len__(self) -> int:
        return self._t

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._trows

    @property
    def tenant_ids(self) -> List[str]:
        """Attached tenant ids in row order."""
        return list(self._tids)

    @property
    def num_columns(self) -> Optional[int]:
        return self._c

    @property
    def state_capacity(self) -> int:
        return self._scap

    @property
    def partition_capacity(self) -> int:
        return self._pcap

    def tenant_row(self, tenant_id: str) -> int:
        """Packed row index of an attached tenant (KeyError if unknown)."""
        return self._trows[tenant_id]

    def slot(self, tenant_id: str, state_id: int) -> int:
        """Packed slot of a tenant's state (KeyError if unknown)."""
        return self._slots[tenant_id][state_id]

    def state_ids(self, tenant_id: str) -> List[int]:
        """A tenant's registered state ids in fleet slot order."""
        return list(self._ids[tenant_id])

    # -- allocation -----------------------------------------------------
    def _alloc(self, tcap: int, scap: int, pcap: int) -> None:
        c = self._c
        mins = np.full((tcap, scap, pcap, c), np.inf)
        maxs = np.full((tcap, scap, pcap, c), -np.inf)
        minsT = np.full((c, tcap, scap, pcap), np.inf)
        maxsT = np.full((c, tcap, scap, pcap), -np.inf)
        rows = np.zeros((tcap, scap, pcap))
        totals = np.ones((tcap, scap))
        self._qlo_buf = np.empty((tcap, c))
        self._qhi_buf = np.empty((tcap, c))
        # A freshly-attached tenant row may not exist in the old arrays
        # yet (attach bumps the row count before ensuring capacity).
        t = min(self._t, 0 if self._mins is None else self._mins.shape[0])
        if t and self._mins is not None:
            old_s, old_p = self._scap, self._pcap
            mins[:t, :old_s, :old_p] = self._mins[:t]
            maxs[:t, :old_s, :old_p] = self._maxs[:t]
            minsT[:, :t, :old_s, :old_p] = self._minsT[:, :t]
            maxsT[:, :t, :old_s, :old_p] = self._maxsT[:, :t]
            rows[:t, :old_s, :old_p] = self._rows[:t]
            totals[:t, :old_s] = self._totals[:t]
        self._mins, self._maxs = mins, maxs
        self._minsT, self._maxsT = minsT, maxsT
        self._rows, self._totals = rows, totals
        self._tcap, self._scap, self._pcap = tcap, scap, pcap

    def _ensure_capacity(self, t: int, s: int, p: int) -> None:
        if self._c is None:
            raise RuntimeError("column count unknown before first register")
        if (self._mins is None or t > self._tcap or s > self._scap
                or p > self._pcap):
            # Geometric growth on every axis keeps reallocation (an
            # O(plane) copy) amortized O(1) per register even when a
            # tenant's state count creeps up one at a time.  The state
            # axis grows by 1.25x+4 rather than doubling: every fused scan
            # sweeps the full S_cap (contiguity beats masking), so state
            # padding is pure overhead on the hot path.
            scap = self._scap
            if s > scap:
                scap = max(s, scap + max(scap >> 2, 4))
            self._alloc(max(self._tcap, 2 * self._t, t), scap,
                        max(self._pcap, 2 * self._pcap if p > self._pcap
                            else self._pcap, p))

    # -- tenant attach/detach -------------------------------------------
    def attach(self, tenant_id: str, matrix: StateMatrix) -> None:
        """Mirror one tenant's StateMatrix into the plane, then follow its
        register/deregister events until :meth:`detach`."""
        if tenant_id in self._trows:
            raise ValueError(f"tenant {tenant_id!r} already attached")
        if (matrix.num_columns is not None and self._c is not None
                and matrix.num_columns != self._c):
            raise ValueError(
                f"tenant {tenant_id!r}: {matrix.num_columns} columns, "
                f"fleet plane has {self._c}")
        row = self._t
        self._t += 1
        self._tids.append(tenant_id)
        self._trows[tenant_id] = row
        self._sms[tenant_id] = matrix
        self._ids[tenant_id] = []
        self._slots[tenant_id] = {}
        self._counts[tenant_id] = []
        if self._c is None:
            self._c = matrix.num_columns       # may still be None: learned
        if self._mins is not None and row >= self._tcap:
            self._ensure_capacity(self._t, self._scap, self._pcap)
        for sid in matrix.state_ids:           # initial sync, in slot order
            self._register(tenant_id, sid, matrix.metadata(sid))
        mirror = _TenantMirror(self, tenant_id)
        self._mirrors[tenant_id] = mirror
        matrix._add_listener(mirror)
        self.version += 1

    def detach(self, tenant_id: str) -> None:
        """Stop mirroring a tenant and drop its row (swap-with-last).
        Unknown ids are a no-op."""
        row = self._trows.pop(tenant_id, None)
        if row is None:
            return
        self._sms.pop(tenant_id)._remove_listener(
            self._mirrors.pop(tenant_id))
        self._ids.pop(tenant_id)
        self._slots.pop(tenant_id)
        self._counts.pop(tenant_id)
        last = self._t - 1
        if row != last:
            if self._mins is not None:
                self._mins[row] = self._mins[last]
                self._maxs[row] = self._maxs[last]
                self._minsT[:, row] = self._minsT[:, last]
                self._maxsT[:, row] = self._maxsT[:, last]
                self._rows[row] = self._rows[last]
                self._totals[row] = self._totals[last]
            moved = self._tids[last]
            self._tids[row] = moved
            self._trows[moved] = row
        if self._mins is not None:
            # Reset the vacated last row to padding so a future attach
            # starts clean without an O(plane) wipe at attach time.
            self._mins[last] = np.inf
            self._maxs[last] = -np.inf
            self._minsT[:, last] = np.inf
            self._maxsT[:, last] = -np.inf
            self._rows[last] = 0.0
            self._totals[last] = 1.0
        self._tids.pop()
        self._t = last
        self.version += 1

    def detach_all(self) -> None:
        for tid in list(self._tids):
            self.detach(tid)

    # -- per-state maintenance (O(P*C) per event) -----------------------
    def _register(self, tid: str, state_id: int,
                  meta: L.PartitionMetadata) -> None:
        if self._c is None:
            self._c = meta.num_columns
        elif meta.num_columns != self._c:
            raise ValueError(
                f"tenant {tid!r} state {state_id}: {meta.num_columns} "
                f"columns, fleet plane has {self._c}")
        p = meta.num_partitions
        ids, slots, counts = self._ids[tid], self._slots[tid], self._counts[tid]
        slot = slots.get(state_id)
        if slot is None:
            slot = len(ids)
            self._ensure_capacity(self._t, slot + 1, p)
            ids.append(state_id)
            slots[state_id] = slot
            counts.append(p)
        else:
            self._ensure_capacity(self._t, slot + 1, p)
            counts[slot] = p
        row = self._trows[tid]
        self._mins[row, slot, :p] = meta.mins
        self._mins[row, slot, p:] = np.inf
        self._maxs[row, slot, :p] = meta.maxs
        self._maxs[row, slot, p:] = -np.inf
        self._minsT[:, row, slot, :p] = meta.mins.T
        self._minsT[:, row, slot, p:] = np.inf
        self._maxsT[:, row, slot, :p] = meta.maxs.T
        self._maxsT[:, row, slot, p:] = -np.inf
        self._rows[row, slot, :p] = meta.rows
        self._rows[row, slot, p:] = 0.0
        self._totals[row, slot] = max(meta.total_rows, 1)
        self.version += 1

    def _deregister(self, tid: str, state_id: int) -> None:
        ids, slots, counts = self._ids[tid], self._slots[tid], self._counts[tid]
        slot = slots.pop(state_id, None)
        if slot is None:
            return
        row = self._trows[tid]
        last = len(ids) - 1
        if slot != last:
            self._mins[row, slot] = self._mins[row, last]
            self._maxs[row, slot] = self._maxs[row, last]
            self._minsT[:, row, slot] = self._minsT[:, row, last]
            self._maxsT[:, row, slot] = self._maxsT[:, row, last]
            self._rows[row, slot] = self._rows[row, last]
            self._totals[row, slot] = self._totals[row, last]
            moved = ids[last]
            ids[slot] = moved
            slots[moved] = slot
            counts[slot] = counts[last]
        self._mins[row, last] = np.inf
        self._maxs[row, last] = -np.inf
        self._minsT[:, row, last] = np.inf
        self._maxsT[:, row, last] = -np.inf
        self._rows[row, last] = 0.0
        self._totals[row, last] = 1.0
        ids.pop()
        counts.pop()
        self.version += 1

    # -- fused scoring --------------------------------------------------
    def _scanned_all(self, q_lo: np.ndarray,
                     q_hi: np.ndarray) -> np.ndarray:
        """(B, T_cap, S_cap, P_cap) bool fleet scan for (B, T_cap, C)
        per-frame, per-tenant bounds.

        Detached / beyond-``self._t`` tenant rows and padded slots carry
        padding bounds and dummy unbounded queries, so their lanes compute
        garbage-free noise that no caller reads — keeping every operand
        contiguous is worth the few wasted lanes.
        """
        tcap = self._tcap
        b = q_lo.shape[0]
        if self.compute_backend == "pallas_fused":
            # One megakernel launch scores all B frames; a per-frame loop
            # of fleet_scan_matrix calls (the "pallas" path below) reads
            # the packed bounds B times instead.  The kernel casts to
            # float32, so the plane (checked once per version) and the
            # frame queries must be float32-exact for the bit-identity
            # contract — otherwise fall back to the exact numpy pass.
            if (self._plane_float32_exact()
                    and compute.float32_exact(q_lo, q_hi)):
                return compute.fused_frames_scan(q_lo, q_hi,
                                                 self._mins, self._maxs)
            warnings.warn(
                "FleetMatrix(pallas_fused): operands are not exactly "
                "float32-representable; using the exact numpy fused pass",
                RuntimeWarning, stacklevel=2)
        elif self.compute_backend == "pallas":
            n = self._scap * self._pcap
            mins3 = self._mins.reshape(tcap, n, self._c)
            maxs3 = self._maxs.reshape(tcap, n, self._c)
            frames = [
                compute.fleet_scan_matrix(
                    q_lo[k], q_hi[k], mins3, maxs3, backend="pallas",
                ).reshape(tcap, self._scap, self._pcap)
                for k in range(b)]
            return np.stack(frames)
        return compute.fleet_masked_overlap(self._minsT, self._maxsT,
                                            q_lo, q_hi)

    def _plane_float32_exact(self) -> bool:
        """Cached-per-version float32-representability of the packed plane."""
        if self._f32_version != self.version:
            self._f32_version = self.version
            self._f32_exact = compute.float32_exact(self._mins, self._maxs)
        return self._f32_exact

    def estimate_frames(self, frames: Sequence[Sequence[tuple]],
                        want_primes: bool = True,
                        ) -> List[List[Optional[Tuple[int, np.ndarray,
                                                      Optional[float]]]]]:
        """Score a block of *frames* — each at most one pending query per
        tenant — in a single fused pass over the whole plane.

        Each frame is a sequence of ``(tenant_id, q_lo, q_hi)`` triples or
        ``(tenant_id, Query)`` pairs (the fleet's event tuples, accepted
        directly so the hot path never re-materializes them), tenants
        distinct within a frame; several frames per pass amortize the fixed
        Python cost of the pass over ``B * T`` events.  Returns, aligned
        with the input, either ``None`` (tenant unknown or has no
        registered states yet — caller falls back to the per-tenant path)
        or ``(version, costs, serve)``: ``version`` is the tenant's
        :attr:`StateMatrix.version` at scoring time, ``costs`` the float64
        per-slot cost vector, bit-identical (numpy backend) to that
        tenant's own :meth:`StateMatrix.estimate`, and ``serve`` the
        serving-shadow slot's score as a float (None when no shadow state
        is mirrored).  A tenant whose plane changes between scoring and
        consumption (mid-decision state churn) is expected to be caught by
        the consumer's version check.

        ``want_primes=False`` skips materializing the per-event prime
        tuples (the returned lists are all ``None``) and only publishes
        :attr:`last_pass_dense` — for callers that will consume the pass
        through the bulk decide path and rescore exactly (plane unchanged,
        so bit-identically) in the rare case they cannot.
        """
        b = len(frames)
        self.last_pass_dense = None
        empty: List[List[Optional[tuple]]] = [
            [None] * len(fr) for fr in frames]
        if self._t == 0 or self._mins is None or b == 0:
            return empty
        tcap, c = self._tcap, self._c
        # Tenants without a query in a frame get fully-unbounded dummy
        # bounds: comparisons against +/-inf are identically True, so they
        # cannot perturb any other tenant's slice and their (unused) output
        # costs nothing extra to mask.
        if self._qlo_buf.shape[0] < b * tcap:
            self._qlo_buf = np.empty((b * tcap, c))
            self._qhi_buf = np.empty((b * tcap, c))
        q_lo = self._qlo_buf[:b * tcap]
        q_hi = self._qhi_buf[:b * tcap]
        q_lo.fill(-np.inf)
        q_hi.fill(np.inf)
        # Per-distinct-tenant facts resolved once per pass, not per event:
        # (row, n, version, uniform-reduce ok, StateMatrix, shadow slot).
        info: Dict[str, Optional[tuple]] = {}
        live: List[Tuple[int, int, tuple]] = []
        flat: List[int] = []
        los: List[np.ndarray] = []
        his: List[np.ndarray] = []
        for k, items in enumerate(frames):
            base = k * tcap
            for j, item in enumerate(items):
                if len(item) == 2:
                    tid, query = item
                    lo, hi = query.lo, query.hi
                else:
                    tid, lo, hi = item
                entry = info.get(tid, False)
                if entry is False:
                    row = self._trows.get(tid)
                    n = len(self._ids[tid]) if row is not None else 0
                    if row is None or n == 0:
                        entry = None
                    else:
                        sm = self._sms[tid]
                        entry = (row, n, sm.version,
                                 len(sm) == n and sm.uniform
                                 and sm.partition_capacity == self._pcap,
                                 sm, self._slots[tid].get(-1))
                    info[tid] = entry
                if entry is None:
                    continue
                flat.append(base + entry[0])
                los.append(lo)
                his.append(hi)
                live.append((k, j, entry))
        if not live:
            return empty
        idx = np.asarray(flat, dtype=np.intp)
        q_lo[idx] = np.stack(los)
        q_hi[idx] = np.stack(his)
        scanned = self._scanned_all(q_lo.reshape(b, tcap, c),
                                    q_hi.reshape(b, tcap, c))
        batched: Optional[np.ndarray] = None
        out = empty
        if not want_primes:
            # Dense-only pass: one batched reduction, no per-event tuples.
            if any(entry[3] for _, _, entry in live):
                batched = (np.einsum("btsp,tsp->bts", scanned,
                                     self._rows) / self._totals[None])
                self.last_pass_dense = (batched, {
                    tid: (entry[0], entry[1], entry[2], entry[5])
                    for tid, entry in info.items()
                    if entry is not None and entry[3]
                    and entry[5] is not None})
            return out
        for k, j, (row, n, version, fused_ok, sm, shadow) in live:
            if fused_ok:
                # Equal reduce width and contiguity on both paths: the
                # batched (B, T, S, P) einsum accumulates each output
                # element exactly like the tenant's own (n, P) einsum, so
                # one fused reduction covers every such tenant bit-exactly.
                # (Unequal widths would change numpy's accumulator grouping
                # — those tenants take the per-tenant reduction below.)
                if batched is None:
                    batched = (np.einsum("btsp,tsp->bts", scanned,
                                         self._rows) / self._totals[None])
                costs = batched[k, row, :n]
            elif len(sm) == n:
                costs = sm.reduce_scanned(np.ascontiguousarray(
                    scanned[k, row, :n, :sm.partition_capacity]))
            else:
                continue            # plane out of sync mid-churn: fall back
            # The serving-shadow slot (state id -1), when mirrored, rides
            # along as a ready-made serve score for backends whose serve()
            # is the exact shadow estimate (InMemoryBackend, numpy).
            out[k][j] = (version, costs,
                         float(costs[shadow]) if shadow is not None else None)
        if batched is not None:
            dense_info = {
                tid: (entry[0], entry[1], entry[2], entry[5])
                for tid, entry in info.items()
                if entry is not None and entry[3] and entry[5] is not None}
            self.last_pass_dense = (batched, dense_info)
        return out

    def estimate_frame(self, items: Sequence[Tuple[str, np.ndarray,
                                                   np.ndarray]],
                       ) -> List[Optional[Tuple[int, np.ndarray,
                                                Optional[float]]]]:
        """Single-frame convenience wrapper over :meth:`estimate_frames`."""
        return self.estimate_frames([items])[0]
