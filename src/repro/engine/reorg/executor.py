"""Budgeted migration execution with exact α-charge amortization.

A :class:`ReorgExecutor` sits between a :class:`repro.engine.LayoutEngine`
running in ``incremental=True`` mode and its storage backend.  The engine's
decision layer is untouched — reorganizations are still *charged* α at
decision time, exactly as in the atomic loop, so the paper's worst-case
accounting is preserved under every budget.  What changes is the physical
side: instead of one wholesale swap at the Δ-due step, the executor

1. **begins** a migration at the step the atomic swap would have applied
   (never earlier — the Δ-delay and every scheduler-deferral rule are the
   same code path as the atomic engine),
2. **advances** it a micro-batch at a time: each engine step it asks the
   governor/scheduler for a row budget (``grant_rows``), completes planned
   moves in greedy order as their row cost is covered, and installs the
   resulting hybrid zone maps on the backend,
3. **completes** by activating the target layout through the backend's
   normal path, so the post-migration state is bitwise the atomic one.

With an infinite per-tick budget every migration begins and completes
within the step the atomic swap would have landed, making the whole
incremental engine trace bit-identical to the atomic engine's.

Charge ledger
-------------
Each migration keeps an amortization schedule of the single atomic α:
every advancing step appends ``(index, rows_moved, charge)`` with the
charge proportional to rows moved, and the increments are constructed so
that their *left-to-right float sum* is bitwise ``α`` at completion (the
final increment is nudged by ULPs if ordinary subtraction would leave the
sum one rounding step off).  ``sum(charge for _, _, charge in
record.charges)`` therefore telescopes to exactly the atomic charge —
the invariant the property tests pin down.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.core import workload as wl

from .planner import MigrationPlan, plan_migration


def closing_increment(charged: float, alpha: float) -> float:
    """The final charge that lands a left-to-right float sum on ``alpha``.

    Returns ``inc`` such that ``charged + inc == alpha`` *bitwise*.  Plain
    ``alpha - charged`` already does this in almost every case; when the
    two roundings (of the difference, then of the sum) conspire to land
    one ULP off, the increment is nudged until the sum is exact.
    """
    inc = alpha - charged
    for _ in range(4):                      # 1 nudge suffices in practice
        total = charged + inc
        if total == alpha:
            return inc
        inc = math.nextafter(inc, math.inf if total < alpha else -math.inf)
    raise AssertionError(
        f"could not close charge ledger: charged={charged!r} "
        f"alpha={alpha!r}")


@dataclasses.dataclass
class MigrationRecord:
    """The observable trace of one (possibly still in-flight) migration."""

    target_state: int
    charged_at: int                 # decision index the α charge landed on
    begun_at: int = -1              # step the physical migration started
    completed_at: int = -1          # step the target layout took over
    alpha: float = 0.0
    total_rows: int = 0             # rows the full migration relocates
    moved_rows: int = 0
    moves_total: int = 0
    moves_done: int = 0
    #: Amortization schedule: (engine index, rows moved, charge).  The
    #: left-to-right float sum of the charges is bitwise ``alpha`` once
    #: ``completed_at >= 0``.
    charges: List[Tuple[int, int, float]] = dataclasses.field(
        default_factory=list)
    #: Running left-to-right sum of ``charges`` (what a consumer summing
    #: the schedule in order obtains).
    charged: float = 0.0

    @property
    def in_flight(self) -> bool:
        return self.begun_at >= 0 and self.completed_at < 0

    def charge(self, index: int, rows: int, completing: bool) -> None:
        if completing:
            inc = closing_increment(self.charged, self.alpha)
        else:
            inc = self.alpha * (self.moved_rows / max(self.total_rows, 1)) \
                - self.charged
        self.charges.append((index, rows, inc))
        self.charged = self.charged + inc


class ReorgExecutor:
    """Drives planned migrations through a backend under a row budget.

    ``rows_per_tick`` is the engine-local budget cap (None = unbounded);
    a fleet governor with ``grant_rows`` (see
    :class:`repro.engine.scheduler.ReorgScheduler`) can tighten — never
    loosen — what a single step may move.  ``recent_window`` bounds the
    query sample handed to the planner's greedy ordering;
    ``compute`` selects the ordering's scan-frequency path (``"numpy"``
    exact / ``"pallas"`` via :mod:`repro.kernels.move_score`).
    """

    def __init__(self, backend, rows_per_tick: Optional[int] = None,
                 recent_window: int = 64, compute: str = "numpy"):
        if rows_per_tick is not None and rows_per_tick <= 0:
            raise ValueError("rows_per_tick must be positive (None = "
                             "unbounded)")
        self.backend = backend
        self.rows_per_tick = rows_per_tick
        self.compute = compute
        self._recent: Deque[wl.Query] = collections.deque(
            maxlen=max(int(recent_window), 1))
        self._active: Optional[MigrationPlan] = None
        self._cursor = 0                    # next move index in plan order
        self._banked = 0.0                  # granted rows not yet spent
        self._done: Optional[np.ndarray] = None
        # Per-step budget tracking: advance() may run more than once per
        # engine step (a completing migration lets the next due swap begin
        # in the same step), and the engine-local cap applies per step.
        self._tick_index = -1
        self._tick_spent = 0
        #: Every migration this executor ran, in begin order (completed
        #: and in-flight); the charge-ledger invariant is per entry.
        self.migrations: List[MigrationRecord] = []

    # ------------------------------------------------------------------
    @property
    def active(self) -> Optional[MigrationRecord]:
        """The in-flight migration's record (None when idle)."""
        return self.migrations[-1] if self._active is not None else None

    @property
    def done_mask(self) -> Optional[np.ndarray]:
        """Copy of the in-flight migration's done mask (None when idle)."""
        return None if self._done is None else self._done.copy()

    def observe(self, query: wl.Query) -> None:
        """Feed one served query into the planner's recent-window sample."""
        self._recent.append(query)

    # ------------------------------------------------------------------
    def begin(self, engine, state_id: int, index: int,
              charged_at: int) -> None:
        """Start the migration the atomic engine would have swapped here.

        Plans the (source -> target) diff against the recent query window
        and leaves the serving state untouched — rows only move in
        :meth:`advance` (called later in the same engine step, so an
        unbounded budget still completes the migration at this very
        step)."""
        if self._active is not None:
            raise RuntimeError("a migration is already in flight")
        source = self.backend.serving_layout
        target = self.backend.get(state_id)
        # Delta-bearing backends (streaming ingest) hand the planner the
        # hybrid source — clustered base partitions plus one pseudo-
        # partition per pending delta batch — so compactions (and drift
        # reorgs with deltas in flight) diff against what is physically
        # being served.  Returns None with no pending deltas, which keeps
        # the plain path (and its traces) bit-identical.
        src_assign = src_meta = None
        delta_source = getattr(self.backend, "delta_source", None)
        if delta_source is not None:
            hybrid = delta_source()
            if hybrid is not None:
                src_assign, src_meta = hybrid
        plan = plan_migration(self.backend.data, source, target,
                              recent_queries=tuple(self._recent),
                              compute=self.compute,
                              source_assignment=src_assign,
                              source_meta=src_meta)
        self._active = plan
        self._cursor = 0
        self._banked = 0.0
        self._done = np.zeros(plan.num_target_partitions, dtype=bool)
        self.backend.begin_migration(plan)
        self.migrations.append(MigrationRecord(
            target_state=state_id, charged_at=charged_at, begun_at=index,
            alpha=engine.alpha, total_rows=plan.total_move_rows,
            moves_total=plan.num_moves))

    def advance(self, engine, index: int) -> None:
        """Spend this step's row budget on the in-flight migration."""
        plan = self._active
        if plan is None:
            return
        if index != self._tick_index:
            self._tick_index = index
            self._tick_spent = 0
        record = self.migrations[-1]
        remaining = int(sum(m.rows for m in plan.moves[self._cursor:])
                        - self._banked)
        want = remaining
        if self.rows_per_tick is not None:
            want = min(want, self.rows_per_tick - self._tick_spent)
        want = max(want, 0)
        granted = want
        governor = engine.governor
        if want and governor is not None and hasattr(governor, "grant_rows"):
            granted = min(want, governor.grant_rows(engine, want))
        self._banked += granted
        self._tick_spent += granted
        newly_done: List[int] = []
        rows_now = 0
        while self._cursor < len(plan.moves):
            move = plan.moves[self._cursor]
            if self._banked < move.rows:
                break
            self._banked -= move.rows
            self._cursor += 1
            newly_done.append(move.target_partition)
            rows_now += move.rows
        if not newly_done and self._cursor < len(plan.moves):
            return
        record.moved_rows += rows_now
        record.moves_done += len(newly_done)
        if self._cursor >= len(plan.moves):
            # Migration complete: snap to the target through the backend's
            # normal activation path (bitwise the atomic end state) and
            # close the charge ledger on exactly alpha.
            if newly_done:
                self._done[newly_done] = True
            self.backend.complete_migration(plan)
            record.charge(index, rows_now, completing=True)
            record.completed_at = index
            self._active = None
            self._done = None
            self._banked = 0.0
            governor = engine.governor
            if governor is not None and hasattr(governor, "on_complete"):
                governor.on_complete(engine, record.target_state)
        else:
            self._done[newly_done] = True
            self.backend.apply_migration(plan.hybrid_meta(self._done),
                                         newly_done)
            record.charge(index, rows_now, completing=False)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate ledger stats (for benchmarks and traces)."""
        completed = [m for m in self.migrations if m.completed_at >= 0]
        return {
            "migrations": len(self.migrations),
            "completed": len(completed),
            "rows_moved": int(sum(m.moved_rows for m in self.migrations)),
            "moves_done": int(sum(m.moves_done for m in self.migrations)),
            "charged": float(sum(m.charged for m in self.migrations)),
        }


__all__ = ["MigrationRecord", "ReorgExecutor", "closing_increment",
           "plan_migration"]
