"""Micro-move planning: diff two layouts into budgetable partition moves.

A *migration* replaces the serving (source) layout with a target layout.
Atomically that is one rewrite of every partition; incrementally it is a
sequence of :class:`MicroMove`\\ s, one per target partition whose row set
actually differs from the source layout (identical partitions never move —
the same diff the skip-aware :meth:`repro.data.partition_store.
PartitionStore.reorganize` applies on disk).

The plan also carries the *block decomposition* the hybrid serving state
is maintained from: block ``(i, j)`` holds the rows routed from source
partition ``i`` to target partition ``j``, with exact per-block zone maps.
After any subset ``D`` of moves has completed, the physically hybrid table
is exactly

* one partition per **done** target ``j ∈ D`` (exact target zone maps),
* one **residual** partition per source ``i`` holding its not-yet-moved
  rows — zone maps are the elementwise min/max over blocks ``(i, j)`` with
  ``j ∉ D``,

and :meth:`MigrationPlan.hybrid_meta` materializes those
``P_s + P_t``-partition zone maps for any done mask in one masked
reduction over the precomputed block tensors.

Move *ordering* is greedy by estimated skipping-benefit-per-row under the
recent query distribution: completing move ``j`` relocates each block
``(i, j)`` from a partition scanned with the source partition's observed
frequency to one scanned with the target partition's frequency.  The
per-partition scan frequencies are one ``(S=2, P, C)`` pass over both
layouts' zone maps — exact numpy by default, or the
:mod:`repro.kernels.move_score` Pallas kernel (float32) with
``compute="pallas"``.  Ordering is an estimation heuristic only: the move
*set* is always exactly the layout diff, whatever the ordering says.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import layouts as L
from repro.core import workload as wl


@dataclasses.dataclass(frozen=True)
class MicroMove:
    """One budgetable unit of migration: materialize one target partition.

    ``rows`` is the number of rows relocated (the move's cost in the row
    budget); ``source_partitions`` the partitions those rows leave;
    ``benefit_per_row`` the greedy ordering key (estimated rows of scan
    saved per query, per row moved — 0.0 when no recent queries were
    available at planning time).
    """

    target_partition: int
    rows: int
    source_partitions: Tuple[int, ...]
    benefit_per_row: float = 0.0


@dataclasses.dataclass
class MigrationPlan:
    """Everything the executor and the hybrid backends need for one
    migration: the ordered moves, the block decomposition, and both
    layouts' row-level assignments."""

    source_id: int
    target: L.Layout
    moves: List[MicroMove]
    total_move_rows: int
    num_source_partitions: int
    num_target_partitions: int
    #: (N,) row -> source / target partition assignments over the table.
    source_assignment: np.ndarray
    target_assignment: np.ndarray
    #: (P_s, P_t, C) / (P_s, P_t) exact per-block zone maps; empty blocks
    #: carry the [+inf, -inf] identity bounds and zero rows.
    block_mins: np.ndarray
    block_maxs: np.ndarray
    block_rows: np.ndarray
    #: Exact zone maps of the fully-materialized target table.
    target_meta: L.PartitionMetadata
    #: target partition j -> identical source partition i (row set
    #: unchanged between the layouts; such partitions never move).
    identical: dict

    @property
    def num_moves(self) -> int:
        return len(self.moves)

    def target_partition_rows(self, data: np.ndarray, j: int) -> np.ndarray:
        """The physical rows of target partition ``j`` (stable row order)."""
        return data[self.target_assignment == j]

    def source_moved_mask(self, i: int, done: np.ndarray) -> np.ndarray:
        """Per-row moved flags for source partition ``i``'s rows (in their
        original, file-stable order) given the ``(P_t,)`` done mask."""
        return done[self.target_assignment[self.source_assignment == i]]

    def hybrid_meta(self, done: np.ndarray) -> L.PartitionMetadata:
        """Exact zone maps of the hybrid table after the ``done`` moves.

        Partition order is ``[residual sources (P_s), targets (P_t)]``;
        fully-drained sources and not-yet-done targets carry the
        [+inf, -inf] identity bounds and zero rows, so they are never
        scanned and contribute exactly 0.0 to any cost reduction.
        """
        p_s = self.num_source_partitions
        c = self.block_mins.shape[2]
        not_done = ~done
        if not_done.any():
            res_mins = self.block_mins[:, not_done, :].min(axis=1)
            res_maxs = self.block_maxs[:, not_done, :].max(axis=1)
            res_rows = self.block_rows[:, not_done].sum(axis=1)
        else:
            res_mins = np.full((p_s, c), np.inf)
            res_maxs = np.full((p_s, c), -np.inf)
            res_rows = np.zeros(p_s)
        tgt_mins = np.where(done[:, None], self.target_meta.mins, np.inf)
        tgt_maxs = np.where(done[:, None], self.target_meta.maxs, -np.inf)
        tgt_rows = np.where(done, self.target_meta.rows, 0.0)
        return L.PartitionMetadata(
            mins=np.concatenate([res_mins, tgt_mins]),
            maxs=np.concatenate([res_maxs, tgt_maxs]),
            rows=np.concatenate([res_rows, tgt_rows]))


def _assignment(layout: L.Layout, data: np.ndarray) -> np.ndarray:
    """Row -> partition assignment, matching what a physical write of the
    layout produces (``route`` when present; partition 0 otherwise, which
    is exactly how :meth:`PartitionStore.write` routes route-less
    layouts)."""
    if layout.route is None:
        return np.zeros(len(data), dtype=np.int64)
    return np.asarray(layout.route(data), dtype=np.int64)


def scan_frequencies(metas: Sequence[L.PartitionMetadata],
                     q_lo: np.ndarray, q_hi: np.ndarray,
                     compute: str = "numpy") -> List[np.ndarray]:
    """Mean scan frequency of every partition of every layout under a query
    sample: ``(Q, C)`` bounds x S layouts -> one ``(P_s,)`` float vector
    per layout.

    ``compute="numpy"`` is the exact float64 path; ``"pallas"`` stacks
    the layouts into one padded ``(S, P_max, C)`` plane and scores all
    (state, partition) move candidates in a single
    :func:`repro.kernels.move_score.ops.move_scan_frequencies` launch;
    ``"pallas_fused"`` routes the same plane through the decision
    megakernel's ``freq`` output (both float32 — ordering heuristic only,
    never cost accounting).
    """
    if compute in ("pallas", "pallas_fused"):
        counts = [m.num_partitions for m in metas]
        p_max = max(counts) if counts else 0
        s, c = len(metas), metas[0].num_columns
        mins = np.full((s, p_max, c), np.inf, dtype=np.float32)
        maxs = np.full((s, p_max, c), -np.inf, dtype=np.float32)
        for k, m in enumerate(metas):
            mins[k, :counts[k]] = m.mins
            maxs[k, :counts[k]] = m.maxs
        if compute == "pallas_fused":
            # The megakernel's freq output over a single-tenant plane
            # (T=1, S layouts, P_max partitions): the (Q, C) sample is the
            # recent-query window, and the same launch could also carry
            # the scoring outputs for the planning tenant.
            from repro.kernels.decision_fused import decision_fused
            dummy = np.zeros((1, 1, c), dtype=np.float32)
            _, _, freq = decision_fused.fused_decision_pallas(
                dummy + 1.0, dummy,          # empty frame query: unused
                mins[None], maxs[None],
                w_lo=q_lo.astype(np.float32), w_hi=q_hi.astype(np.float32),
                emit_scan=False)
            freq = np.asarray(freq)[0]                       # (S, P_max)
        else:
            from repro.kernels.move_score import ops as ms_ops
            freq = np.asarray(ms_ops.move_scan_frequencies(
                q_lo.astype(np.float32), q_hi.astype(np.float32), mins,
                maxs))
        return [freq[k, :counts[k]].astype(np.float64) for k in range(s)]
    out = []
    for m in metas:
        scanned = L.partitions_scanned(m, q_lo, q_hi)       # (Q, P)
        out.append(np.atleast_2d(scanned).mean(axis=0))
    return out


def plan_migration(data: np.ndarray, source: L.Layout, target: L.Layout,
                   recent_queries: Sequence[wl.Query] = (),
                   compute: str = "numpy",
                   source_assignment: Optional[np.ndarray] = None,
                   source_meta: Optional[L.PartitionMetadata] = None,
                   ) -> MigrationPlan:
    """Diff ``source`` -> ``target`` into greedily-ordered micro-moves.

    The move set is exactly the layout diff: one move per non-empty target
    partition whose row set is not already held verbatim by some source
    partition.  ``recent_queries`` drives the greedy
    benefit-per-row-moved ordering; with an empty sample the diff is
    ordered by target partition id (benefit 0).

    ``source_assignment`` / ``source_meta`` (always passed together)
    override the physical source partitioning — the hook the streaming
    ingest plane uses to plan *compactions*: the source is then the
    hybrid delta-bearing state (clustered base partitions plus one
    pseudo-partition per delta batch), so a compaction's move set is
    exactly the delta-touched target partitions and untouched clustered
    partitions are skipped as identical.
    """
    if (source_assignment is None) != (source_meta is None):
        raise ValueError("source_assignment and source_meta go together")
    if source_assignment is None:
        a_s = _assignment(source, data)
        src_meta = source.serving_meta()
    else:
        a_s = np.asarray(source_assignment, dtype=np.int64)
        src_meta = source_meta
    a_t = _assignment(target, data)
    p_s = src_meta.num_partitions
    p_t = target.num_partitions
    target_meta = target.materialize(data)

    # Exact per-block zone maps in one grouped reduction over the combined
    # (source, target) assignment key.
    key = a_s * p_t + a_t
    block = L.metadata_from_assignment(data, key, p_s * p_t)
    block_mins = block.mins.reshape(p_s, p_t, -1)
    block_maxs = block.maxs.reshape(p_s, p_t, -1)
    block_rows = block.rows.reshape(p_s, p_t)

    src_counts = block_rows.sum(axis=1)                  # (P_s,)
    tgt_counts = block_rows.sum(axis=0)                  # (P_t,)
    feeders = block_rows > 0                             # (P_s, P_t)

    # A target partition is *identical* iff all its rows come from one
    # source partition that contributes nothing anywhere else.
    identical = {}
    single_feeder = feeders.sum(axis=0) == 1
    for j in np.nonzero(single_feeder & (tgt_counts > 0))[0]:
        i = int(np.nonzero(feeders[:, j])[0][0])
        if block_rows[i, j] == src_counts[i] == tgt_counts[j]:
            identical[int(j)] = i

    diff = [int(j) for j in range(p_t)
            if tgt_counts[j] > 0 and int(j) not in identical]

    benefit_per_row = np.zeros(p_t)
    if recent_queries and diff:
        q_lo, q_hi = wl.stack_queries(list(recent_queries))
        freq_src, freq_tgt = scan_frequencies(
            [src_meta, target_meta], q_lo, q_hi,
            compute=compute)
        # Completing move j relocates block (i, j) from a partition read
        # with frequency freq_src[i] to one read with freq_tgt[j].
        gain = block_rows.T @ freq_src - tgt_counts * freq_tgt   # (P_t,)
        benefit_per_row = np.divide(gain, tgt_counts,
                                    out=np.zeros(p_t),
                                    where=tgt_counts > 0)

    order = sorted(diff, key=lambda j: (-benefit_per_row[j], j))
    moves = [MicroMove(target_partition=j,
                       rows=int(tgt_counts[j]),
                       source_partitions=tuple(
                           int(i) for i in np.nonzero(feeders[:, j])[0]),
                       benefit_per_row=float(benefit_per_row[j]))
             for j in order]
    return MigrationPlan(
        source_id=source.layout_id,
        target=target,
        moves=moves,
        total_move_rows=int(sum(m.rows for m in moves)),
        num_source_partitions=p_s,
        num_target_partitions=p_t,
        source_assignment=a_s,
        target_assignment=a_t,
        block_mins=block_mins,
        block_maxs=block_maxs,
        block_rows=block_rows,
        target_meta=target_meta,
        identical=identical,
    )


def plan_is_permutation_of_diff(plan: MigrationPlan) -> bool:
    """True iff the plan's move order is a permutation of the layout diff
    (every differing non-empty target partition exactly once) — the
    invariant the property tests pin down."""
    tgt_counts = plan.block_rows.sum(axis=0)
    diff = {int(j) for j in range(plan.num_target_partitions)
            if tgt_counts[j] > 0 and int(j) not in plan.identical}
    moved = [m.target_partition for m in plan.moves]
    return len(moved) == len(set(moved)) and set(moved) == diff


__all__ = ["MicroMove", "MigrationPlan", "plan_migration",
           "plan_is_permutation_of_diff", "scan_frequencies"]
