"""Incremental reorganization plane: micro-move planning and budgeted
execution.

The paper charges one atomic α-cost event per reorganization and swaps the
serving layout wholesale after the Δ-delay.  Production reclustering
systems instead migrate a few micro-partitions at a time, realizing
skipping benefit early and bounding per-tick reorganization work.  This
package is that plane:

* :mod:`planner` — diff a (source, target) layout pair into partition-level
  :class:`MicroMove`\\ s and order them greedily by estimated
  skipping-benefit-per-row-moved under the recent query distribution.
* :mod:`executor` — a :class:`ReorgExecutor` that consumes scheduler
  grants as *row budgets*, drives moves through the backend a micro-batch
  at a time, and keeps a per-migration charge ledger whose cumulative
  charge is bitwise equal to the atomic α charge at completion.

Hybrid-layout serving (zone maps mixing moved target and unmoved source
partitions) lives in the backends (:mod:`repro.engine.backends`); the
engine/fleet entry point is ``LayoutEngine(..., incremental=True)`` /
``FleetEngine(..., incremental=True)``.
"""
from repro.engine.reorg.executor import MigrationRecord, ReorgExecutor
from repro.engine.reorg.planner import MicroMove, MigrationPlan, plan_migration

__all__ = [
    "MicroMove", "MigrationPlan", "MigrationRecord", "ReorgExecutor",
    "plan_migration",
]
