"""Multi-tenant fleet: many LayoutEngines, one reorganization budget.

A :class:`FleetEngine` drives N independent tenants — each a fully-formed
:class:`repro.engine.LayoutEngine` with its own policy, backend, α and
Δ-delay — over a single interleaved stream of typed
:data:`repro.core.workload.Event` envelopes
(:class:`~repro.core.workload.QueryEvent` /
:class:`~repro.core.workload.IngestEvent`), the shape of traffic a
warehouse actually sees.  :meth:`FleetEngine.submit` enqueues one event
and :meth:`FleetEngine.drain` processes the backlog; ``run`` /
``run_batched`` (and the serving tier,
:class:`repro.serve.ServeFrontend`) are drivers over that one entry
point.  Decisions stay strictly per-tenant; what is *shared* is physical
reorganization work, arbitrated by a pluggable
:class:`repro.engine.scheduler.ReorgScheduler`.

The contract with each tenant's Δ-delay semantics (paper §VI-D5):

* Reorganization **charges** are untouched.  A tenant's policy runs
  exactly as it would standalone, and α is charged at decision time, so
  ``reorg_indices`` and ``state_seq`` are identical under *every*
  scheduler (decisions are metadata-only and never read the serving
  layout).
* Physical **swaps** may only be deferred, never advanced: a swap lands at
  the first of the tenant's own steps whose index is ≥ its due index
  (charge index + Δ) *and* whose work the scheduler has granted.  Under
  :class:`~repro.engine.scheduler.UnlimitedScheduler` every grant is
  immediate and each tenant's full trace — query costs included — is
  bit-identical to running its engine alone.
* Swaps apply in charge order per tenant; a deferred swap blocks the
  tenant's later swaps, not other tenants'.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import oreo as _oreo
from repro.core import workload as wl

from .core import LayoutEngine, StepResult
from .fleet_matrix import FleetMatrix
from .scheduler import (ReorgScheduler, SchedulerSpec, UnlimitedScheduler,
                        as_scheduler_spec)


@dataclasses.dataclass
class FleetStepResult:
    """One interleaved event's pass through the fleet.

    ``step`` is None for ingest events — they append rows without
    advancing the tenant's query index, so there is no step observation.
    """

    tick: int                   # fleet clock (1-based event counter)
    tenant_id: str
    step: Optional[StepResult]  # the tenant-local step observation
    swap_deferred: bool         # a due swap was kept waiting at this step


@dataclasses.dataclass
class FleetResult:
    """Aggregate trace of a fleet run: per-tenant RunResults + fleet totals."""

    name: str
    scheduler: str
    per_tenant: Dict[str, _oreo.RunResult]
    ticks: int
    #: Distinct swaps the scheduler kept waiting past their due step.
    swaps_deferred: int
    #: Tenant steps served under a stale layout while a due swap waited —
    #: one deferred swap accrues a tick per step until granted, so this
    #: measures wait *time*, not how many swaps were affected.
    deferred_ticks: int
    scheduler_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def total_query_cost(self) -> float:
        return sum(r.total_query_cost for r in self.per_tenant.values())

    @property
    def total_reorg_cost(self) -> float:
        return sum(r.total_reorg_cost for r in self.per_tenant.values())

    @property
    def total_cost(self) -> float:
        return self.total_query_cost + self.total_reorg_cost

    @property
    def num_reorgs(self) -> int:
        return sum(r.num_reorgs for r in self.per_tenant.values())

    @property
    def decide_seconds(self) -> float:
        return sum(r.decide_seconds for r in self.per_tenant.values())

    @property
    def reorg_seconds(self) -> float:
        return sum(r.reorg_seconds for r in self.per_tenant.values())

    @property
    def serve_seconds(self) -> float:
        return sum(r.serve_seconds for r in self.per_tenant.values())

    @property
    def wall_seconds(self) -> float:
        return self.decide_seconds + self.reorg_seconds + self.serve_seconds

    def summary(self) -> str:
        return (f"{self.name}[{self.scheduler}]: "
                f"total={self.total_cost:.1f} "
                f"(query={self.total_query_cost:.1f}, "
                f"reorg={self.total_reorg_cost:.1f}, "
                f"moves={self.num_reorgs}, "
                f"deferred={self.swaps_deferred} "
                f"over {self.deferred_ticks} ticks) "
                f"tenants={len(self.per_tenant)} ticks={self.ticks}")


class _TenantGovernor:
    """Bridges one tenant's engine hooks to the fleet's shared scheduler."""

    __slots__ = ("fleet", "tenant_id")

    def __init__(self, fleet: "FleetEngine", tenant_id: str):
        self.fleet = fleet
        self.tenant_id = tenant_id

    def on_charge(self, engine: LayoutEngine, index: int,
                  state_id: int) -> bool:
        return self.fleet._on_charge(self.tenant_id, engine, state_id)

    def may_apply(self, engine: LayoutEngine, due_index: int,
                  state_id: int) -> bool:
        return self.fleet._may_apply(self.tenant_id, engine, state_id)

    def may_begin(self, engine: LayoutEngine, due_index: int,
                  state_id: int) -> bool:
        # Incremental variant of may_apply: the granted unit stays held
        # for the whole migration (released via on_complete), so the
        # scheduler sees in-flight migrations as in-flight work.
        return self.fleet._may_apply(self.tenant_id, engine, state_id,
                                     hold=True)

    def on_complete(self, engine: LayoutEngine, state_id: int) -> None:
        self.fleet._on_complete(self.tenant_id)

    def grant_rows(self, engine: LayoutEngine, want: int) -> int:
        return self.fleet._grant_rows(self.tenant_id, want)


class FleetEngine:
    """Drives N tenant engines over one interleaved query stream.

    ``tenants`` maps tenant id → a *fresh* :class:`LayoutEngine` (not yet
    started, no governor of its own); ``scheduler`` arbitrates physical
    reorganization work fleet-wide (default: unlimited, i.e. no
    contention).  Feed events with :meth:`step` or :meth:`run`, read the
    aggregate trace with :meth:`result` — per-tenant traces are ordinary
    :class:`repro.core.oreo.RunResult` objects.
    """

    def __init__(self, tenants: Mapping[str, LayoutEngine],
                 scheduler: Optional[ReorgScheduler] = None,
                 name: str = "fleet",
                 incremental: Optional[bool] = None):
        if not tenants and incremental is None:
            # An empty fleet is legal only as a router shard awaiting
            # tenants, and then the mode cannot be inferred — requiring
            # it explicitly keeps the bare-constructor misuse loud.
            raise ValueError("a fleet needs at least one tenant (or an "
                             "explicit incremental= mode for an empty "
                             "router shard)")
        self.name = name
        if isinstance(scheduler, SchedulerSpec):
            # One fleet owning one instance is fine, so no deprecation
            # here — but accepting the declarative form everywhere lets
            # callers standardize on specs.
            scheduler = scheduler.build()
        self.scheduler = scheduler or UnlimitedScheduler()
        self._tenants: Dict[str, LayoutEngine] = dict(tenants)
        #: Incremental fleet mode (see :mod:`repro.engine.reorg`): every
        #: tenant engine must have been built with ``incremental=True``;
        #: scheduler grants are then held for whole migrations and
        #: ``grant_rows`` meters per-tick row budgets.  ``None`` infers
        #: the mode from the tenants (which must agree).
        modes = {tid: e.incremental for tid, e in self._tenants.items()}
        if incremental is None:
            if len(set(modes.values())) > 1:
                raise ValueError(
                    f"tenants mix incremental and atomic engines: {modes}")
            incremental = next(iter(modes.values()))
        else:
            wrong = [tid for tid, m in modes.items()
                     if m != bool(incremental)]
            if wrong:
                raise ValueError(
                    f"incremental={incremental!r} but tenants {wrong} were "
                    f"built with the opposite mode")
        self.incremental = bool(incremental)
        for tid, engine in self._tenants.items():
            if engine.governor is not None:
                raise ValueError(f"tenant {tid!r}: engine already governed")
            if engine._started:
                raise ValueError(f"tenant {tid!r}: engine already started")
            engine.governor = _TenantGovernor(self, tid)
        self._tick = 0
        self.swaps_deferred = 0
        self.deferred_ticks = 0
        # Whether each tenant's *front* pending swap has already been
        # counted in swaps_deferred; reset whenever a front swap resolves.
        self._front_deferred: Dict[str, bool] = {
            tid: False for tid in tenants}
        # Charged swaps whose physical work awaits a scheduler grant, in
        # fleet-wide charge order; per-tenant FIFO is enforced so a
        # tenant's later swap never overtakes its earlier one.
        self._waiting: Deque[Tuple[str, int]] = collections.deque()
        self._waiting_count: Dict[str, int] = {
            tid: 0 for tid in self._tenants}
        # Work granted (prepare issued) but swap not yet applied.
        self._granted: Dict[str, Deque[int]] = {
            tid: collections.deque() for tid in self._tenants}
        # Units held by in-flight incremental migrations (granted via
        # may_begin, released on migration completion).
        self._held: Dict[str, int] = {tid: 0 for tid in self._tenants}
        # Units held by *transplanted* in-flight migrations this fleet's
        # scheduler refused to grant at re-attach time (see add_tenant):
        # the migration keeps moving — physical work cannot be un-begun —
        # but completion must not release a unit that was never acquired
        # here, so these are consumed before self._held on completion.
        self._held_free: Dict[str, int] = {}
        # Packed decision plane for run_batched; built lazily on first use
        # and maintained incrementally from then on (tenant attach/detach
        # plus per-tenant state events), never rebuilt per tick.
        self._fleet_matrix: Optional[FleetMatrix] = None
        # Submitted-but-not-yet-processed events (see submit/drain).
        self._inbox: Deque[wl.Event] = collections.deque()

    @property
    def tenant_ids(self) -> List[str]:
        return list(self._tenants)

    def tenant(self, tenant_id: str) -> LayoutEngine:
        return self._tenants[tenant_id]

    @property
    def fleet_matrix(self) -> Optional[FleetMatrix]:
        """The packed plane behind :meth:`run_batched` (None until used)."""
        return self._fleet_matrix

    # ------------------------------------------------------------------
    # Dynamic tenant membership
    # ------------------------------------------------------------------
    def add_tenant(self, tenant_id: str, engine: LayoutEngine) -> None:
        """Register a tenant mid-flight: a fresh engine, or a transplant.

        A *fresh* engine (not started, never governed) joins exactly as
        at construction.  A *started* engine — one detached from another
        fleet via :meth:`remove_tenant`, the live-migration path — is
        **re-attached**: every charged-but-unapplied swap re-enters this
        fleet's admission queue in charge order (charges are never
        re-issued; α already landed at decision time, so the tenant's
        charge ledger is untouched by the move), and an in-flight
        incremental migration keeps its partially-summed
        :class:`~repro.engine.reorg.executor.MigrationRecord` ledger and
        holds one scheduler unit here (or a free hold if this scheduler
        refuses — moves in flight cannot be un-begun).  Under
        :class:`~repro.engine.scheduler.UnlimitedScheduler` on both
        sides, a detach/re-attach round trip is trace-bitwise invisible.
        A governed engine is always rejected — detach it first.

        If the packed plane exists it picks the tenant up incrementally
        (one new row), not via a rebuild.
        """
        if tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        if engine.governor is not None:
            raise ValueError(f"tenant {tenant_id!r}: engine already governed")
        if engine.incremental != self.incremental:
            raise ValueError(
                f"tenant {tenant_id!r}: engine incremental="
                f"{engine.incremental} but the fleet runs "
                f"incremental={self.incremental}")
        engine.governor = _TenantGovernor(self, tenant_id)
        self._tenants[tenant_id] = engine
        self._front_deferred[tenant_id] = False
        self._waiting_count[tenant_id] = 0
        self._granted[tenant_id] = collections.deque()
        self._held[tenant_id] = 0
        if engine._started:
            # Transplant: queued physical work re-enters admission here.
            for _, sid in engine._pending_swaps:
                self._waiting.append((tenant_id, sid))
                self._waiting_count[tenant_id] += 1
            executor = engine.reorg_executor
            if executor is not None and executor.active is not None:
                if self.scheduler.try_acquire(tenant_id):
                    self._held[tenant_id] = 1
                else:
                    self._held_free[tenant_id] = \
                        self._held_free.get(tenant_id, 0) + 1
        if self._fleet_matrix is not None:
            self._fleet_matrix.attach(tenant_id,
                                      self._batchable_matrix(tenant_id))

    def take_inbox(self, tenant_id: str) -> List[wl.Event]:
        """Remove and return ``tenant_id``'s queued events, in order.

        The live-migration handoff: the router drains these out of the
        source shard before :meth:`remove_tenant` and replays them into
        the target, preserving the tenant's per-event order (cross-tenant
        interleaving is not preserved — tenants are independent).
        """
        taken = [ev for ev in self._inbox if ev.tenant_id == tenant_id]
        if taken:
            self._inbox = collections.deque(
                ev for ev in self._inbox if ev.tenant_id != tenant_id)
        return taken

    def remove_tenant(self, tenant_id: str,
                      finish: bool = False) -> LayoutEngine:
        """Detach a tenant and return its (still usable) engine.

        Deterministic **finish-or-transplant** semantics for physical
        work in flight:

        * Charged-but-unapplied swaps stay on the engine's own pending
          queue (charges are decision-time and never dropped); their
          scheduler grants are released here and re-acquired wherever the
          engine lands next — a fleet via :meth:`add_tenant`, or
          standalone Δ-delay semantics if never re-attached.
        * An in-flight incremental migration either keeps migrating on
          the engine (transplant: its held unit is released to this pool
          and the partially-summed charge ledger travels with the
          engine's executor), or — with ``finish=True`` — is driven to
          completion *now*, closing the ledger bitwise on α at the
          current index, before the engine is handed back.

        Queued inbox events for the tenant must be taken first
        (:meth:`take_inbox`); leaving them behind would crash the next
        drain on an unknown tenant, so that is refused loudly here.
        """
        engine = self._tenants[tenant_id]
        if any(ev.tenant_id == tenant_id for ev in self._inbox):
            raise ValueError(
                f"tenant {tenant_id!r} has queued events; take_inbox() "
                f"them first (the router hands them to the target shard)")
        if finish:
            engine.finish_migration()
        del self._tenants[tenant_id]
        if self._waiting_count.pop(tenant_id):
            self._waiting = collections.deque(
                (t, s) for t, s in self._waiting if t != tenant_id)
        for _ in self._granted.pop(tenant_id):
            self.scheduler.release(tenant_id)
        for _ in range(self._held.pop(tenant_id, 0)):
            # An in-flight migration's unit goes back to the pool; the
            # detached engine keeps migrating under its own local budget.
            self.scheduler.release(tenant_id)
        # Free holds were never acquired from this scheduler: drop them.
        self._held_free.pop(tenant_id, None)
        self._front_deferred.pop(tenant_id)
        if self._fleet_matrix is not None:
            self._fleet_matrix.detach(tenant_id)
        engine.governor = None
        return engine

    # ------------------------------------------------------------------
    # Governor callbacks (one per tenant, shared budget)
    # ------------------------------------------------------------------
    def _on_charge(self, tid: str, engine: LayoutEngine,
                   state_id: int) -> bool:
        """A tenant charged a reorg; True lets its physical work start now."""
        if (self._waiting_count[tid] == 0
                and self.scheduler.try_acquire(tid)):
            self._granted[tid].append(state_id)
            return True
        self._waiting.append((tid, state_id))
        self._waiting_count[tid] += 1
        return False

    def _may_apply(self, tid: str, engine: LayoutEngine,
                   state_id: int, hold: bool = False) -> bool:
        """May this tenant's front (due) swap take effect at this step?

        ``hold=True`` (incremental mode) keeps the granted unit instead of
        releasing it: the migration about to begin holds it until
        :meth:`_on_complete`.  An evicted target releases immediately —
        no migration will begin for it.
        """
        granted = self._granted[tid]
        if granted and granted[0] == state_id:
            granted.popleft()
            if hold and engine.backend.has(state_id):
                self._held[tid] += 1
            else:
                self.scheduler.release(tid)
            self._front_deferred[tid] = False
            return True
        if not engine.backend.has(state_id):
            # Evicted while waiting for a grant: there is no physical work
            # to do and the engine skips the activation; just forget it.
            try:
                self._waiting.remove((tid, state_id))
                self._waiting_count[tid] -= 1
            except ValueError:
                pass
            self._front_deferred[tid] = False
            return True
        self.deferred_ticks += 1
        if not self._front_deferred[tid]:
            self._front_deferred[tid] = True
            self.swaps_deferred += 1
        return False

    def _on_complete(self, tid: str) -> None:
        """A tenant's incremental migration finished: release its unit.

        Free holds (transplanted migrations this scheduler refused to
        grant at re-attach) are consumed first and release nothing — the
        unit was never acquired from this pool.
        """
        if self._held_free.get(tid, 0) > 0:
            self._held_free[tid] -= 1
            return
        if self._held.get(tid, 0) > 0:
            self._held[tid] -= 1
            self.scheduler.release(tid)

    def _grant_rows(self, tid: str, want: int) -> int:
        """Per-tick row budget for a tenant's in-flight migration."""
        grant = getattr(self.scheduler, "grant_rows", None)
        if grant is None:
            return want
        return grant(tid, want)

    def _pump(self) -> None:
        """Grant waiting physical work, FIFO, as the scheduler allows."""
        if not self._waiting:
            return
        blocked: set = set()
        keep: Deque[Tuple[str, int]] = collections.deque()
        while self._waiting:
            tid, sid = self._waiting.popleft()
            engine = self._tenants[tid]
            if not engine.backend.has(sid):
                self._waiting_count[tid] -= 1
                continue
            if tid in blocked or not self.scheduler.try_acquire(tid):
                blocked.add(tid)
                keep.append((tid, sid))
                continue
            self._waiting_count[tid] -= 1
            self._granted[tid].append(sid)
            if not engine.incremental:
                # Incremental engines never pre-materialize: rows move at
                # apply time, a micro-batch per tick (see _apply_due_swaps).
                engine.backend.prepare(sid)
        self._waiting = keep

    # ------------------------------------------------------------------
    # Driving the fleet: submit / drain is THE entry point.  ``step``,
    # ``run`` and ``run_batched`` (and repro.serve.ServeFrontend) are all
    # drivers over it.
    # ------------------------------------------------------------------
    def submit(self, event) -> None:
        """Enqueue one :data:`repro.core.workload.Event` for processing.

        Accepts :class:`~repro.core.workload.QueryEvent` /
        :class:`~repro.core.workload.IngestEvent`; a legacy bare
        ``(tenant_id, Query | IngestBatch)`` pair is coerced with a
        :class:`DeprecationWarning`.  Nothing runs until :meth:`drain`.
        """
        self._inbox.append(wl.as_event(event))

    @property
    def queue_depth(self) -> int:
        """Events submitted but not yet drained."""
        return len(self._inbox)

    def drain(self, *, batched: bool = False, compute: str = "numpy",
              frames_per_pass: Optional[int] = None,
              collect: bool = False):
        """Process every submitted event, in submission order.

        By default each event goes through the exact per-event machinery
        (tick, pump, decide, charge, Δ-delayed swap, serve) and the number
        of events processed is returned; ``collect=True`` returns the
        per-event :class:`FleetStepResult` observations instead.

        ``batched=True`` routes the backlog through the fused
        :class:`FleetMatrix` pass (see :meth:`run_batched` for the
        ``compute`` / ``frames_per_pass`` contract); observations are not
        produced on that path, so it is mutually exclusive with
        ``collect``.
        """
        if batched and collect:
            raise ValueError("collect=True needs the per-event path; "
                             "it cannot be combined with batched=True")
        if batched:
            events = list(self._inbox)
            self._inbox.clear()
            self._drain_batched(events, compute=compute,
                                frames_per_pass=frames_per_pass)
            return len(events)
        if collect:
            results = []
            while self._inbox:
                results.append(self._dispatch(self._inbox.popleft()))
            return results
        n = 0
        while self._inbox:
            self._dispatch(self._inbox.popleft())
            n += 1
        return n

    def _dispatch(self, event: wl.Event) -> FleetStepResult:
        """Advance the fleet by one typed event (the per-event hot path)."""
        tenant_id = event.tenant_id
        engine = self._tenants[tenant_id]
        self._tick += 1
        self.scheduler.tick(self._tick)
        self._pump()
        if isinstance(event, wl.IngestEvent):
            # Rows appended to the tenant's table — visible to its very
            # next query, ticking the fleet clock and the scheduler but
            # not the tenant's own index.
            engine.ingest(event.batch.rows)
            return FleetStepResult(tick=self._tick, tenant_id=tenant_id,
                                   step=None, swap_deferred=False)
        before = self.deferred_ticks
        step = engine.step(event.query)
        return FleetStepResult(tick=self._tick, tenant_id=tenant_id,
                               step=step,
                               swap_deferred=self.deferred_ticks > before)

    def step(self, tenant_id: str, event) -> FleetStepResult:
        """Advance the fleet by one interleaved event (payload form).

        ``event`` is a :class:`repro.core.workload.Query` (one tenant
        step) or a :class:`repro.core.workload.IngestBatch`; the pair is
        wrapped into the typed :data:`repro.core.workload.Event` envelope
        and dispatched immediately, ahead of any submitted backlog.
        """
        if isinstance(event, wl.IngestBatch):
            return self._dispatch(wl.IngestEvent(tenant_id, event))
        return self._dispatch(wl.QueryEvent(tenant_id, event))

    def run(self, events: Iterable[wl.Event],
            name: Optional[str] = None) -> FleetResult:
        """Submit every event, drain, and return the trace.

        Accepts any iterable of :data:`repro.core.workload.Event`,
        including a :class:`repro.core.workload.FleetStream` or a mixed
        query/ingest :class:`repro.core.workload.IngestStream`; legacy
        bare ``(tenant_id, payload)`` pairs are accepted with a
        :class:`DeprecationWarning`.
        """
        for event in events:
            self.submit(event)
        self.drain()
        return self.result(name)

    # ------------------------------------------------------------------
    # Batched fleet path over the packed FleetMatrix plane
    # ------------------------------------------------------------------
    def _batchable_matrix(self, tenant_id: str):
        backend = self._tenants[tenant_id].backend
        matrix = getattr(backend, "state_matrix", None)
        if matrix is None or not callable(getattr(backend, "prime_estimates",
                                                  None)):
            raise ValueError(
                f"tenant {tenant_id!r}: backend has no StateMatrix plane "
                f"(compute='reference'?) — run_batched needs every tenant "
                f"on a matrix-backed backend")
        return matrix

    def _ensure_fleet_matrix(self, compute: str) -> FleetMatrix:
        if self._fleet_matrix is None:
            fm = FleetMatrix(compute_backend=compute,
                             tenant_capacity=len(self._tenants))
            for tid in self._tenants:
                fm.attach(tid, self._batchable_matrix(tid))
            self._fleet_matrix = fm
        else:
            self._fleet_matrix.set_compute_backend(compute)
        return self._fleet_matrix

    def run_batched(self, events: Iterable[wl.Event],
                    name: Optional[str] = None, compute: str = "numpy",
                    frames_per_pass: Optional[int] = None) -> FleetResult:
        """Run the fleet with per-frame fused cost evaluation.

        The event stream is cut into *frames* — maximal runs of events with
        pairwise-distinct tenants (a full round of T events under the
        default round-robin interleave).  Each frame's candidate-state and
        serve costs are evaluated for all tenants in one fused pass over
        the packed :class:`FleetMatrix` plane and primed into each tenant's
        backend; the events are then stepped **in exactly the original
        order through the per-event machinery** (tick, pump, decide,
        charge, Δ-delayed swap, serve — only the per-step observation
        objects are skipped, like ``LayoutEngine.run``'s fast path), so
        decide/charge/swap bookkeeping, scheduler grants and Δ-delay
        semantics are untouched — under ``compute="numpy"`` the trace is
        bit-identical to :meth:`run` under every scheduler.  A tenant that
        mutates its state space mid-decision invalidates its primed frame
        entry (plane-version check) and transparently falls back to the
        exact per-tenant path for that event.

        ``compute="pallas"`` routes the fused pass through the
        :func:`repro.kernels.fleet_scan.fleet_scan.scan_fleet_pallas`
        kernel (float32 — throughput on accelerators, not bit-identity).

        ``compute="pallas_fused"`` scores each pass in **one** decision
        megakernel launch over all of its frames
        (:func:`repro.engine.compute.fused_frames_scan`) instead of one
        kernel call per frame; the float32 guard keeps estimates exact
        (non-representable operands fall back to the numpy pass), so the
        bit-identity contract holds here too.

        When every tenant's policy implements the
        :class:`repro.engine.policies.BatchablePolicy` contract (and no
        incremental executor or ingest debt is attached), passes in which
        no event charges a reorganization and no swap is pending resolve
        through a *bulk* path: the argmin/threshold decision rule runs
        once per tenant over the stacked primed cost matrix and the
        per-event bookkeeping (cost trace, state trace, index, fleet
        clock) is committed wholesale — no per-event Python at all.  Any
        pass containing a charge, a pending swap, or a stale prime is
        replayed through the exact per-event machinery, so traces stay
        bit-identical under every scheduler.

        ``frames_per_pass`` controls how many frames are scored per fused
        pass (primed results a tenant invalidates by churning state are
        simply recomputed exactly at consumption time); the default scales
        with fleet size so one pass covers a few hundred events — about a
        thousand when the bulk path is available, since then per-pass
        fixed cost is all that remains.
        """
        for event in events:
            self.submit(event)
        self.drain(batched=True, compute=compute,
                   frames_per_pass=frames_per_pass)
        return self.result(name)

    def _drain_batched(self, events: List[wl.Event], compute: str,
                       frames_per_pass: Optional[int]) -> None:
        fm = self._ensure_fleet_matrix(compute)
        scheduler = self.scheduler
        # Per-tenant hot-loop facts hoisted out of the inner loop; the
        # serve memo is only primable where serve() charges exact metadata
        # scores (see InMemoryBackend._serve_primable).
        prep = {tid: (e, e.backend,
                      bool(getattr(e.backend, "_serve_primable", False)))
                for tid, e in self._tenants.items()}
        # Materialize every tenant's initial layout up front (idempotent;
        # a first step would do it anyway) so even the first fused pass
        # scores fully-populated planes instead of falling back.
        for engine, _, _ in prep.values():
            engine.start()
        # Static bulk-path eligibility: every tenant must carry a pure
        # batched decision rule and bookkeeping a no-swap frame can replay
        # wholesale (no incremental executor ticking per step, no ingest
        # debt observing per query, exact primable serve scores).
        bulk_ok = all(
            callable(getattr(engine.policy, "decide_frames", None))
            and engine.reorg_executor is None and engine._debt is None
            and primable
            for engine, _, primable in prep.values())
        n_tenants = len(prep)
        if frames_per_pass is None:
            # A few hundred events per pass amortizes the fixed Python
            # cost of a fused pass; with the bulk decide path available
            # the per-pass fixed cost is all that's left, so larger
            # passes pay off (a refused pass replays more events, but a
            # bulk-eligible fleet refuses only on actual reorg/swap
            # activity).
            per_pass = 1024 if bulk_ok else 256
            frames_per_pass = max(1, per_pass // max(n_tenants, 1))
        # Whether to skip prime-tuple materialization on the next pass:
        # flips off after a refused bulk commit (the replay needs primes,
        # and a switch-heavy stretch would otherwise score twice), back
        # on after a successful one.
        dense_hint = True
        i, n = 0, len(events)
        while i < n:
            if isinstance(events[i], wl.IngestEvent):
                # Ingest event: handled inline through the same per-event
                # machinery as :meth:`_dispatch` (tick, scheduler, pump,
                # append) — never scored by the fused pass, so a stream
                # without ingest events takes exactly the pre-ingest path.
                tid, batch = events[i]
                self._tick += 1
                scheduler.tick(self._tick)
                if self._waiting:
                    self._pump()
                prep[tid][0].ingest(batch.rows)
                i += 1
                continue
            frames: List[List[wl.QueryEvent]] = []
            while len(frames) < frames_per_pass and i < n:
                j = i
                seen = set()
                while (j < n and isinstance(events[j], wl.QueryEvent)
                       and events[j][0] not in seen):
                    seen.add(events[j][0])
                    j += 1
                frames.append(events[i:j])
                i = j
                if j < n and isinstance(events[j], wl.IngestEvent):
                    break
            # A regular pass headed for the bulk path never reads the
            # per-event prime tuples — score dense-only and, in the rare
            # case the bulk commit is refused (pending swap, stale plane,
            # a charged reorg), rescore with primes: the plane is
            # untouched in between, so the rescore is bit-identical.
            dense_only = (bulk_ok and dense_hint
                          and all(len(f) == n_tenants for f in frames))
            primed = fm.estimate_frames(frames, want_primes=not dense_only)
            if bulk_ok:
                if self._bulk_pass(frames, primed, prep):
                    dense_hint = True
                    continue
                dense_hint = False
                if dense_only:
                    primed = fm.estimate_frames(frames)
            for frame, primes in zip(frames, primed):
                for (tid, q), prime in zip(frame, primes):
                    # Inlined per-event path: same tick/pump/step sequence
                    # as :meth:`step`, minus the FleetStepResult observation
                    # (the trace comes from :meth:`result`) — mirroring how
                    # ``LayoutEngine.run``'s fast path relates to ``step``.
                    engine, backend, primable = prep[tid]
                    if prime is not None:
                        # Direct install of (query, version, costs) — the
                        # attribute form of backend.prime_estimates, minus
                        # one method call on the hottest line of the fleet.
                        # Stale costs are rejected at consumption time by
                        # the version check in _primed_costs.
                        backend._primed = (q, prime[0], prime[1])
                        if (primable and prime[2] is not None
                                and prime[0] == backend._matrix.version):
                            # Shadow serve score from the same fused pass.
                            # The version guard matters: a swap that landed
                            # at an *earlier* event of this pass bumped the
                            # plane version (activate registers the new
                            # shadow), so a score computed pre-swap must
                            # not be installed over the cleared memo — a
                            # policy that never re-estimates would
                            # otherwise serve it.  A swap landing at *this*
                            # event clears the memo after installation
                            # (activate() resets it), which stays safe.
                            backend._serve_memo = (q, prime[2])
                    self._tick += 1
                    scheduler.tick(self._tick)
                    if self._waiting:
                        self._pump()
                    engine.step_fast(q)

    def _bulk_pass(self, frames, primed, prep) -> bool:
        """Commit one scored pass without per-event Python, if legal.

        Returns True when the whole pass was resolved in bulk; False
        commits nothing — the caller replays the identical pass through
        the exact per-event machinery (decide/charge/swap/serve), which
        performs any side effects the pure batched rule must not.

        Legality is exactly "no event of the pass can touch swap or
        scheduler state": no reorganization waiting for a grant, no
        pending Δ-delayed swap, every prime current (plane untouched since
        scoring) with a ready-made serve score, and no tenant's batched
        rule charging a reorganization.  Under those conditions each event
        reduces to appending its primed serve cost and decision state —
        the bookkeeping of a no-swap ``_step_core`` — and the scheduler
        clock may advance in one jump: ``tick`` is idempotent arithmetic
        over elapsed ticks (token refill is clamped the same whether
        applied per event or once), and with no acquires in the region no
        grant decision can depend on the intermediate values.
        """
        if self._waiting:
            return False
        # Fast dense path: on a *regular* pass (every frame holds exactly
        # one event per tenant — the round-robin common case) where every
        # tenant's costs came out of the batched (B, T, S) reduction, each
        # tenant's whole cost matrix is one slice ``batched[:, row, :n]``
        # and its serve scores one column — no per-event Python at all.
        fm = self._fleet_matrix
        dense = fm.last_pass_dense if fm is not None else None
        t = len(prep)
        if dense is not None and all(len(frame) == t for frame in frames):
            batched, dinfo = dense
            b = len(frames)
            decided = []
            for tid, (engine, backend, _) in prep.items():
                d = dinfo.get(tid)
                if d is None:
                    decided = None          # mixed plane: prime-tuple path
                    break
                row, n_states, version, shadow = d
                if engine._pending_swaps or version != backend._matrix.version:
                    return False
                costs = batched[:, row, :n_states]
                states, reorg = engine.policy.decide_frames(costs, backend)
                if reorg is not None and np.any(reorg):
                    return False
                decided.append((engine, states, costs[:, shadow]))
            if decided is not None:
                for engine, states, serve in decided:
                    engine._query_costs.extend(serve.tolist())
                    engine._state_seq.extend(states.tolist())
                    engine._index += b
                self._tick += b * t
                self.scheduler.tick(self._tick)
                return True
        per: Dict[str, List[tuple]] = {}
        for frame, primes in zip(frames, primed):
            for (tid, _), prime in zip(frame, primes):
                if prime is None or prime[2] is None:
                    return False
                per.setdefault(tid, []).append(prime)
        decided = []
        for tid, plist in per.items():
            engine, backend, _ = prep[tid]
            if engine._pending_swaps or plist[0][0] != backend._matrix.version:
                return False
            costs = np.stack([p[1] for p in plist])
            states, reorg = engine.policy.decide_frames(costs, backend)
            if reorg is not None and np.any(reorg):
                return False
            decided.append((engine, states, plist))
        total = 0
        for engine, states, plist in decided:
            engine._query_costs.extend(p[2] for p in plist)
            engine._state_seq.extend(int(s) for s in states)
            engine._index += len(plist)
            total += len(plist)
        self._tick += total
        self.scheduler.tick(self._tick)
        return True

    def shard_fleets(self) -> List["FleetEngine"]:
        """The concrete fleets behind this sink: itself.

        Part of the :class:`repro.engine.EventSink` surface the serving
        tier uses to reach per-shard schedulers; a
        :class:`repro.engine.router.FleetRouter` returns its shards.
        """
        return [self]

    def stats(self) -> dict:
        """Fleet counters (one shard's worth of the EventSink contract)."""
        sched = (self.scheduler.stats()
                 if callable(getattr(self.scheduler, "stats", None)) else {})
        return {
            "name": self.name,
            "tenants": len(self._tenants),
            "queue_depth": len(self._inbox),
            "ticks": self._tick,
            "swaps_deferred": self.swaps_deferred,
            "deferred_ticks": self.deferred_ticks,
            "scheduler": sched,
        }

    def result(self, name: Optional[str] = None) -> FleetResult:
        stats = (self.scheduler.stats()
                 if callable(getattr(self.scheduler, "stats", None)) else {})
        return FleetResult(
            name=name or self.name,
            scheduler=self.scheduler.name,
            per_tenant={tid: engine.result()
                        for tid, engine in self._tenants.items()},
            ticks=self._tick,
            swaps_deferred=self.swaps_deferred,
            deferred_ticks=self.deferred_ticks,
            scheduler_stats=stats,
        )
